"""Train a small LM end-to-end on the training substrate: data pipeline,
AdamW, checkpoint/resume, preemption-safe loop.  Defaults to a ~20M-param
model sized for a CPU demo; --layers/--d-model scale it up (the same code
path the dry-run lowers at 72B/400B scale).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs import get_smoke
from repro.data.tokenizer import TOKENIZER
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke("llama3.2-3b").with_(
        vocab_size=TOKENIZER.vocab_size, num_layers=args.layers,
        d_model=args.d_model, num_heads=max(args.d_model // 64, 1),
        num_kv_heads=max(args.d_model // 128, 1), d_ff=args.d_model * 4)
    print(f"model params: {cfg.param_count()/1e6:.1f}M")
    loop = LoopConfig(steps=args.steps, batch=args.batch, seq_len=args.seq_len,
                      ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10,
                      compress_grads=args.compress_grads)
    ocfg = opt.OptimizerConfig(learning_rate=3e-4, warmup_steps=20,
                               total_steps=args.steps)
    metrics = run(cfg, ocfg, loop)
    print("final:", metrics)


if __name__ == "__main__":
    main()
