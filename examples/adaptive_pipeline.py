"""Adaptive re-optimization on a drifting-selectivity workload.

The pipeline chains a broad (~90% pass) and a narrow (~5% pass) filter
above a ``sem_map``.  Nothing below the chain is a Scan, so the plan-time
optimizer cannot probe selectivities and keeps the expensive as-written
order.  The first run observes reality into a ``StatsStore``; the second,
adaptive run blends those observations into its live cost model, promotes
the narrow filter mid-query, and pays a visibly smaller oracle bill for
bit-identical records.

    PYTHONPATH=src python examples/adaptive_pipeline.py
"""
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.obs.stats_store import StatsStore

records, world, *_ = synth.make_filter_world(120, seed=8)
synth.add_phrase_predicate(world, records, "is broad", 0.9, seed=8)
synth.add_phrase_predicate(world, records, "is narrow", 0.05, seed=8)


def session():
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world), sample_size=40)


def chain(log):
    return (SemFrame(records, session(), log).lazy()
            .sem_map("a short note on {claim}", out_column="note")
            .sem_filter("the {claim} is broad")
            .sem_filter("the {claim} is narrow"))


def oracle_calls(log):
    return sum(st.get("oracle_calls", 0) for st in log)


store = StatsStore()

# -- run 1: static plan, observing into the store ---------------------------
log1 = []
first = chain(log1).collect(stats_store=store)
print(f"run 1 (static, cold store): {oracle_calls(log1)} oracle calls, "
      f"{len(first.records)} rows")

# the store now knows both predicates' observed selectivities
for e in store.snapshot():
    if e["operator"] == "sem_filter":
        print(f"  observed {e['operator']}[{e['fingerprint']}] "
              f"sel={e['selectivity']}")

# -- run 2: adaptive, warm store -------------------------------------------
log2 = []
frame = chain(log2)
second = frame.collect(adaptive=True, stats_store=store)
calls1, calls2 = oracle_calls(log1), oracle_calls(log2)
print(f"run 2 (adaptive, warm store): {calls2} oracle calls "
      f"({100 * (calls1 - calls2) / calls1:.0f}% saved)")

for e in frame._exec_pair[2].replans:
    print(f"  replan [{e.kind}] {e.node}: {e.reason}")

assert second.records == first.records
print("records identical:", second.records == first.records)

# -- the feedback is visible in explain() -----------------------------------
print("\nwarm explain (observed selectivity next to the prior):")
print(chain([]).explain(stats_store=store))
