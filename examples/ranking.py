"""Search & ranking (paper §5.3, Tables 6-7): sem_topk algorithms compared on
an objective synthetic benchmark (HellaSwag-bench analogue).

    PYTHONPATH=src python examples/ranking.py
"""
from repro.core.backends import synth
from repro.core.backends.base import CountedModel
from repro.core.operators.topk import (sem_topk_heap, sem_topk_quadratic,
                                       sem_topk_quickselect)

records, world, model, embedder, pivot_scores = synth.make_rank_world(
    120, compare_noise=0.05, seed=4)
model = CountedModel(model, "oracle")
truth = sorted(range(120), key=lambda i: -world.rank_value[records[i]["id"]])[:10]

for name, fn, kw in (
    ("quadratic   ", sem_topk_quadratic, {}),
    ("heap        ", sem_topk_heap, {}),
    ("quickselect ", sem_topk_quickselect, {"seed": 0}),
    ("pivot-opt   ", sem_topk_quickselect, {"seed": 0, "pivot_scores": pivot_scores}),
):
    idx, st = fn(records, "the {abstract} with the highest accuracy", 10, model, **kw)
    hit = len(set(idx) & set(truth))
    print(f"{name} overlap@10={hit}/10  comparisons={st['compare_calls']}")
