"""Topic analysis over an ArXiv-like corpus (paper §5.4, Figs 7-8):
sem_group_by discovery + guaranteed-accuracy classification + per-group
aggregation.

    PYTHONPATH=src python examples/topic_analysis.py
"""
from collections import Counter

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

records, world, model, embedder = synth.make_topic_world(400, 5, seed=3)
sess = Session(oracle=model, embedder=embedder, sample_size=120)
papers = SemFrame(records, sess)

grouped = papers.sem_group_by("the topic of each {paper}", 5,
                              accuracy_target=0.85, delta=0.2)
st = papers.last_stats()
print("discovered groups:", Counter(t["group_label"] for t in grouped.records))
print(f"classification: {st['proxy_classified']} by proxy, "
      f"{st['oracle_classified']} by oracle (tau={st['tau']:.3f})")

summaries = grouped.sem_agg("summarize the papers: {paper}", group_by="group")
for g, s in sorted(summaries.items()):
    print(f"group {g}: {s[:60]}")
