"""END-TO-END DRIVER: serve a small LM with batched requests through the full
JAX inference engine (continuous batching + KV cache + logprob scoring) and
run a complete semantic-operator pipeline on top of it — the paper's
production dataflow (LOTUS over vLLM), here over our TPU-native substrate
with randomly initialized weights.

    PYTHONPATH=src python examples/serve_semantic_pipeline.py
"""
import time

from repro.core.backends.jax_engine import make_session
from repro.core.frame import SemFrame

print("building oracle/proxy engines + embedding encoder (JAX, CPU)...")
sess = make_session(max_seq=256)

records = [{"claim": f"statement {i}: widget-{i % 7} is compatible with gadget-{i % 3}"}
           for i in range(24)]
sf = SemFrame(records, sess)

t0 = time.time()
mapped = sf.sem_map("rewrite {claim} as a question")
print(f"sem_map over engine: {len(mapped)} generations in {time.time()-t0:.1f}s "
      f"({mapped.last_stats()['generate_calls']} LM calls, continuous batching)")

t0 = time.time()
filtered = sf.sem_filter("the {claim} is plausible",
                         recall_target=0.8, precision_target=0.8, delta=0.3)
st = sf.last_stats()
print(f"sem_filter cascade: {len(filtered)} pass in {time.time()-t0:.1f}s "
      f"(proxy scored {st['proxy_calls']}, oracle confirmed {st['oracle_calls']})")

idx = sf.sem_index("claim")
hits = sf.sem_search("claim", "widget-3 compatibility", k=3, index=idx)
print("sem_search top-3:", [t["claim"][:40] for t in hits.records])
print("engine stats:", sess.oracle._m.engine.stats)
