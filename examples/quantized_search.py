"""Quantized retrieval hot path: int8 IVF tiles + fused dequantize+score
scan, exact fp32 rerank, byte-aware plan costing, and persistence.

    PYTHONPATH=src python examples/quantized_search.py
"""
import tempfile

import numpy as np

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.index import (IVFIndex, VectorIndex, bytes_per_vector,
                         choose_retrieval_config)

records, world, oracle, proxy, embedder = synth.make_filter_world(3000, seed=0)
sess = Session(oracle=oracle, embedder=embedder)
claims = SemFrame(records, sess)

# -- operator-level: pin int8 tiles on a search ------------------------------
hits = claims.sem_search("claim", "claim text 42", k=5, index_kind="ivf",
                         quantize="int8")
st = hits.last_stats()
print("int8 ivf :", [t["id"] for t in hits.records],
      f"| scanned_bytes: {st['scanned_bytes']} "
      f"| exact-reranked rows: {st['rerank_exact_rows']}")
fp = claims.sem_search("claim", "claim text 42", k=5, index_kind="ivf")
print(f"fp32 ivf : scanned_bytes: {fp.last_stats()['scanned_bytes']} "
      f"({fp.last_stats()['scanned_bytes'] / st['scanned_bytes']:.2f}x more)")

# -- index-level: the rerank keeps the recall contract -----------------------
rng = np.random.default_rng(0)
centers = rng.normal(size=(64, 64))
centers /= np.linalg.norm(centers, axis=1, keepdims=True)
corpus = centers[rng.integers(64, size=20_000)] \
    + 0.18 * rng.normal(size=(20_000, 64))
corpus = np.asarray(corpus / np.linalg.norm(corpus, axis=1, keepdims=True),
                    np.float32)
queries = np.asarray(centers[rng.integers(64, size=16)]
                     + 0.18 * rng.normal(size=(16, 64)), np.float32)

_, exact_idx = VectorIndex(corpus).search(queries, 10)
ivf_q = IVFIndex(corpus, quantize="int8")         # rerank_factor=4 default
_, q_idx = ivf_q.search(queries, 10)
recall = np.mean([len(set(exact_idx[i]) & set(q_idx[i])) / 10
                  for i in range(len(queries))])
print(f"\nint8 + exact rerank recall@10 vs exact: {recall:.3f}")
print("tile bytes/vector:",
      f"fp32={bytes_per_vector(64, 'none'):.0f}",
      f"int8={bytes_per_vector(64, 'int8'):.0f}",
      f"({bytes_per_vector(64, 'none') / bytes_per_vector(64, 'int8'):.2f}x)")
print("describe:", ivf_q.describe())

# -- byte-aware cost model ---------------------------------------------------
# the serving regime (shared=True: an IndexRegistry amortizes the build)
# picks int8 once the byte win beats the rerank re-reads
cfg = choose_retrieval_config(50_000, 64, shared=True)
print(f"\n50k-corpus serving plan: kind={cfg['kind']} "
      f"nprobe={cfg['nprobe']} quantize={cfg['quantize']}")
print(f"  bytes/query: fp32 scan {cfg['costs']['ivf_bytes_per_query']:.0f} "
      f"vs int8 {cfg['costs']['ivf_q_bytes_per_query']:.0f}")

# -- persistence: int8 store + scales round-trip -----------------------------
with tempfile.TemporaryDirectory() as tmp:
    ivf_q.save(tmp)
    loaded = IVFIndex.load(tmp)
    _, i2 = loaded.search(queries, 10)
    assert np.array_equal(q_idx, i2)
    print("\nsave/load round-trip identical:", loaded.describe()["quantize"])
