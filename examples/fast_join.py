"""Fast semantic joins: blocking -> block prompts -> transitivity pruning.

An entity-resolution join ("which mention refers to which entity record?")
is an *equivalence* predicate, the regime where the block-join path shines:
each left row retrieves only a top-k candidate block from the retrieval
layer, candidates are judged 16 pairs per structured prompt, and confirmed
verdicts propagate through a union-find transitivity closure so implied
pairs never reach the oracle at all.  The per-stage ledger below shows
where the prompt budget actually goes.

    PYTHONPATH=src python examples/fast_join.py
"""
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

N_LEFT, N_RIGHT, N_CLASSES = 120, 80, 16
LX = "the {mention} refers to the same entity as {entity:right}"

left, right, world, oracle, proxy, embedder = synth.make_entity_world(
    N_LEFT, N_RIGHT, N_CLASSES, seed=4)
sess = Session(oracle=oracle, embedder=embedder, sample_size=150)
mentions = SemFrame(left, sess)

matched = mentions.sem_join(right, LX, recall_target=0.9,
                            precision_target=0.9, strategy="block")
st = mentions.last_stats()

grid = N_LEFT * N_RIGHT
print(f"matched rows:  {len(matched)}  (pair grid {grid})")
print()
print("stage 1 - blocking (retrieval layer)")
print(f"  candidate pairs: {st['candidate_pairs']}  "
      f"(k={st['candidate_k']} per left row, "
      f"{grid - st['candidate_pairs']} pairs never considered)")
print(f"  coverage est:    {st['coverage_est']}  (index: {st['index']})")
print()
print("stage 2 - block prompts (16 pairs per oracle prompt)")
print(f"  block prompts:   {st['block_prompts']}  "
      f"({st['pairs_block_judged']} pairs judged, "
      f"{st['block_retries']} strict retries, "
      f"{st['block_fallbacks']} pairwise fallbacks)")
print(f"  block agreement: {st['block_agreement']}  "
      f"(calibration blocks re-judged: {st['blocks_rejudged']})")
print()
print("stage 3 - transitivity inference")
print(f"  equivalence:     {st['equivalence']}  "
      f"({st['match_classes']} match classes)")
print(f"  pruned:          {st['pairs_pruned_by_inference']} candidate "
      f"verdicts implied without prompting")
print(f"  recovered:       {st['pairs_recovered_by_inference']} blocking "
      f"misses restored by the closure")
print()

truth = {(i, j) for i in range(N_LEFT) for j in range(N_RIGHT)
         if world.join_truth.get((left[i]["id"], right[j]["id"]))}
have = {(rec["id"], rec["right_id"]) for rec in matched.records}
hits = sum(1 for (i, j) in truth
           if (left[i]["id"], right[j]["id"]) in have)
recall = hits / max(len(truth), 1)
precision = sum(1 for pair in have
                if world.join_truth.get(pair)) / max(len(have), 1)

print("ledger")
print(f"  oracle prompts:  {st['lm_calls']}  vs gold {grid}  "
      f"-> {grid / max(st['lm_calls'], 1):.0f}x fewer")
print(f"  recall vs gold:  {recall:.3f}  (target 0.9, "
      f"precision {precision:.3f})")
