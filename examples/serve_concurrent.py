"""WALKTHROUGH: serving many semantic pipelines concurrently.

Three users hit the system at once: a fact-checker filtering claims, an
analyst joining articles to reaction labels, and a latecomer who repeats the
fact-checker's query.  One Gateway runs them all — the dispatcher fuses
their oracle calls into shared micro-batches, the shared semantic cache
means the latecomer's repeated predicate is answered entirely from the work
the first session already paid for, and per-tenant fair scheduling keeps
the analyst from being starved by the fact-checking traffic.

    PYTHONPATH=src python examples/serve_concurrent.py
"""
import json

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.serve import Gateway

# -- a shared corpus with known ground truth --------------------------------
left, right, world, *_ = synth.make_join_world(40, 10, seed=42)
synth.add_phrase_predicate(world, left, "is checkable", 0.35, seed=42)

session = Session(oracle=synth.SimulatedModel(world, "oracle"),
                  embedder=synth.SimulatedEmbedder(world), sample_size=40)

# -- the gateway: 3 workers, 5 ms fusion window, TTL'd shared cache ---------
with Gateway(session, max_inflight=3, window_s=0.005,
             cache_ttl_s=600.0) as gw:

    def fact_check():
        return (SemFrame(left, gw.session).lazy()
                .sem_filter("the {abstract} is checkable"))

    def label_join():
        return (SemFrame(left, gw.session).lazy()
                .sem_join(right, "the {abstract} reports the {reaction:right}"))

    # two tenants submit concurrently; the third session repeats tenant
    # "press"'s query and should ride almost entirely on cache
    h1 = gw.submit(fact_check(), tenant="press")
    h2 = gw.submit(label_join(), tenant="pharma",
                   deadline_s=30.0)             # analysts want bounded latency
    h1.result()
    h3 = gw.submit(fact_check(), tenant="press")   # the latecomer

    for h in (h1, h2, h3):
        rows = h.result()
        st = h.stats
        print(f"{h.sid} [{h.tenant:7s}] {h.status}: {len(rows):3d} rows, "
              f"paid {st.oracle_calls:3d} oracle calls, "
              f"rode {st.cache_hits:3d} shared answers "
              f"({1e3 * h.latency_s:.0f} ms)")

    assert h3.result() == h1.result()           # identical answers
    assert h3.stats.oracle_calls == 0           # the latecomer paid nothing

    snap = gw.snapshot()
    print(f"\ngateway: {snap['completed']} sessions, "
          f"{snap['throughput_rps']:.1f}/s, p95 {snap['p95_latency_s']}s")
    print(f"cross-query hit rate: {snap['cross_query_hit_rate']:.2f}")
    print("dispatch:", json.dumps(snap["dispatch"]))
