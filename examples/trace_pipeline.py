"""Observability end to end: EXPLAIN ANALYZE over a filter -> join -> topk
pipeline, the observed-statistics store, and Perfetto-loadable trace export.

    PYTHONPATH=src python examples/trace_pipeline.py
"""
import json
import tempfile

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.obs import StatsStore, explain_analyze
from repro.serve import Gateway

left, right, world, *_ = synth.make_join_world(40, 8, seed=11)
synth.add_phrase_predicate(world, left, "is checkable", 0.4, seed=11)


def session():
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world), sample_size=40)


def pipeline(sess):
    return (SemFrame(left, sess).lazy()
            .sem_filter("the {abstract} is checkable")
            .sem_join(right, "the {abstract} reports the {reaction:right}")
            .sem_topk("most accurate {abstract}", 5))


# -- EXPLAIN ANALYZE: predicted vs observed, per plan node ------------------
# The optimizer prices each node from an importance sample; explain_analyze
# runs the plan under a tracer and prints the prediction next to what the
# node actually did — flagging nodes where the cost model drifted.
store = StatsStore()
report = explain_analyze(pipeline(session()), stats_store=store)
print(report.render())
print(f"\nresult rows: {len(report.records)}, "
      f"drifted nodes: {len(report.drifted)}")

# every executed semantic node also lands in the stats store, keyed by
# (operator, predicate-fingerprint) — selectivity is a property of the
# predicate, so observations accumulate across corpora and sessions
print("\nobserved statistics:")
for e in store.snapshot():
    print(f"  {e['operator']}[{e['fingerprint'][:8]}] "
          f"runs={e['runs']} sel={e['selectivity']} "
          f"oracle={e['oracle_calls']}")

# -- gateway tracing: spans from every layer, exportable --------------------
with tempfile.TemporaryDirectory() as tmp:
    with Gateway(session(), max_inflight=2, trace=True) as gw:
        sess = gw.submit(pipeline(gw.session))
        sess.result(timeout=60)

        # the per-session span tree: session -> plan stages -> operators,
        # plus dispatcher batches fused on the dispatcher thread
        print("\nsession trace:")
        for sp in gw.session_trace(sess.sid)[:8]:
            print(f"  {sp.kind:12s} {sp.name:28s} {sp.dur_s * 1e3:7.2f}ms")

        # span-derived stage breakdown inside the gateway snapshot
        stages = gw.snapshot()["stages"]
        ops = {k: v for k, v in stages.items() if k.startswith("operator/")}
        print("\nstage breakdown:", json.dumps(ops, indent=2)[:400])

        # export: one-span-per-line JSONL, or Chrome trace_event JSON you
        # can load in Perfetto (https://ui.perfetto.dev) / chrome://tracing
        n = gw.export_trace(f"{tmp}/trace.jsonl")
        gw.export_trace(f"{tmp}/trace.json", fmt="chrome")
        with open(f"{tmp}/trace.json") as fh:
            events = json.load(fh)["traceEvents"]
        print(f"\nexported {n} spans ({len(events)} trace events)")
