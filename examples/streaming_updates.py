"""WALKTHROUGH: a live corpus under a standing semantic query.

A fact-checking team keeps a claims corpus in a ``CorpusTable`` and
subscribes a sem_filter pipeline through the gateway.  New claims stream in
while the subscription is live: each append triggers a re-execution in
which ONLY the new rows reach the oracle — the shared semantic cache
already holds every earlier row's judgment — and the emission reports the
delta (which records appeared).  A second gateway run over the same
persistence file answers the whole corpus from disk without a single
oracle call.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import os
import tempfile

import numpy as np

from repro.core.backends import synth
from repro.core.backends.testing import CountingBackend
from repro.core.frame import Session
from repro.serve import Gateway
from repro.stream import CorpusTable

# -- a live corpus with known ground truth ----------------------------------
records, world, *_ = synth.make_filter_world(80, seed=11)
table = CorpusTable(records, name="claims")
rng = np.random.default_rng(7)


def breaking_news(start, n):
    rows = []
    for i in range(start, start + n):
        rid = f"claim{i}"
        world.filter_truth[rid] = bool(rng.random() < 0.4)
        rows.append({"id": rid, "claim": f"claim text {i} {synth.tag(rid)}"})
    return rows


persist = os.path.join(tempfile.mkdtemp(), "semantic_cache.jsonl")
backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
session = Session(oracle=backend, embedder=synth.SimulatedEmbedder(world))

# -- first run: subscribe, then watch appends flow through ------------------
with Gateway(session, max_inflight=2, persist_path=persist) as gw:
    sub = gw.subscribe(table.lazy(session)
                       .sem_filter("the {claim} is supported"))

    first = sub.poll(timeout=120)
    print(f"v{first.version}: {len(first.records)} supported claims "
          f"(oracle judged all {backend.n_prompts} rows)")

    for batch in range(2):
        before = backend.n_prompts
        table.append(breaking_news(80 + 10 * batch, 10))
        em = sub.poll(timeout=120)
        print(f"v{em.version}: +{len(em.added)} new matches, "
              f"{len(em.records)} total — oracle saw only "
              f"{backend.n_prompts - before} prompts for 10 new rows")

    snap = gw.snapshot()
    print(f"emissions={snap['emissions']}, "
          f"store entries={snap['cache']['entries']}")

# -- second run: the persisted cache answers everything from disk -----------
backend2 = CountingBackend(synth.SimulatedModel(world, "oracle"))
session2 = Session(oracle=backend2, embedder=synth.SimulatedEmbedder(world))
with Gateway(session2, max_inflight=1, persist_path=persist) as gw:
    sub = gw.subscribe(table.lazy(session2)
                       .sem_filter("the {claim} is supported"))
    replay = sub.poll(timeout=120)
    print(f"second run at v{replay.version}: {len(replay.records)} rows, "
          f"{backend2.n_prompts} oracle prompts — the persisted cache "
          f"answered every judgment from disk")
