"""Lazy plan optimization end to end: the declarative payoff of §2.

The same fact-check pipeline is written once and executed two ways —
operator-at-a-time (eager) and as an optimized logical plan (lazy).  The
optimizer reorders the filter chain by cost x selectivity, and the batched
executor's prompt cache makes the optimizer's own selectivity probes free at
execution time.  Output records are identical; the oracle bill is not.

    PYTHONPATH=src python examples/lazy_pipeline.py
"""
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

left, right, world, oracle, proxy, emb = synth.make_join_world(80, 10, seed=0)
synth.add_phrase_predicate(world, left, "names a checkable claim", 0.15)
synth.add_phrase_predicate(world, left, "is written in English", 0.85)


def fresh_frame(log):
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world), sample_size=60)
    return SemFrame(left, sess, log)


def pipeline(sf):
    return (sf.sem_filter("the {abstract} is written in English")   # broad
              .sem_filter("the {abstract} names a checkable claim")  # selective
              .sem_join(right, "the {abstract} reports the {reaction:right}"))


eager_log: list = []
eager = pipeline(fresh_frame(eager_log))

lazy_log: list = []
lazy = pipeline(fresh_frame(lazy_log).lazy())
print(lazy.explain())
out = lazy.collect()

tally = lambda log: sum(st.get("oracle_calls", 0) for st in log)
print(f"\neager:     {tally(eager_log)} oracle calls -> {len(eager.records)} rows")
print(f"optimized: {tally(lazy_log)} oracle calls -> {len(out.records)} rows "
      f"(identical: {out.records == eager.records})")
