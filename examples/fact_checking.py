"""Fact-checking (paper §5.1, Table 2): the FacTool pipeline as 3 semantic
operators — map (claim -> queries), search (evidence), filter (verdict) —
with and without the cascade optimizer.

    PYTHONPATH=src python examples/fact_checking.py
"""
import time

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

N = 500
records, world, oracle, proxy, embedder = synth.make_filter_world(
    N, positive_rate=0.5, proxy_alpha=2.0, seed=1)
sess = Session(oracle=oracle, proxy=proxy, embedder=embedder, sample_size=100)
claims = SemFrame(records, sess)

# --- pipeline: map -> (index+search) -> filter -------------------------
t0 = time.time()
with_queries = claims.sem_map("write two search queries for {claim}",
                              out_column="queries")
idx = with_queries.sem_index("claim")          # the "wikipedia" index
verdict_gold = with_queries.sem_filter("the {claim} is supported by evidence")
t_gold = time.time() - t0
gold_ids = {t["id"] for t in verdict_gold.records}
print(f"[unopt] {len(verdict_gold)} supported | {t_gold:.2f}s | "
      f"{sum(s['lm_calls'] for s in claims.stats_log)} LM calls")

t0 = time.time()
verdict_opt = with_queries.sem_filter("the {claim} is supported by evidence",
                                      recall_target=0.9, precision_target=0.9,
                                      delta=0.2)
t_opt = time.time() - t0
st = with_queries.last_stats()
opt_ids = {t["id"] for t in verdict_opt.records}
agree = 1 - len(gold_ids ^ opt_ids) / N
print(f"[opt]   {len(verdict_opt)} supported | {t_opt:.2f}s | "
      f"{st['oracle_calls']} oracle calls | agreement vs gold {agree:.1%}")
