"""Online guarantee monitoring: catch silent drift, recalibrate, recover.

A cascade filter ships with statistical guarantees (recall/precision >= 0.9)
that hold *for the distribution its thresholds were calibrated on*.  When
the world drifts underneath a deployed cascade, those guarantees fail
silently — the pipeline keeps returning rows, the bill looks normal, and
nothing on the query path can tell.  The ``GuaranteeAuditor`` closes that
gap: it samples a budgeted fraction of the cascade's auto-accepts and
auto-rejects, re-judges them with the gold oracle in the background, and
maintains confidence intervals on the *live* precision and recall.

Three acts:

  1. healthy traffic — the audited CI brackets the target; no alerts;
  2. drift — reality flips under the calibrated thresholds; the CI lower
     bound collapses, a structured violation fires, and the matching
     StatsStore fingerprint is poisoned so the optimizer stops trusting
     stale observations;
  3. recalibration — the cascade re-calibrates its thresholds against
     current traffic and the audited CI climbs back above the target.

    PYTHONPATH=src python examples/guarantee_monitor.py
"""
import json

from repro.core.backends import synth
from repro.core.operators.filter import sem_filter_cascade
from repro.obs import audit as A
from repro.obs.stats_store import StatsStore, predicate_fingerprint

TEMPLATE = "{claim} holds"
TARGET = 0.9
FP = predicate_fingerprint("Filter", TEMPLATE)

# the world production was calibrated on, and a drifted copy whose gold
# labels have all flipped (worst-case drift: the serving proxy and the
# calibrated thresholds are now confidently wrong)
records, world, oracle, proxy, _ = synth.make_filter_world(
    400, proxy_alpha=2.5, seed=7)
_, drifted, *_ = synth.make_filter_world(400, proxy_alpha=2.5, seed=7)
for rid in drifted.filter_truth:
    drifted.filter_truth[rid] = not drifted.filter_truth[rid]

store = StatsStore()
events = []


def run_rounds(auditor, oracle, proxy, n_rounds=3):
    with A.activate_ctx(auditor):
        for r in range(n_rounds):
            sem_filter_cascade(records, TEMPLATE, oracle, proxy,
                               recall_target=TARGET, precision_target=TARGET,
                               delta=0.2, sample_size=100, seed=3 + r)
    auditor.drain()


def show(auditor, label):
    est = auditor.report_for(FP)
    for kind in ("precision", "recall"):
        ci = est[kind]
        if ci is None:
            print(f"  [{label}] {kind}: not enough audited samples")
        else:
            print(f"  [{label}] {kind} ~{ci['point']:.3f} "
                  f"CI [{ci['lo']:.3f}, {ci['hi']:.3f}] "
                  f"n={ci['n']} target={TARGET}")


policy = A.AuditPolicy(sample_fraction=0.5, budget_per_window=256,
                       window_s=3600.0, min_samples=16, seed=1)

# -- act 1: healthy traffic — gold oracle agrees with the calibration -------
aud = A.GuaranteeAuditor(synth.SimulatedModel(world, "oracle"), policy=policy,
                         stats_store=store, on_violation=events.append)
run_rounds(aud, oracle, proxy)
print("act 1: healthy traffic")
show(aud, "healthy")
print(f"  violations: {sum(aud.violation_counts.values())}, "
      f"gold calls: {aud.stats.audit_calls}")
aud.close()

# -- act 2: reality drifts under the calibrated cascade ---------------------
# the optimizer has history for this predicate; drift makes it a lie
store.observe("Filter", FP, rows_in=400, rows_out=200, wall_s=0.1,
              stats={"oracle_calls": 100})
events.clear()
aud = A.GuaranteeAuditor(synth.SimulatedModel(drifted, "oracle"),
                         policy=policy, stats_store=store,
                         on_violation=events.append)
run_rounds(aud, oracle, proxy)       # serving models are now stale
print("\nact 2: drifted traffic (same thresholds, flipped reality)")
assert events, "drift must trip the auditor"
first = events[0]
print(f"  [drifted] {first.kind} lower bound {first.lower:.3f} < "
      f"target {first.target} after n={first.n} audited samples "
      f"(window resets after each alert)")
print(f"  {len(events)} violation(s); first event:")
print("   ", json.dumps(first.as_dict(), indent=2).replace("\n", "\n    "))
assert store.get("Filter", FP) is None, "stale stats should be dropped"
print(f"  StatsStore entries poisoned: {store.poisoned} "
      f"(optimizer will re-observe instead of trusting stale stats)")
aud.close()

# -- act 3: recalibrate against current traffic and re-audit ----------------
# post-drift reality: a fresh calibration world standing in for "today's"
# traffic; the cascade re-derives its thresholds and the CI recovers
records3, world3, oracle3, proxy3, _ = synth.make_filter_world(
    400, proxy_alpha=2.5, seed=13)
records = records3
aud = A.GuaranteeAuditor(synth.SimulatedModel(world3, "oracle"),
                         policy=policy, stats_store=store,
                         on_violation=events.append)
run_rounds(aud, oracle3, proxy3)
print("\nact 3: recalibrated cascade on current traffic")
show(aud, "recalibrated")
est = aud.report_for(FP)
assert est["precision"] is None or est["precision"]["lo"] > 0.5
assert not aud.violation_counts, "recalibrated cascade must audit clean"
print(f"  violations after recalibration: "
      f"{sum(aud.violation_counts.values())}")
aud.close()
