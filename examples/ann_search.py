"""Retrieval layer end to end: exact vs IVF-pruned ANN search, the recall
knob, persistence of both index formats, and cost-based plan selection.

    PYTHONPATH=src python examples/ann_search.py
"""
import tempfile

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.core.operators.search import load_sem_index
from repro.index import retrieval_costs

records, world, oracle, proxy, embedder = synth.make_filter_world(3000, seed=0)
sess = Session(oracle=oracle, embedder=embedder)
claims = SemFrame(records, sess)

# -- explicit index kinds ---------------------------------------------------
exact = claims.sem_search("claim", "claim text 42", k=5, index_kind="exact")
print("exact   :", [t["id"] for t in exact.records],
      "| scored:", exact.last_stats()["scored_vectors"])

ivf = claims.sem_search("claim", "claim text 42", k=5, index_kind="ivf")
st = ivf.last_stats()
print("ivf     :", [t["id"] for t in ivf.records],
      f"| scored: {st['scored_vectors']} "
      f"(probed {st['probed_clusters']} clusters)")

# the recall knob: nprobe = all clusters degenerates to exact-identical
full = claims.sem_search("claim", "claim text 42", k=5, index_kind="ivf",
                         nprobe=10_000)
assert [t["id"] for t in full.records] == [t["id"] for t in exact.records]
print("nprobe=all reproduces the exact top-k")

# -- cost-based plan selection ----------------------------------------------
# index_shared=True models the serving regime (an IndexRegistry amortizes
# the IVF build across sessions); a one-shot collect with no registry
# charges the whole build to this plan and stays exact
lz = claims.lazy().sem_search("claim", "claim text 7", k=5)
print("\n" + lz.explain(index_min_corpus=500, index_shared=True))
print("\ncost model on a 50k corpus (serving regime):",
      retrieval_costs(50_000, 64, recall_target=0.95, shared=True))

# -- persistence: both formats round-trip through one loader ----------------
with tempfile.TemporaryDirectory() as tmp:
    claims.sem_index("claim", path=f"{tmp}/exact")
    claims.sem_index("claim", path=f"{tmp}/ivf", index="ivf")
    for p in (f"{tmp}/exact", f"{tmp}/ivf"):
        idx = load_sem_index(p)
        print(f"loaded {p.split('/')[-1]:5s} ->", idx.describe())
