"""Partitioned parallel execution on a multi-device CPU mesh.

Forces a 4-logical-device CPU topology (the XLA flag must be set before jax
first initializes), then runs one semantic pipeline twice — single-partition
and cut into 4 Exchange-bounded fragments with the corpus device-sharded —
and shows that the outputs, the cascade thresholds, and the oracle bill are
identical while the plan (``explain``) now carries Partition/Exchange
boundaries and per-fragment cost shares.

    PYTHONPATH=src python examples/partitioned_pipeline.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402  (after the device-count flag)

from repro.core.backends import synth  # noqa: E402
from repro.core.frame import SemFrame, Session  # noqa: E402

N_ROWS = 6000
PART_KW = dict(n_partitions=4, fragment_workers=4, shard_min_corpus=2048)


def make_session(world):
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   proxy=synth.SimulatedModel(world, "proxy"),
                   embedder=synth.SimulatedEmbedder(world), sample_size=100)


def main() -> None:
    print(f"devices: {jax.devices()}")
    records, world, *_ = synth.make_filter_world(N_ROWS, positive_rate=0.35,
                                                 seed=11)
    synth.add_phrase_predicate(world, records, "is urgent", 0.2, seed=11)

    def pipeline(sf):
        return (sf.lazy()
                  .sem_filter("the {claim} is urgent",
                              recall_target=0.9, precision_target=0.9)
                  .sem_search("claim", "claim text 40", k=5))

    log_single, log_part = [], []
    single = pipeline(SemFrame(records, make_session(world),
                               log_single)).collect()

    lazy = pipeline(SemFrame(records, make_session(world), log_part))
    print("\n== partitioned plan ==")
    print(lazy.explain(**PART_KW).split("== optimized plan ==")[1])
    part = lazy.collect(**PART_KW)

    calls = lambda log: sum(st.get("oracle_calls", 0) for st in log)
    st_s = next(st for st in log_single if st["operator"] == "sem_filter")
    st_p = next(st for st in log_part if st["operator"] == "sem_filter")
    print(f"records identical:   {part.records == single.records}")
    print(f"thresholds identical: tau+ {st_p['tau_plus'] == st_s['tau_plus']}, "
          f"tau- {st_p['tau_minus'] == st_s['tau_minus']}")
    print(f"oracle calls:        single={calls(log_single)} "
          f"partitioned={calls(log_part)}")
    print(f"filter fragments:    {st_p.get('n_partitions')} partitions "
          f"{st_p.get('partition_sizes')}")
    search_st = next(st for st in log_part if st["operator"] == "sem_search")
    print(f"search index:        {search_st.get('index')} "
          f"(device-sharded when the corpus clears shard_min_corpus)")


if __name__ == "__main__":
    main()
