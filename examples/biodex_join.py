"""Extreme multi-label classification via sem_join (paper §5.2, Tables 3-5):
articles x reaction labels with optimizer plan selection.

    PYTHONPATH=src python examples/biodex_join.py
"""
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

left, right, world, oracle, proxy, embedder = synth.make_join_world(
    100, 200, labels_per_left=1, sim_correlation=0.0, seed=2)
sess = Session(oracle=oracle, proxy=proxy, embedder=embedder, sample_size=1500)
articles = SemFrame(left, sess)

matched = articles.sem_join(right, "the {abstract} reports the {reaction:right}",
                            recall_target=0.85, precision_target=0.85, delta=0.2)
st = articles.last_stats()
print(f"pairs matched: {len(matched)}")
print(f"plan chosen:   {st['plan']}  (costs: {st['plan_costs']})")
print(f"LM calls:      {st['lm_calls']}  vs gold {100 * 200}"
      f"  -> {100 * 200 / max(st['lm_calls'], 1):.0f}x fewer")
