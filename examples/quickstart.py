"""Quickstart: semantic operators in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

# a synthetic corpus with known ground truth (no API keys / weights needed)
records, world, oracle, proxy, embedder = synth.make_filter_world(
    400, positive_rate=0.4, proxy_alpha=2.5, seed=0)
sess = Session(oracle=oracle, proxy=proxy, embedder=embedder, sample_size=150)
claims = SemFrame(records, sess)

# gold algorithm: one oracle call per tuple
supported = claims.sem_filter("the {claim} is supported")
print(f"gold filter: {len(supported)}/{len(claims)} pass, "
      f"{claims.last_stats()['oracle_calls']} oracle calls")

# optimized: proxy cascade with accuracy guarantees (Algorithm 1)
fast = claims.sem_filter("the {claim} is supported",
                         recall_target=0.9, precision_target=0.9, delta=0.2)
st = claims.last_stats()
print(f"optimized:   {len(fast)}/{len(claims)} pass, "
      f"{st['oracle_calls']} oracle calls "
      f"(tau+={st['tau_plus']:.2f}, tau-={st['tau_minus']:.2f})")

# row-wise projection + vector search
queries = claims.sem_map("write a search query for {claim}", out_column="query")
idx = claims.sem_index("claim")
hits = claims.sem_search("claim", "claim text 42", k=3, index=idx)
print("search:", [t["id"] for t in hits.records])

# lazy pipelines: build a logical plan, let the optimizer reorder/fuse/dedup,
# then execute in one batched pass (see examples/lazy_pipeline.py for more)
lazy = (claims.lazy()
        .sem_map("write a search query for {claim}", out_column="query")
        .sem_filter("the {claim} is supported"))
print(lazy.explain())
out = lazy.collect()
print(f"lazy collect: {len(out)} rows, rewrites: {[r.rule for r in lazy.last_rewrites]}")
