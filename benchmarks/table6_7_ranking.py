"""Tables 6/7 analogue: ranking quality + LM-call complexity across top-k
algorithms, on a synthetic HellaSwag-bench (objective scalar ground truth)."""
import time

import numpy as np

from benchmarks._util import emit, ndcg_at_k
from repro.core.backends import synth
from repro.core.backends.base import CountedModel
from repro.core.operators.topk import (sem_topk_heap, sem_topk_quadratic,
                                       sem_topk_quickselect)

N, K = 150, 10


def run() -> None:
    records, world, model, emb, piv = synth.make_rank_world(N, compare_noise=0.05, seed=4)
    model = CountedModel(model, "oracle")
    rel = {i: world.rank_value[records[i]["id"]] for i in range(N)}

    # search baseline: embedding similarity only (0 LM calls)
    order = list(np.argsort(-piv))
    emit("table6/search", 0.0, ndcg10=round(ndcg_at_k(order, rel, K), 3), lm_calls=0)

    for name, fn, kw in (
        ("quadratic", sem_topk_quadratic, {}),
        ("heap", sem_topk_heap, {}),
        ("quickselect", sem_topk_quickselect, {"seed": 0}),
        ("lotus_pivot_opt", sem_topk_quickselect, {"seed": 0, "pivot_scores": piv}),
    ):
        t0 = time.monotonic()
        idx, st = fn(records, "{abstract} highest accuracy", K, model, **kw)
        dt = time.monotonic() - t0
        emit(f"table7/{name}", 1e6 * dt / max(st["compare_calls"], 1),
             ndcg10=round(ndcg_at_k(list(idx), rel, K), 3),
             lm_calls=st["compare_calls"], et_s=round(dt, 3))
