"""Retrieval-layer benchmark: exact scan vs IVF-pruned ANN at a fixed
recall target, plus the degenerate exactness contract and cross-session
index sharing.

Three sections:

  * 50k-row clustered corpus, 64 queries: IVF (cost-model nprobe at
    recall_target=0.95, per-query probing) must score >= 5x fewer corpus
    vectors than exact while holding recall@10 >= 0.95 vs the exact top-10;
  * degenerate setting (nprobe = all clusters, 2k rows): top-k must be
    *identical* to exact;
  * two concurrent gateway sessions over one corpus: IndexRegistry metrics
    must show exactly one index build.

Writes ``BENCH_index.json``.

    PYTHONPATH=src python -m benchmarks.index_bench
"""
import json
import time

import numpy as np

from benchmarks._util import emit
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.index import IVFIndex, VectorIndex, retrieval_costs

N_CORPUS = 50_000
N_QUERIES = 64
K = 10
RECALL_TARGET = 0.95
MIN_PRUNE_FACTOR = 5.0


def _clustered(n, d=32, n_centers=64, noise=0.18, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    lab = rng.integers(n_centers, size=n)
    x = centers[lab] + noise * rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return np.asarray(x, np.float32), centers


def run() -> None:
    corpus, centers = _clustered(N_CORPUS)
    rng = np.random.default_rng(99)
    queries = centers[rng.integers(len(centers), size=N_QUERIES)] \
        + 0.18 * rng.normal(size=(N_QUERIES, 32))
    queries = np.asarray(queries, np.float32)

    # -- exact baseline ----------------------------------------------------
    exact = VectorIndex(corpus)
    t0 = time.monotonic()
    _, exact_idx = exact.search(queries, K)
    t_exact = time.monotonic() - t0
    exact_scored = exact.last_stats["scored_vectors"]
    emit("index/exact", 1e6 * t_exact / N_QUERIES,
         scored_vectors=exact_scored, wall_s=round(t_exact, 3))

    # -- IVF at the recall target (cost-model nprobe, per-query probing) ---
    costs = retrieval_costs(N_CORPUS, N_QUERIES, recall_target=RECALL_TARGET,
                            shared=True)  # serving regime: registry-amortized
    t0 = time.monotonic()
    ivf = IVFIndex(corpus, recall_target=RECALL_TARGET, block_q=1, seed=7)
    t_build = time.monotonic() - t0
    t0 = time.monotonic()
    _, ivf_idx = ivf.search(queries, K)
    t_ivf = time.monotonic() - t0
    st = ivf.last_stats
    recall = float(np.mean([len(set(exact_idx[i]) & set(ivf_idx[i])) / K
                            for i in range(N_QUERIES)]))
    prune = exact_scored / max(st["scored_vectors"], 1)
    emit("index/ivf", 1e6 * t_ivf / N_QUERIES,
         scored_vectors=st["scored_vectors"],
         prune_factor=round(prune, 1), recall_at_10=round(recall, 4),
         nprobe=st["nprobe"], n_clusters=st["n_clusters"],
         build_s=round(t_build, 3), wall_s=round(t_ivf, 3),
         est_cost_exact=int(costs["exact"]), est_cost_ivf=int(costs["ivf"]))

    # -- degenerate: nprobe = all clusters -> identical to exact -----------
    small, _ = _clustered(2000, seed=3)
    sq = np.asarray(small[::311][:8] + 0.01, np.float32)
    _, de = VectorIndex(small).search(sq, K)
    deg = IVFIndex(small, n_clusters=32, seed=3)
    _, dv = deg.search(sq, K, nprobe=deg.n_clusters)
    degenerate_identical = bool(np.array_equal(de, dv))
    emit("index/degenerate", 0.0, identical_topk=degenerate_identical)

    # -- cross-session sharing: 2 concurrent sessions, 1 build -------------
    from repro.serve import Gateway
    records, world, *_ = synth.make_filter_world(300, seed=21)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    sf = SemFrame(records, sess)
    with Gateway(sess, max_inflight=2) as gw:
        handles = [gw.submit(sf.lazy().sem_search("claim", f"claim text {i}",
                                                  k=3), tenant=f"t{i}")
                   for i in range(2)]
        for h in handles:
            h.result(timeout=300)
        snap = gw.snapshot()
    emit("index/registry", 0.0, index_builds=snap["index_builds"],
         index_hits=snap["index_hits"])

    with open("BENCH_index.json", "w") as fh:
        json.dump({
            "corpus": N_CORPUS, "queries": N_QUERIES, "k": K,
            "recall_target": RECALL_TARGET,
            "exact": {"scored_vectors": exact_scored,
                      "wall_s": round(t_exact, 4)},
            "ivf": {**st, "recall_at_10": round(recall, 4),
                    "prune_factor": round(prune, 2),
                    "build_s": round(t_build, 4), "wall_s": round(t_ivf, 4)},
            "degenerate_identical": degenerate_identical,
            "registry": {"index_builds": snap["index_builds"],
                         "index_hits": snap["index_hits"]},
        }, fh, indent=2)

    assert recall >= RECALL_TARGET, \
        f"IVF recall@{K} {recall:.3f} below target {RECALL_TARGET}"
    assert prune >= MIN_PRUNE_FACTOR, \
        f"IVF scored only {prune:.1f}x fewer vectors (need >={MIN_PRUNE_FACTOR}x)"
    assert degenerate_identical, "nprobe=all did not reproduce exact top-k"
    assert snap["index_builds"] == 1, \
        f"expected exactly one shared index build, got {snap['index_builds']}"


if __name__ == "__main__":
    run()
