"""Fig 8 analogue: sem_group_by classification accuracy vs oracle cost."""
import numpy as np

from benchmarks._util import emit
from repro.core.backends import synth
from repro.core.frame import Session
from repro.core.operators.groupby import sem_group_by_cascade, sem_group_by_gold

N, C = 400, 5


def run() -> None:
    records, world, model, emb = synth.make_topic_world(N, C, seed=7)
    sess = Session(oracle=model, embedder=emb)
    gold = sem_group_by_gold(records, "topic of {paper}", C, sess.oracle,
                             sess.embedder, seed=0)
    emit("fig8/oracle_only", float("nan"), accuracy=1.0, oracle_calls=N)

    for tgt in (0.75, 0.85, 0.95):
        opt = sem_group_by_cascade(records, "topic of {paper}", C, sess.oracle,
                                   sess.embedder, accuracy_target=tgt, delta=0.2,
                                   sample_size=150, seed=0)
        acc = float(np.mean(gold.assignment == opt.assignment))
        emit(f"fig8/cascade_t{tgt}", float("nan"), accuracy=round(acc, 3),
             oracle_calls=opt.stats["oracle_classified"],
             proxy_assigned=opt.stats["proxy_classified"])
