"""Tracing-overhead benchmark: a 50k-row pipeline traced vs untraced.

The tentpole's overhead contract: span tracing must cost <5% wall time when
on (spans are per plan stage / operator / batch, never per row) and be
record-identical in both modes.  Runs the same filter pipeline interleaved
untraced/traced (min over repeats, so OS noise doesn't land on one mode),
then runs ``explain_analyze`` over a filter -> join -> topk pipeline and
checks the StatsStore picked up observed selectivities.  Writes
``BENCH_trace.json`` plus the exported span artifacts
(``BENCH_trace_spans.jsonl``, ``BENCH_trace_chrome.json``).

    PYTHONPATH=src python -m benchmarks.trace_bench
"""
import json
import time

from benchmarks._util import emit
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.obs import StatsStore, Tracer, explain_analyze
from repro.obs import trace as T

N_ROWS = 50_000
REPEATS = 3
MAX_OVERHEAD = 0.05          # the tentpole's <5% contract
ABS_SLACK_S = 0.1            # absolute jitter floor for short runs


def _session(world):
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world), sample_size=40)


def _run(records, world, tracer):
    """One cold-session pipeline run; returns (wall_s, records)."""
    lz = (SemFrame(records, _session(world)).lazy()
          .sem_filter("the {claim} is rare"))
    t0 = time.monotonic()
    if tracer is None:
        out = lz.collect()
    else:
        with T.activate(tracer):
            out = lz.collect()
    return time.monotonic() - t0, out.records


def run() -> None:
    records, world, *_ = synth.make_filter_world(N_ROWS, seed=5)
    synth.add_phrase_predicate(world, records, "is rare", 0.3, seed=5)

    _run(records, world, None)                   # warm-up (JAX + samplers)

    t_off, t_on = [], []
    rows_off = rows_on = None
    tracer = Tracer()
    for _ in range(REPEATS):                     # interleave the modes
        dt, rows_off = _run(records, world, None)
        t_off.append(dt)
        dt, rows_on = _run(records, world, tracer)
        t_on.append(dt)
    t_untraced, t_traced = min(t_off), min(t_on)
    overhead = t_traced / t_untraced - 1.0

    assert rows_on == rows_off, "tracing changed the result set"
    spans = tracer.spans()
    kinds = {s.kind for s in spans}
    assert spans, "traced run recorded no spans"
    assert {"plan_stage", "operator"} <= kinds, f"thin span tree: {kinds}"
    assert t_traced <= t_untraced * (1 + MAX_OVERHEAD) + ABS_SLACK_S, (
        f"tracing overhead {overhead:.1%} exceeds {MAX_OVERHEAD:.0%} "
        f"({t_traced:.3f}s vs {t_untraced:.3f}s)")

    n_jsonl = tracer.export_jsonl("BENCH_trace_spans.jsonl")
    tracer.export_chrome("BENCH_trace_chrome.json")
    with open("BENCH_trace_chrome.json") as fh:
        chrome = json.load(fh)                   # must round-trip as JSON
    assert len(chrome["traceEvents"]) == n_jsonl > 0

    emit("trace/untraced", 1e6 * t_untraced / N_ROWS,
         wall_s=round(t_untraced, 3), rows=len(rows_off))
    emit("trace/traced", 1e6 * t_traced / N_ROWS,
         wall_s=round(t_traced, 3), overhead_pct=round(100 * overhead, 2),
         spans=len(spans))

    # -- explain_analyze + stats store over a multi-operator pipeline -----
    left, right, jworld, *_ = synth.make_join_world(40, 8, seed=11)
    synth.add_phrase_predicate(jworld, left, "is checkable", 0.4, seed=11)
    lz = (SemFrame(left, _session(jworld)).lazy()
          .sem_filter("the {abstract} is checkable")
          .sem_join(right, "the {abstract} reports the {reaction:right}")
          .sem_topk("most accurate {abstract}", 5))
    store = StatsStore()
    t0 = time.monotonic()
    rep = explain_analyze(lz, stats_store=store)
    t_ea = time.monotonic() - t0
    print(rep.render(), flush=True)
    observed = [r for r in rep.nodes if r.observed is not None]
    assert observed, "explain_analyze carried no observations"
    sels = [e["selectivity"] for e in store.snapshot()
            if e["selectivity"] is not None]
    assert sels, "stats store learned no selectivities"
    emit("trace/explain_analyze", 1e6 * t_ea, nodes=len(rep.nodes),
         observed_nodes=len(observed), drifted=len(rep.drifted),
         stats_entries=len(store))

    with open("BENCH_trace.json", "w") as fh:
        json.dump({
            "n_rows": N_ROWS,
            "wall_untraced_s": round(t_untraced, 4),
            "wall_traced_s": round(t_traced, 4),
            "overhead_pct": round(100 * overhead, 2),
            "max_overhead_pct": 100 * MAX_OVERHEAD,
            "identical_records": True,
            "spans": len(spans),
            "span_kinds": sorted(kinds),
            "explain_analyze": {
                "nodes": len(rep.nodes),
                "observed_nodes": len(observed),
                "drifted_nodes": len(rep.drifted),
                "stats_entries": len(store),
                "observed_selectivities": [round(s, 4) for s in sels],
            },
        }, fh, indent=2)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
