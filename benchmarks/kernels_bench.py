"""Kernel-path microbenchmarks (jnp reference path on CPU; the Pallas
kernels target TPU and are correctness-validated in interpret mode)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit
from repro.kernels import ops


def run() -> None:
    q = np.random.default_rng(0).normal(size=(512, 256)).astype(np.float32)
    c = np.random.default_rng(1).normal(size=(4096, 256)).astype(np.float32)
    ops.similarity(q[:8], c[:8])  # warmup
    t0 = time.monotonic()
    for _ in range(5):
        ops.similarity(q, c)
    dt = (time.monotonic() - t0) / 5
    emit("kernels/similarity_512x4096", 1e6 * dt, gflops=round(2 * 512 * 4096 * 256 / dt / 1e9, 1))

    # IVF cluster scans: fp32 tiles vs int8 tiles + fused dequantize — the
    # quantized scan streams (d+4)/(4d) of the bytes through the hot loop
    from repro.index.quant import bytes_per_vector, quantize_tiles
    rng = np.random.default_rng(2)
    kc, L, d, nq, nprobe = 64, 256, 64, 64, 8
    store = rng.normal(size=(kc, L, d)).astype(np.float32)
    mask = np.ones((kc, L), np.float32)
    cents = rng.normal(size=(kc, d)).astype(np.float32)
    queries = rng.normal(size=(nq, d)).astype(np.float32)
    store_q, scales = quantize_tiles(store)
    ops.ivf_search(queries[:8], cents, store, mask, nprobe=nprobe)  # warmup
    t0 = time.monotonic()
    for _ in range(5):
        ops.ivf_search(queries, cents, store, mask, nprobe=nprobe)
    dt = (time.monotonic() - t0) / 5
    emit(f"kernels/ivf_search_{kc}x{L}x{d}", 1e6 * dt,
         bytes_per_vec=bytes_per_vector(d, "none"))
    ops.ivf_search_q(queries[:8], cents, store_q, scales, mask, nprobe=nprobe)
    t0 = time.monotonic()
    for _ in range(5):
        ops.ivf_search_q(queries, cents, store_q, scales, mask, nprobe=nprobe)
    dtq = (time.monotonic() - t0) / 5
    emit(f"kernels/ivf_search_q_{kc}x{L}x{d}", 1e6 * dtq,
         bytes_per_vec=bytes_per_vector(d, "int8"))

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    qq = jax.random.normal(ks[0], (2, 512, 8, 64), jnp.float32)
    kk = jax.random.normal(ks[1], (2, 512, 2, 64), jnp.float32)
    vv = jax.random.normal(ks[2], (2, 512, 2, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="ref"))
    f(qq, kk, vv).block_until_ready()
    t0 = time.monotonic()
    for _ in range(5):
        f(qq, kk, vv).block_until_ready()
    dt = (time.monotonic() - t0) / 5
    emit("kernels/attention_ref_2x512", 1e6 * dt)
