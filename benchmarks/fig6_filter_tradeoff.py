"""Fig 6 analogue: accuracy/cost trade-off of the filter cascade as targets
vary, against proxy-only and oracle-only endpoints."""
import numpy as np

from benchmarks._util import emit, set_metrics
from repro.core.backends import synth
from repro.core.frame import Session
from repro.core.operators.filter import sem_filter_cascade, sem_filter_gold

N = 600


def run() -> None:
    records, world, oracle, proxy, _ = synth.make_filter_world(N, proxy_alpha=1.8, seed=5)
    sess = Session(oracle=oracle, proxy=proxy)
    gold, _ = sem_filter_gold(records, "{claim} holds", sess.oracle)
    gold_ids = set(np.flatnonzero(gold).tolist())

    passed, _ = sess.proxy.predicate([f"does it hold? {t['claim']}" for t in records])
    r, p = set_metrics(set(np.flatnonzero(passed).tolist()), gold_ids)
    emit("fig6/proxy_only", float("nan"), recall=round(r, 3), precision=round(p, 3),
         oracle_calls=0)
    emit("fig6/oracle_only", float("nan"), recall=1.0, precision=1.0, oracle_calls=N)

    for tgt in (0.7, 0.8, 0.9, 0.95):
        mask, st = sem_filter_cascade(records, "{claim} holds", sess.oracle, sess.proxy,
                                      recall_target=tgt, precision_target=tgt,
                                      delta=0.2, sample_size=100, seed=6)
        r, p = set_metrics(set(np.flatnonzero(mask).tolist()), gold_ids)
        emit(f"fig6/cascade_t{tgt}", float("nan"), recall=round(r, 3),
             precision=round(p, 3), oracle_calls=st["oracle_calls"])
