"""Fast-join benchmark: blocking + block prompts + transitivity inference.

A 100k-pair entity-resolution join (500 mentions x 200 entity records, the
equivalence regime where verdict inference pays).  Three paths over the
same world:

  * **gold** — the O(n1*n2) nested-loop judge (the reference truth);
  * **cascade** — the historical pairwise cascade (proxy thresholds, every
    mid-region pair judged one prompt per pair);
  * **block** — IVF blocking -> multi-pair block prompts -> transitivity
    pruning (``sem_join(strategy="block")``'s operator).

Asserts the PR's two acceptance properties:

  * the block path spends **>=10x fewer oracle prompts** than the pairwise
    cascade while holding recall >= the 0.9 target against gold;
  * ``strategy="cascade"`` through the frame API stays **record-identical**
    to the historical default dispatch.

Writes ``BENCH_join.json``.

    PYTHONPATH=src python -m benchmarks.join_bench
"""
import json
import time

import numpy as np

from benchmarks._util import emit, set_metrics
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.core.operators.join import (sem_join_block, sem_join_cascade,
                                       sem_join_gold)

N_LEFT, N_RIGHT, N_CLASSES = 500, 200, 40
RECALL_TARGET = 0.9
MIN_SPEEDUP = 10.0
JOIN_LX = "the {mention} refers to the same entity as {entity:right}"


class _Counting:
    """Counts every prompt a path sends to the oracle — the unit the >=10x
    claim is stated in (one block prompt of B pairs = one prompt)."""

    def __init__(self, model):
        self._m = model
        self.prompts = 0

    def predicate(self, prompts):
        self.prompts += len(prompts)
        return self._m.predicate(prompts)

    def generate(self, prompts):
        self.prompts += len(prompts)
        return self._m.generate(prompts)


def _pairs(mask):
    return {(int(i), int(j)) for i, j in zip(*np.nonzero(mask))}


def run() -> None:
    left, right, world, oracle, _, emb = synth.make_entity_world(
        N_LEFT, N_RIGHT, N_CLASSES, sim_correlation=0.75, seed=11)
    n_pairs = N_LEFT * N_RIGHT
    assert n_pairs >= 100_000

    # -- gold reference (bill == n_pairs by construction) -------------------
    gold_oracle = _Counting(oracle)
    t0 = time.monotonic()
    gold_mask, _ = sem_join_gold(left, right, JOIN_LX, gold_oracle)
    t_gold = time.monotonic() - t0
    want = _pairs(gold_mask)
    emit("join/gold", 1e6 * t_gold / n_pairs, pairs=n_pairs,
         oracle_prompts=gold_oracle.prompts, matches=len(want))

    # -- pairwise cascade ---------------------------------------------------
    cas_oracle = _Counting(oracle)
    t0 = time.monotonic()
    cas_mask, cas_st = sem_join_cascade(
        left, right, JOIN_LX, cas_oracle, emb,
        recall_target=RECALL_TARGET, precision_target=0.9,
        sample_size=400, seed=7)
    t_cas = time.monotonic() - t0
    r_cas, p_cas = set_metrics(_pairs(cas_mask), want)
    emit("join/cascade", 1e6 * t_cas / n_pairs,
         oracle_prompts=cas_oracle.prompts, recall=round(r_cas, 3),
         precision=round(p_cas, 3), plan=cas_st["plan"])

    # -- block path ---------------------------------------------------------
    blk_oracle = _Counting(oracle)
    t0 = time.monotonic()
    blk_mask, blk_st = sem_join_block(
        left, right, JOIN_LX, blk_oracle, emb,
        recall_target=RECALL_TARGET, precision_target=0.9,
        sample_size=400, probe_size=64, seed=7)
    t_blk = time.monotonic() - t0
    r_blk, p_blk = set_metrics(_pairs(blk_mask), want)
    speedup = cas_oracle.prompts / max(blk_oracle.prompts, 1)
    emit("join/block", 1e6 * t_blk / n_pairs,
         oracle_prompts=blk_oracle.prompts, recall=round(r_blk, 3),
         precision=round(p_blk, 3), prompt_speedup=round(speedup, 1),
         candidate_pairs=blk_st["candidate_pairs"],
         block_prompts=blk_st["block_prompts"],
         pruned=blk_st["pairs_pruned_by_inference"],
         match_classes=blk_st["match_classes"])

    # -- record identity: strategy="cascade" == historical dispatch ---------
    il, ir, iworld, *_ = synth.make_entity_world(40, 24, 8, seed=3)
    outs = []
    for strategy in (None, "cascade"):
        sess = Session(oracle=synth.SimulatedModel(iworld, "oracle"),
                       embedder=synth.SimulatedEmbedder(iworld),
                       sample_size=60, seed=0)
        out = SemFrame(il, sess).sem_join(
            ir, JOIN_LX, recall_target=RECALL_TARGET, precision_target=0.9,
            strategy=strategy)
        outs.append(out.records)
    identical = outs[0] == outs[1]
    emit("join/cascade_identity", 0.0, identical_records=identical)

    with open("BENCH_join.json", "w") as fh:
        json.dump({
            "pairs": n_pairs, "matches": len(want),
            "recall_target": RECALL_TARGET,
            "gold_prompts": gold_oracle.prompts,
            "cascade": {"prompts": cas_oracle.prompts,
                        "recall": round(r_cas, 4),
                        "precision": round(p_cas, 4),
                        "wall_s": round(t_cas, 3), "plan": cas_st["plan"]},
            "block": {"prompts": blk_oracle.prompts,
                      "recall": round(r_blk, 4),
                      "precision": round(p_blk, 4),
                      "wall_s": round(t_blk, 3),
                      "candidate_pairs": blk_st["candidate_pairs"],
                      "coverage_est": blk_st["coverage_est"],
                      "block_prompts": blk_st["block_prompts"],
                      "block_fallbacks": blk_st["block_fallbacks"],
                      "pairs_pruned_by_inference":
                          blk_st["pairs_pruned_by_inference"],
                      "match_classes": blk_st["match_classes"],
                      "block_agreement": blk_st["block_agreement"]},
            "prompt_speedup_vs_cascade": round(speedup, 2),
            "cascade_identity": identical,
        }, fh, indent=2)

    assert r_blk >= RECALL_TARGET, (
        f"block join recall {r_blk:.3f} below target {RECALL_TARGET}")
    assert speedup >= MIN_SPEEDUP, (
        f"block join spent {blk_oracle.prompts} prompts vs cascade "
        f"{cas_oracle.prompts}: {speedup:.1f}x < {MIN_SPEEDUP}x")
    assert identical, (
        "strategy='cascade' changed records vs the default dispatch")


if __name__ == "__main__":
    run()
