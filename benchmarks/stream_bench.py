"""Streaming-layer benchmark: incremental index maintenance + continuous
queries, against the frozen-corpus baseline that rebuilds everything.

Three sections:

  * **delta indexing** — a 50k-row corpus in a ``CorpusTable`` gets a 10%
    append.  The versioned ``IndexRegistry`` path must re-embed/index ONLY
    the 5k delta rows (>= 5x fewer embed calls than the fingerprint-keyed
    rebuild, which re-embeds all 55k) while the delta-merged IVF search
    holds recall@10 >= 0.95 vs an exact scan of the appended corpus;
  * **drift retrain** — a second append pushes the delta buffer past the
    spill threshold: the drift detector folds it into a retrained quantizer
    and recall holds with an empty buffer;
  * **continuous query** — a pipeline subscribed through the gateway: after
    an append, ONLY the delta rows reach the oracle (the shared semantic
    cache covers every already-judged row) and the emitted records are
    identical to a from-scratch run of the same pipeline.

Writes ``BENCH_stream.json``.

    PYTHONPATH=src python -m benchmarks.stream_bench
"""
import json
import time

import numpy as np

from benchmarks._util import emit
from repro.core.backends import synth
from repro.core.backends.testing import CountingBackend
from repro.core.frame import SemFrame, Session
from repro.index import VectorIndex, build_index
from repro.index.backend import default_n_clusters, nprobe_for_recall
from repro.serve import Gateway, IndexRegistry
from repro.stream import CorpusTable

N_CORPUS = 50_000
N_DELTA = 5_000            # the 10% append
N_QUERIES = 64
K = 10
RECALL_TARGET = 0.95
MIN_EMBED_SAVINGS = 5.0


def _clustered(n, d=32, n_centers=64, noise=0.18, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    lab = rng.integers(n_centers, size=n)
    x = centers[lab] + noise * rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return np.asarray(x, np.float32), centers


class _LookupEmbedder:
    """texts are integer strings indexing a fixed vector matrix — embeds
    stay cheap so the benchmark measures maintenance, not hashing."""

    index_key = "stream-bench-embedder"

    def __init__(self, vectors):
        self.vectors = vectors
        self.calls = 0

    @property
    def dim(self):
        return self.vectors.shape[1]

    def embed(self, texts):
        self.calls += len(texts)
        return self.vectors[[int(t) for t in texts]]


def run() -> None:
    all_vecs, centers = _clustered(N_CORPUS + N_DELTA + N_DELTA // 2)
    rng = np.random.default_rng(99)
    queries = centers[rng.integers(len(centers), size=N_QUERIES)] \
        + 0.18 * rng.normal(size=(N_QUERIES, 32))
    queries = np.asarray(queries, np.float32)

    kc = default_n_clusters(N_CORPUS)
    nprobe = nprobe_for_recall(kc, RECALL_TARGET)
    ivf_kw = dict(kind="ivf", nprobe=nprobe, block_q=1, seed=7,
                  retrain="sync")     # deterministic wall-clock + results

    emb = _LookupEmbedder(all_vecs)
    table = CorpusTable([{"t": str(i)} for i in range(N_CORPUS)])
    reg = IndexRegistry()

    def builder(records):
        return build_index(emb.embed([r["t"] for r in records]), **ivf_kw)

    def updater(index, added):
        index.add(emb.embed([r["t"] for r in added]))

    # -- base build (v1) ---------------------------------------------------
    t0 = time.monotonic()
    reg.get_or_update(table, emb, kind="ivf", params={"nprobe": nprobe},
                      builder=builder, updater=updater)
    t_build = time.monotonic() - t0
    base_embeds = emb.calls
    emit("stream/base_build", 1e6 * t_build, embed_calls=base_embeds,
         n_clusters=kc, nprobe=nprobe, wall_s=round(t_build, 3))

    # -- the 10% append: delta path vs rebuild -----------------------------
    table.append([{"t": str(i)} for i in range(N_CORPUS, N_CORPUS + N_DELTA)])
    t0 = time.monotonic()
    idx = reg.get_or_update(table, emb, kind="ivf", params={"nprobe": nprobe},
                            builder=builder, updater=updater)
    t_delta = time.monotonic() - t0
    delta_embeds = emb.calls - base_embeds

    # the frozen-corpus baseline: content fingerprint changed, re-embed +
    # rebuild everything (what every pre-stream version of this repo did)
    rebuild_emb = _LookupEmbedder(all_vecs)
    t0 = time.monotonic()
    build_index(rebuild_emb.embed([r["t"] for r in table.snapshot()]), **ivf_kw)
    t_rebuild = time.monotonic() - t0
    rebuild_embeds = rebuild_emb.calls
    savings = rebuild_embeds / max(delta_embeds, 1)

    n_now = N_CORPUS + N_DELTA
    exact = VectorIndex(all_vecs[:n_now])
    _, exact_idx = exact.search(queries, K)
    t0 = time.monotonic()
    _, ivf_idx = idx.search(queries, K)
    t_search = time.monotonic() - t0
    st = dict(idx.last_stats)
    recall = float(np.mean([len(set(exact_idx[i]) & set(ivf_idx[i])) / K
                            for i in range(N_QUERIES)]))
    emit("stream/delta_append", 1e6 * t_delta,
         delta_embed_calls=delta_embeds, rebuild_embed_calls=rebuild_embeds,
         embed_savings=round(savings, 1), recall_at_10=round(recall, 4),
         delta_rows=st["delta_rows"], scored_vectors=st["scored_vectors"],
         search_us_per_q=round(1e6 * t_search / N_QUERIES, 1),
         delta_wall_s=round(t_delta, 3), rebuild_wall_s=round(t_rebuild, 3))

    # -- drift detector: spill past threshold -> retrain -------------------
    table.append([{"t": str(i)} for i in range(n_now, len(all_vecs))])
    t0 = time.monotonic()
    idx = reg.get_or_update(table, emb, kind="ivf", params={"nprobe": nprobe},
                            builder=builder, updater=updater)
    t_retrain = time.monotonic() - t0
    exact_all = VectorIndex(all_vecs)
    _, exact_idx2 = exact_all.search(queries, K)
    _, ivf_idx2 = idx.search(queries, K)
    recall2 = float(np.mean([len(set(exact_idx2[i]) & set(ivf_idx2[i])) / K
                             for i in range(N_QUERIES)]))
    emit("stream/drift_retrain", 1e6 * t_retrain, retrains=idx.retrains,
         delta_rows_left=idx.delta_rows, recall_at_10=round(recall2, 4),
         wall_s=round(t_retrain, 3))
    reg_metrics = reg.metrics()

    # -- continuous query through the gateway ------------------------------
    n_rows, n_new = 300, 30
    records, world, *_ = synth.make_filter_world(n_rows, seed=21)
    ctable = CorpusTable(records)
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    sess = Session(oracle=backend, embedder=synth.SimulatedEmbedder(world))
    rng = np.random.default_rng(5)
    new_rows = []
    for i in range(n_rows, n_rows + n_new):
        rid = f"claim{i}"
        world.filter_truth[rid] = bool(rng.random() < 0.4)
        new_rows.append({"id": rid, "claim": f"claim text {i} {synth.tag(rid)}"})

    t0 = time.monotonic()
    with Gateway(sess, max_inflight=2, max_batch=512) as gw:
        sub = gw.subscribe(ctable.lazy(sess)
                           .sem_filter("the {claim} is supported"))
        em0 = sub.poll(timeout=300)
        initial_prompts = backend.n_prompts
        ctable.append(new_rows)
        em1 = sub.poll(timeout=300)
        delta_prompts = backend.n_prompts - initial_prompts
        snap = gw.snapshot()
    t_cq = time.monotonic() - t0

    fresh_sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                         embedder=synth.SimulatedEmbedder(world))
    fresh = SemFrame(ctable.snapshot(), fresh_sess).sem_filter(
        "the {claim} is supported")
    identical = em1.records == fresh.records
    new_tags = {synth.tag(f"claim{i}") for i in range(n_rows, n_rows + n_new)}
    delta_only = all(any(t in p for t in new_tags)
                     for b in backend.batches[1:] for p in b)
    emit("stream/continuous", 1e6 * t_cq,
         initial_prompts=initial_prompts, delta_prompts=delta_prompts,
         delta_only_oracle=delta_only, identical_records=identical,
         emissions=snap["emissions"], added_rows=len(em1.added),
         wall_s=round(t_cq, 3))

    with open("BENCH_stream.json", "w") as fh:
        json.dump({
            "corpus": N_CORPUS, "delta": N_DELTA, "queries": N_QUERIES,
            "k": K, "recall_target": RECALL_TARGET,
            "delta_append": {
                "delta_embed_calls": delta_embeds,
                "rebuild_embed_calls": rebuild_embeds,
                "embed_savings": round(savings, 2),
                "recall_at_10": round(recall, 4),
                "delta_wall_s": round(t_delta, 4),
                "rebuild_wall_s": round(t_rebuild, 4),
                "search_stats": {k_: v for k_, v in st.items()},
            },
            "drift_retrain": {"retrains": idx.retrains,
                              "delta_rows_left": idx.delta_rows,
                              "recall_at_10": round(recall2, 4),
                              "wall_s": round(t_retrain, 4)},
            "registry": reg_metrics,
            "continuous": {"rows": n_rows, "appended": n_new,
                           "initial_prompts": initial_prompts,
                           "delta_prompts": delta_prompts,
                           "delta_only_oracle": delta_only,
                           "identical_records": identical,
                           "emissions": snap["emissions"]},
        }, fh, indent=2)

    assert savings >= MIN_EMBED_SAVINGS, \
        f"delta path embedded too much: {savings:.1f}x < {MIN_EMBED_SAVINGS}x"
    assert recall >= RECALL_TARGET, \
        f"delta-merged recall@{K} {recall:.3f} < {RECALL_TARGET}"
    assert recall2 >= RECALL_TARGET, \
        f"post-retrain recall@{K} {recall2:.3f} < {RECALL_TARGET}"
    assert reg_metrics["index_builds"] == 1 and reg_metrics["index_updates"] == 2
    assert em0.error is None and em1.error is None
    assert delta_prompts == n_new, \
        f"continuous query paid {delta_prompts} oracle prompts for {n_new} new rows"
    assert delta_only, "an already-judged row reached the oracle after the append"
    assert identical, "continuous emission diverged from a from-scratch run"


if __name__ == "__main__":
    run()
