"""Serving-gateway benchmark: N concurrent sessions vs serial execution.

Each session is a filter -> join pipeline over the same corpus; sessions
share predicate templates (the many-users-one-workload regime), so the
gateway's cross-query micro-batching + shared semantic cache should answer
most prompts once.  Reports per-mode throughput, p50/p95 latency, total
oracle prompts, and the cross-query cache hit rate; verifies the concurrent
results are record-identical to the serial runs.  Writes ``BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.serve_bench
"""
import json
import time

from benchmarks._util import emit
from repro.core.backends.testing import CountingBackend
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

N_SESSIONS = 8
N_LEFT, N_RIGHT = 60, 10
# two templates across 8 sessions -> every template shared by 4 sessions
FILTERS = ["the {abstract} is checkable", "the {abstract} is recent"]
JOIN = "the {abstract} reports the {reaction:right}"


def _world(seed=0):
    left, right, world, *_ = synth.make_join_world(N_LEFT, N_RIGHT, seed=seed)
    synth.add_phrase_predicate(world, left, "is checkable", 0.3, seed=seed)
    synth.add_phrase_predicate(world, left, "is recent", 0.4, seed=seed)
    return left, right, world


def _session(world, backend):
    return Session(oracle=backend, embedder=synth.SimulatedEmbedder(world),
                   sample_size=40)


def _pipeline(left, right, session, i):
    return (SemFrame(left, session).lazy()
            .sem_filter(FILTERS[i % len(FILTERS)])
            .sem_join(right, JOIN))


def run() -> None:
    from repro.serve import Gateway

    left, right, world = _world()

    # -- serial: each session alone, fresh per-query cache ----------------
    serial_backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    serial_rows, serial_lat = [], []
    t0 = time.monotonic()
    for i in range(N_SESSIONS):
        t1 = time.monotonic()
        out = _pipeline(left, right, _session(world, serial_backend), i).collect()
        serial_lat.append(time.monotonic() - t1)
        serial_rows.append(out.records)
    t_serial = time.monotonic() - t0
    serial_lat.sort()
    emit("serve/serial", 1e6 * t_serial / N_SESSIONS,
         oracle_prompts=serial_backend.n_prompts,
         throughput_rps=round(N_SESSIONS / t_serial, 2),
         p95_latency_s=round(serial_lat[int(0.95 * (N_SESSIONS - 1))], 4),
         wall_s=round(t_serial, 3))

    # -- concurrent: all sessions through the gateway ---------------------
    gw_backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    t0 = time.monotonic()
    with Gateway(_session(world, gw_backend), max_inflight=4,
                 window_s=0.005, max_batch=256) as gw:
        handles = [gw.submit(_pipeline(left, right, gw.session, i),
                             tenant=f"tenant{i % 2}")
                   for i in range(N_SESSIONS)]
        rows = [h.result(timeout=300) for h in handles]
        snap = gw.snapshot()
    t_conc = time.monotonic() - t0
    emit("serve/concurrent", 1e6 * t_conc / N_SESSIONS,
         oracle_prompts=gw_backend.n_prompts,
         throughput_rps=round(N_SESSIONS / t_conc, 2),
         p50_latency_s=snap["p50_latency_s"],
         p95_latency_s=snap["p95_latency_s"],
         cross_query_hit_rate=round(snap["cross_query_hit_rate"], 3),
         fused_batches=snap["dispatch"]["fused_batches"],
         wall_s=round(t_conc, 3))

    identical = rows == serial_rows
    saved = serial_backend.n_prompts - gw_backend.n_prompts
    emit("serve/outcome", 0.0, identical_records=identical,
         oracle_prompts_saved=saved,
         saved_pct=round(100.0 * saved / max(serial_backend.n_prompts, 1), 1))

    with open("BENCH_serve.json", "w") as fh:
        json.dump({
            "sessions": N_SESSIONS,
            "serial": {"oracle_prompts": serial_backend.n_prompts,
                       "wall_s": round(t_serial, 4),
                       "throughput_rps": round(N_SESSIONS / t_serial, 2)},
            "concurrent": {"oracle_prompts": gw_backend.n_prompts,
                           "wall_s": round(t_conc, 4),
                           "gateway": snap},
            "identical_records": identical,
            "oracle_prompts_saved": saved,
        }, fh, indent=2)

    assert identical, "concurrent sessions diverged from serial results"
    assert saved > 0, "gateway did not save oracle prompts vs serial"
    assert snap["cross_query_hit_rate"] > 0, "no cross-query sharing happened"


if __name__ == "__main__":
    run()
