"""Table 2 analogue: fact-checking pipeline variants on a synthetic FEVER.

Methods: gold LOTUS program (map->filter, oracle only), optimized LOTUS
(cascade filter), proxy-only AI-UDF analogue. Reports accuracy vs the gold
output, wall time, and LM calls."""
import time

import numpy as np

from benchmarks._util import emit, set_metrics
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

N = 800


def run() -> None:
    records, world, oracle, proxy, emb = synth.make_filter_world(N, proxy_alpha=2.5, seed=0)
    sess = Session(oracle=oracle, proxy=proxy, embedder=emb, sample_size=100)
    claims = SemFrame(records, sess)
    langex = "the {claim} is supported by evidence"

    t0 = time.monotonic()
    gold = claims.sem_map("query for {claim}", out_column="q").sem_filter(langex)
    t_gold = time.monotonic() - t0
    st_gold = claims.last_stats()
    gold_ids = {t["id"] for t in gold.records}
    emit("table2/lotus_unopt", 1e6 * t_gold / N, accuracy=1.0,
         lm_calls=st_gold["lm_calls"] + N, et_s=round(t_gold, 3))

    t0 = time.monotonic()
    opt = claims.sem_map("query for {claim}", out_column="q").sem_filter(
        langex, recall_target=0.9, precision_target=0.9, delta=0.2)
    t_opt = time.monotonic() - t0
    st = claims.last_stats()
    r, p = set_metrics({t["id"] for t in opt.records}, gold_ids)
    acc_vs_gold = 1.0 - (len(gold_ids ^ {t["id"] for t in opt.records}) / N)
    emit("table2/lotus_opt", 1e6 * t_opt / N, accuracy=round(acc_vs_gold, 4),
         recall=round(r, 3), precision=round(p, 3),
         oracle_calls=st["oracle_calls"], lm_calls=st["lm_calls"] + N,
         et_s=round(t_opt, 3))

    # AI-UDF analogue: proxy-only row-wise map (no guarantees)
    t0 = time.monotonic()
    passed, _ = sess.proxy.predicate([f"the claim is supported {t['claim']}" for t in records])
    t_udf = time.monotonic() - t0
    udf_ids = {records[i]["id"] for i in np.flatnonzero(passed)}
    acc = 1.0 - len(gold_ids ^ udf_ids) / N
    emit("table2/proxy_only_udf", 1e6 * t_udf / N, accuracy=round(acc, 4),
         lm_calls=N, et_s=round(t_udf, 3))
