"""Quantized retrieval benchmark: int8 IVF tiles + exact rerank vs fp32 IVF.

50k-row clustered corpus (d=64), 64 queries, k=10:

  * bytes per scanned vector: int8 tiles must stream >= 3.5x fewer bytes
    through the cluster-scan hot loop than the fp32 IVF scan (measured from
    ``last_stats["scanned_bytes"]``, which includes the exact-rerank fp32
    re-reads);
  * recall@10 vs the exact top-10 with the rerank on (must hold >= 0.99 of
    exact) and with it off (rerank_factor=1: shows what the rerank buys);
  * scan wall-clock for both precisions (jnp reference path on CPU — the
    byte win is the HBM story; wall-clock is reported, not asserted);
  * ``quantize="none"`` must stay bit-identical to the plain IVF path.

Writes ``BENCH_quant.json``.

    PYTHONPATH=src python -m benchmarks.quant_bench
"""
import json
import time

import numpy as np

from benchmarks._util import emit
from repro.index import IVFIndex, VectorIndex
from repro.index.quant import bytes_per_vector

N_CORPUS = 50_000
N_QUERIES = 64
DIM = 64
K = 10
RECALL_TARGET = 0.95
MIN_BYTES_FACTOR = 3.5
MIN_RECALL_VS_EXACT = 0.99


def _clustered(n, d=DIM, n_centers=64, noise=0.18, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    lab = rng.integers(n_centers, size=n)
    x = centers[lab] + noise * rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return np.asarray(x, np.float32), centers


def _recall(exact_idx, got_idx):
    return float(np.mean([len(set(exact_idx[i]) & set(got_idx[i])) / K
                          for i in range(len(exact_idx))]))


def run() -> None:
    corpus, centers = _clustered(N_CORPUS)
    rng = np.random.default_rng(99)
    queries = centers[rng.integers(len(centers), size=N_QUERIES)] \
        + 0.18 * rng.normal(size=(N_QUERIES, DIM))
    queries = np.asarray(queries, np.float32)

    _, exact_idx = VectorIndex(corpus).search(queries, K)

    # -- fp32 IVF baseline -------------------------------------------------
    ivf = IVFIndex(corpus, recall_target=RECALL_TARGET, block_q=1, seed=7)
    t0 = time.monotonic()
    fp32_scores, fp32_idx = ivf.search(queries, K)
    t_fp32 = time.monotonic() - t0
    st_fp32 = dict(ivf.last_stats)
    recall_fp32 = _recall(exact_idx, fp32_idx)
    emit("quant/ivf_fp32", 1e6 * t_fp32 / N_QUERIES,
         scanned_bytes=st_fp32["scanned_bytes"],
         recall_at_10=round(recall_fp32, 4), wall_s=round(t_fp32, 3))

    # -- int8 IVF + exact rerank (same layout knobs) -----------------------
    t0 = time.monotonic()
    ivf_q = IVFIndex(corpus, recall_target=RECALL_TARGET, block_q=1, seed=7,
                     quantize="int8")
    t_build_q = time.monotonic() - t0
    t0 = time.monotonic()
    _, q_idx = ivf_q.search(queries, K)
    t_int8 = time.monotonic() - t0
    st_int8 = dict(ivf_q.last_stats)
    recall_int8 = _recall(exact_idx, q_idx)
    bytes_factor = st_fp32["scanned_bytes"] / max(st_int8["scanned_bytes"], 1)
    emit("quant/ivf_int8_rerank", 1e6 * t_int8 / N_QUERIES,
         scanned_bytes=st_int8["scanned_bytes"],
         bytes_factor=round(bytes_factor, 2),
         recall_at_10=round(recall_int8, 4),
         reranked=st_int8["reranked"], wall_s=round(t_int8, 3))

    # -- int8 with the rerank off (rerank_factor=1 keeps pool == k) --------
    ivf_q1 = IVFIndex(corpus, recall_target=RECALL_TARGET, block_q=1, seed=7,
                      quantize="int8", rerank_factor=1)
    _, q1_idx = ivf_q1.search(queries, K)
    recall_norerank = _recall(exact_idx, q1_idx)
    emit("quant/ivf_int8_norerank", 0.0,
         recall_at_10=round(recall_norerank, 4))

    # -- quantize="none" bit-identical to the fp32 path --------------------
    ivf_none = IVFIndex(corpus, recall_target=RECALL_TARGET, block_q=1,
                        seed=7, quantize="none")
    none_scores, none_idx = ivf_none.search(queries, K)
    none_identical = bool(np.array_equal(none_scores, fp32_scores)
                          and np.array_equal(none_idx, fp32_idx))
    emit("quant/none_identical", 0.0, identical=none_identical)

    with open("BENCH_quant.json", "w") as fh:
        json.dump({
            "corpus": N_CORPUS, "queries": N_QUERIES, "dim": DIM, "k": K,
            "recall_target": RECALL_TARGET,
            "bytes_per_vector": {
                "fp32": bytes_per_vector(DIM, "none"),
                "int8": bytes_per_vector(DIM, "int8")},
            "fp32": {**st_fp32, "recall_at_10": round(recall_fp32, 4),
                     "wall_s": round(t_fp32, 4)},
            "int8": {**st_int8, "recall_at_10": round(recall_int8, 4),
                     "build_s": round(t_build_q, 4),
                     "wall_s": round(t_int8, 4)},
            "int8_no_rerank": {"recall_at_10": round(recall_norerank, 4)},
            "bytes_factor": round(bytes_factor, 3),
            "recall_vs_exact_ratio": round(
                recall_int8 / max(recall_fp32, 1e-9), 4),
            "none_identical": none_identical,
        }, fh, indent=2)

    assert bytes_factor >= MIN_BYTES_FACTOR, \
        f"int8 scan streamed only {bytes_factor:.2f}x fewer bytes " \
        f"(need >={MIN_BYTES_FACTOR}x)"
    assert recall_int8 >= MIN_RECALL_VS_EXACT, \
        f"int8+rerank recall@{K} {recall_int8:.3f} below " \
        f"{MIN_RECALL_VS_EXACT} of exact"
    assert none_identical, "quantize='none' diverged from the fp32 IVF path"


if __name__ == "__main__":
    run()
