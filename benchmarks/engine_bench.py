"""Serving-engine microbenchmarks on a tiny real model (CPU): continuous
batching throughput + single-token predicate scoring latency."""
import time


from benchmarks._util import emit
from repro.configs import get_smoke
from repro.data.tokenizer import TOKENIZER
from repro.engine.engine import InferenceEngine


def run() -> None:
    cfg = get_smoke("llama3.2-3b").with_(vocab_size=TOKENIZER.vocab_size)
    eng = InferenceEngine(cfg, max_slots=4, max_seq=160)
    prompts = [f"benchmark request {i} with some padding text" for i in range(8)]
    eng.generate(prompts[:2], max_new_tokens=4)  # warmup/compile

    t0 = time.monotonic()
    outs = eng.generate(prompts, max_new_tokens=16)
    dt = time.monotonic() - t0
    toks = sum(len(TOKENIZER.encode(o, bos=False)) for o in outs)
    emit("engine/continuous_batching", 1e6 * dt / max(toks, 1),
         tok_per_s=round(toks / dt, 1), requests=len(prompts))

    eng.predicate(prompts[:2])  # warmup
    t0 = time.monotonic()
    eng.predicate(prompts * 4)
    dt = time.monotonic() - t0
    emit("engine/predicate_scoring", 1e6 * dt / (len(prompts) * 4),
         calls=len(prompts) * 4)
