"""Partitioned-execution benchmark: device-sharded retrieval + fragment-
parallel operator pipeline on a 50k-row corpus.

Two sections:

  * **sharded search** — exact top-10 over 50k rows, unsharded vs a
    4-shard layout (``shard_map`` across devices when the process has them,
    the jnp shard simulation otherwise — identical numerics either way).
    The sharded scan must be result-identical (recall@10 = 1.0 >= 0.99)
    while each device scores >= ~4x fewer vectors per query
    (``scored_vectors_per_shard``) — the number that turns into wall-clock
    on a real multi-chip mesh.

  * **partitioned pipeline** — a guarantee-carrying cascade filter over the
    same 50k rows, single-partition vs 4 fragments on a 4-worker pool.  The
    oracle/proxy are wrapped with a per-prompt *service latency* (sleep, so
    the GIL is released — modeling a remote LM endpoint whose replicas
    serve fragments concurrently; the simulated model's own CPU work stays
    serial under the GIL and is identical in both runs).  Records, cascade
    thresholds, and the oracle bill must be identical; wall-clock must
    improve.

Writes ``BENCH_shard.json``.

    PYTHONPATH=src [XLA_FLAGS=--xla_force_host_platform_device_count=4] \
        python -m benchmarks.shard_bench
"""
import json
import time

import numpy as np

from benchmarks._util import emit
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.index.vector_index import VectorIndex

N_CORPUS = 50_000
N_QUERIES = 64
K = 10
SHARDS = 4
N_PARTITIONS = 4
FRAGMENT_WORKERS = 4
PER_PROMPT_LATENCY_S = 1e-4     # modeled LM service time per prompt
MIN_PER_SHARD_FACTOR = 3.0      # >= this x fewer vectors per device
RECALL_FLOOR = 0.99


def _clustered(n, d=32, n_centers=64, noise=0.18, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    lab = rng.integers(n_centers, size=n)
    x = centers[lab] + noise * rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return np.asarray(x, np.float32), centers


class ServiceLatencyModel:
    """Backend wrapper adding a per-prompt service time.  ``sleep`` releases
    the GIL, so concurrent fragments genuinely overlap — the bench's honest
    stand-in for parallel LM replicas behind the oracle/proxy."""

    def __init__(self, model, per_prompt_s: float):
        self._m = model
        self._s = per_prompt_s

    def _wait(self, prompts):
        time.sleep(len(prompts) * self._s)

    def predicate(self, prompts):
        self._wait(prompts)
        return self._m.predicate(prompts)

    def generate(self, prompts):
        self._wait(prompts)
        return self._m.generate(prompts)

    def compare(self, prompts):
        self._wait(prompts)
        return self._m.compare(prompts)

    def choose(self, prompts, n_options):
        self._wait(prompts)
        return self._m.choose(prompts, n_options)


def _sharded_search_section(out: dict) -> None:
    corpus, centers = _clustered(N_CORPUS)
    rng = np.random.default_rng(99)
    queries = np.asarray(
        centers[rng.integers(len(centers), size=N_QUERIES)]
        + 0.18 * rng.normal(size=(N_QUERIES, 32)), np.float32)

    exact = VectorIndex(corpus)
    t0 = time.monotonic()
    _, exact_idx = exact.search(queries, K)
    t_exact = time.monotonic() - t0
    exact_scored = exact.last_stats["scored_vectors"]

    sharded = VectorIndex(corpus, shards=SHARDS)
    t0 = time.monotonic()
    _, shard_idx = sharded.search(queries, K)
    t_shard = time.monotonic() - t0
    st = sharded.last_stats
    recall = float(np.mean([len(set(exact_idx[i]) & set(shard_idx[i])) / K
                            for i in range(N_QUERIES)]))
    per_shard = st["scored_vectors_per_shard"]
    factor = exact_scored / max(per_shard, 1)
    emit("shard/search", 1e6 * t_shard / N_QUERIES,
         shards=st["shards"], recall_at_10=round(recall, 4),
         scored_vectors=st["scored_vectors"],
         scored_vectors_per_shard=per_shard,
         per_shard_factor=round(factor, 1),
         wall_s_exact=round(t_exact, 3), wall_s_sharded=round(t_shard, 3))
    out["sharded_search"] = {
        "shards": st["shards"], "recall_at_10": round(recall, 4),
        "scored_vectors": st["scored_vectors"],
        "scored_vectors_per_shard": per_shard,
        "per_shard_factor": round(factor, 2),
        "wall_s_exact": round(t_exact, 4),
        "wall_s_sharded": round(t_shard, 4),
    }
    assert recall >= RECALL_FLOOR, \
        f"sharded recall@{K} {recall:.3f} < {RECALL_FLOOR}"
    assert factor >= MIN_PER_SHARD_FACTOR, \
        f"per-device scan only {factor:.1f}x smaller (need >= {MIN_PER_SHARD_FACTOR}x)"


def _pipeline_section(out: dict) -> None:
    records, world, *_ = synth.make_filter_world(N_CORPUS, positive_rate=0.3,
                                                 seed=17)
    synth.add_phrase_predicate(world, records, "is actionable", 0.25, seed=17)

    def session():
        return Session(
            oracle=ServiceLatencyModel(synth.SimulatedModel(world, "oracle"),
                                       PER_PROMPT_LATENCY_S),
            proxy=ServiceLatencyModel(synth.SimulatedModel(world, "proxy"),
                                      PER_PROMPT_LATENCY_S),
            embedder=synth.SimulatedEmbedder(world), sample_size=100)

    def pipeline(sf):
        return sf.lazy().sem_filter("the {claim} is actionable",
                                    recall_target=0.9, precision_target=0.9)

    log_s, log_p = [], []
    t0 = time.monotonic()
    single = pipeline(SemFrame(records, session(), log_s)).collect()
    t_single = time.monotonic() - t0

    t0 = time.monotonic()
    part = pipeline(SemFrame(records, session(), log_p)).collect(
        n_partitions=N_PARTITIONS, fragment_workers=FRAGMENT_WORKERS)
    t_part = time.monotonic() - t0

    calls = lambda log, k: sum(st.get(k, 0) for st in log)
    st_s = next(st for st in log_s if st["operator"] == "sem_filter")
    st_p = next(st for st in log_p if st["operator"] == "sem_filter")
    identical = part.records == single.records
    same_tau = (st_p["tau_plus"] == st_s["tau_plus"]
                and st_p["tau_minus"] == st_s["tau_minus"])
    oracle_s, oracle_p = calls(log_s, "oracle_calls"), calls(log_p, "oracle_calls")
    speedup = t_single / max(t_part, 1e-9)
    emit("shard/pipeline", 1e6 * t_part / N_CORPUS,
         n_partitions=N_PARTITIONS, identical=identical, same_tau=same_tau,
         oracle_calls=oracle_p, speedup=round(speedup, 2),
         wall_s_single=round(t_single, 3), wall_s_partitioned=round(t_part, 3))
    out["partitioned_pipeline"] = {
        "rows": N_CORPUS, "n_partitions": N_PARTITIONS,
        "fragment_workers": FRAGMENT_WORKERS,
        "latency_model_per_prompt_s": PER_PROMPT_LATENCY_S,
        "records_identical": identical, "same_thresholds": same_tau,
        "oracle_calls_single": oracle_s, "oracle_calls_partitioned": oracle_p,
        "wall_s_single": round(t_single, 4),
        "wall_s_partitioned": round(t_part, 4),
        "speedup": round(speedup, 3),
    }
    assert identical, "partitioned pipeline diverged from single-partition"
    assert same_tau, "partitioned cascade learned different thresholds"
    assert oracle_p == oracle_s, \
        f"partitioning changed the oracle bill ({oracle_p} vs {oracle_s})"
    assert t_part < t_single, \
        f"no wall-clock win ({t_part:.2f}s vs {t_single:.2f}s)"


def run() -> None:
    out: dict = {"corpus": N_CORPUS, "queries": N_QUERIES, "k": K}
    _sharded_search_section(out)
    _pipeline_section(out)
    with open("BENCH_shard.json", "w") as fh:
        json.dump(out, fh, indent=2)


if __name__ == "__main__":
    run()
