"""Benchmark helpers: timing, metrics, CSV rows."""
from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, **derived) -> None:
    d = "|".join(f"{k}={v}" for k, v in derived.items())
    ROWS.append((name, us_per_call, d))
    print(f"{name},{us_per_call:.2f},{d}", flush=True)


def timed(fn, *args, **kw):
    t0 = time.monotonic()
    out = fn(*args, **kw)
    return out, (time.monotonic() - t0)


def ndcg_at_k(ranked_ids, relevance: dict, k: int = 10) -> float:
    """relevance: id -> gain."""
    gains = [relevance.get(i, 0.0) for i in ranked_ids[:k]]
    dcg = sum(g / np.log2(r + 2) for r, g in enumerate(gains))
    ideal = sorted(relevance.values(), reverse=True)[:k]
    idcg = sum(g / np.log2(r + 2) for r, g in enumerate(ideal))
    return float(dcg / idcg) if idcg > 0 else 0.0


def rank_precision_at_k(ranked_ids, truth: set, k: int) -> float:
    """RP@k (BioDEX metric): fraction of top-k that are true labels."""
    top = ranked_ids[:k]
    return len([i for i in top if i in truth]) / min(k, max(len(truth), 1))


def set_metrics(got: set, want: set) -> tuple[float, float]:
    inter = len(got & want)
    recall = inter / max(len(want), 1)
    precision = inter / max(len(got), 1)
    return recall, precision
