"""Benchmark harness: one module per paper table/figure (see DESIGN.md §8).

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only tableN]
"""
import argparse
import sys
import time

from benchmarks import (adapt_bench, audit_bench, engine_bench,
                        fig6_filter_tradeoff, fig8_groupby, fig9_guarantees,
                        index_bench, join_bench, kernels_bench,
                        pipeline_bench, quant_bench, serve_bench,
                        shard_bench, stream_bench, table2_factcheck,
                        table3_biodex, table5_join_plans, table6_7_ranking,
                        trace_bench)

MODULES = {
    "table2": table2_factcheck,
    "table3": table3_biodex,
    "table5": table5_join_plans,
    "table6_7": table6_7_ranking,
    "fig6": fig6_filter_tradeoff,
    "fig8": fig8_groupby,
    "fig9": fig9_guarantees,
    "pipeline": pipeline_bench,
    "serve": serve_bench,
    "index": index_bench,
    "quant": quant_bench,
    "stream": stream_bench,
    "shard": shard_bench,
    "engine": engine_bench,
    "kernels": kernels_bench,
    "trace": trace_bench,
    "adapt": adapt_bench,
    "audit": audit_bench,
    "join": join_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module keys")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(MODULES)
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    for k in keys:
        try:
            MODULES[k].run()
        except Exception as e:  # pragma: no cover
            print(f"{k}/ERROR,nan,{type(e).__name__}:{e}", flush=True)
            raise
    print(f"# total {time.monotonic()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
