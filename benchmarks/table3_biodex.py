"""Table 3/4 analogue: extreme multilabel classification via sem_join on a
synthetic BioDEX (left articles x right reaction labels)."""
import time

import numpy as np

from benchmarks._util import emit, rank_precision_at_k
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.core.operators.search import sem_index, sem_sim_join

N1, N2 = 150, 300  # 45k candidate pairs


def run() -> None:
    from repro.core.backends.simulated import SimConfig
    left, right, world, oracle, proxy, emb = synth.make_join_world(
        N1, N2, labels_per_left=1, sim_correlation=0.0, seed=1,
        cfg=SimConfig(sim_correlation=0.0, label_noise=0.03))
    sess = Session(oracle=oracle, proxy=proxy, embedder=emb, sample_size=1500)
    truth = {l["id"]: {r for (a, r), v in world.join_truth.items() if v and a == l["id"]}
             for l in left}

    # search baseline: pure similarity join (no LM calls)
    t0 = time.monotonic()
    idx = sem_index([t["reaction"] for t in right], sess.embedder)
    scores, top, _ = sem_sim_join([t["abstract"] for t in left], idx, sess.embedder, k=5)
    t_search = time.monotonic() - t0
    rp5 = np.mean([rank_precision_at_k([right[j]["id"] for j in top[i]],
                                       truth[left[i]["id"]], 5) for i in range(N1)])
    emit("table3/search", 1e6 * t_search / N1, rp5=round(float(rp5), 3), lm_calls=0)

    # gold nested-loop join: the quadratic cost the optimizer avoids
    emit("table3/gold_join_estimated", float("nan"), lm_calls=N1 * N2,
         note="quadratic_oracle_pass")

    # optimized LOTUS join
    sf = SemFrame(left, sess)
    t0 = time.monotonic()
    joined = sf.sem_join(right, "the {abstract} reports the {reaction:right}",
                         recall_target=0.85, precision_target=0.85, delta=0.2)
    t_join = time.monotonic() - t0
    st = sf.last_stats()
    got = {}
    for t in joined.records:
        got.setdefault(t["id"], set()).add(t["right_id"])
    rp5 = np.mean([rank_precision_at_k(sorted(got.get(l["id"], set())),
                                       truth[l["id"]], 5) for l in left])
    speedup = (N1 * N2) / max(st["lm_calls"], 1)
    emit("table3/lotus_join", 1e6 * t_join / N1, rp5=round(float(rp5), 3),
         lm_calls=st["lm_calls"], plan=st["plan"],
         speedup_vs_gold=round(speedup, 1))

    # XL row: the oracle-call saving is ~scale-independent with a good proxy,
    # so the speedup grows with |T1 x T2| (the paper's 1,000x is at 250 x
    # 24,000 labels; BioDEX-XL here is 200 x 2,500 = 500k pairs).
    n1x, n2x = 200, 2500
    from repro.core.backends.simulated import SimConfig as _SC
    lx, rx, wx, ox, px, ex = synth.make_join_world(
        n1x, n2x, labels_per_left=1, sim_correlation=0.0, seed=9,
        cfg=_SC(sim_correlation=0.0, label_noise=0.03))
    truth_x = {l["id"]: {r for (a, r), v in wx.join_truth.items() if v and a == l["id"]}
               for l in lx}

    def _run_xl(sample_size, tag):
        sess_x = Session(oracle=ox, proxy=px, embedder=ex, sample_size=sample_size)
        sfx = SemFrame(lx, sess_x)
        t0 = time.monotonic()
        joined_x = sfx.sem_join(rx, "the {abstract} reports the {reaction:right}",
                                recall_target=0.85, precision_target=0.85, delta=0.2)
        t_x = time.monotonic() - t0
        st_x = sfx.last_stats()
        got_x = {}
        for t in joined_x.records:
            got_x.setdefault(t["id"], set()).add(t["right_id"])
        rp5_x = np.mean([rank_precision_at_k(sorted(got_x.get(l["id"], set())),
                                             truth_x[l["id"]], 5) for l in lx])
        emit(f"table3/lotus_join_xl_{tag}", 1e6 * t_x / n1x,
             rp5=round(float(rp5_x), 3), lm_calls=st_x["lm_calls"],
             plan=st_x["plan"], gold_calls=n1x * n2x, sample=sample_size,
             speedup_vs_gold=round(n1x * n2x / max(st_x["lm_calls"], 1), 1))

    # certifying recall at a 0.04% positive base rate needs enough observed
    # positives (Wilson-corrected bounds; see core/optimizer/stats.py) —
    # the sample is the price of the guarantee at extreme skew:
    _run_xl(8000, "guaranteed")
    # the paper's operating point (CLT-only bounds, small sample): far fewer
    # calls; the guarantee is then only as strong as the CLT approximation
    from repro.core.optimizer import stats as _stats
    _stats.FINITE_SAMPLE_GUARD = False
    try:
        _run_xl(500, "paper_regime")
    finally:
        _stats.FINITE_SAMPLE_GUARD = True
