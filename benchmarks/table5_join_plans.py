"""Table 5 analogue: candidate join plans vs the optimizer's choice, in the
regime where raw similarity is uninformative (projection required)."""

from benchmarks._util import emit, set_metrics
from repro.core.backends import synth
from repro.core.frame import Session
from repro.core.operators.join import sem_join_cascade, sem_join_gold


def run() -> None:
    left, right, world, oracle, proxy, emb = synth.make_join_world(
        60, 40, labels_per_left=1, sim_correlation=0.0, seed=2)
    sess = Session(oracle=oracle, proxy=proxy, embedder=emb, sample_size=400)
    langex = "the {abstract} reports the {reaction:right}"
    gold, _ = sem_join_gold(left, right, langex, sess.oracle)
    want = {(i, j) for i in range(60) for j in range(40) if gold[i, j]}

    for plan in ("sim-filter", "project-sim-filter", None):
        mask, st = sem_join_cascade(left, right, langex, sess.oracle, sess.embedder,
                                    recall_target=0.85, precision_target=0.85,
                                    delta=0.2, sample_size=400, seed=3,
                                    force_plan=plan)
        got = {(i, j) for i in range(60) for j in range(40) if mask[i, j]}
        r, p = set_metrics(got, want)
        emit(f"table5/{plan or 'optimizer_choice'}", float("nan"),
             recall=round(r, 3), precision=round(p, 3),
             lm_calls=st["lm_calls"], chosen=st["plan"],
             plan_costs=str(st["plan_costs"]).replace(",", ";"))
