"""Adaptive re-optimization benchmark: the three feedback timescales.

Part A (drifting selectivity): a broad(0.9) -> narrow(0.05) filter chain
above a ``sem_map`` — unprobeable at plan time, so the static plan runs the
expensive as-written order.  One observed run warms the stats store; the
adaptive second run promotes the narrow filter mid-query and must cut the
oracle bill by >= 25% while staying record-identical.

Part B (multi-query sharing): N concurrent gateway sessions over the same
fingerprinted subplan materialize it exactly once (``matview_builds == 1``)
and serve the rest from the view.

Part C (mid-query re-plans): the retrieval switch (planned IVF over an
overestimated corpus -> observed-small exact) and the fragment resize
(4 planned fragments -> 1 for the observed survivor count), each asserted
record-identical to the static plan.  Writes ``BENCH_adapt.json``.

    PYTHONPATH=src python -m benchmarks.adapt_bench
"""
import json
import time

from benchmarks._util import emit
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.obs.stats_store import StatsStore

N_ROWS = 120
N_SESSIONS = 6
MIN_SAVINGS_PCT = 25.0


def _world(n=N_ROWS, seed=8):
    records, world, *_ = synth.make_filter_world(n, seed=seed)
    synth.add_phrase_predicate(world, records, "is broad", 0.9, seed=seed)
    synth.add_phrase_predicate(world, records, "is narrow", 0.05, seed=seed)
    return records, world


def _session(world, *, sample_size=40):
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world),
                   sample_size=sample_size)


def _chain(records, world, log):
    return (SemFrame(records, _session(world), log).lazy()
            .sem_map("a short note on {claim}", out_column="note")
            .sem_filter("the {claim} is broad")
            .sem_filter("the {claim} is narrow"))


def _calls(log, kind="oracle_calls"):
    return sum(st.get(kind, 0) for st in log)


def run() -> None:
    from repro.serve import Gateway

    # -- A: drift workload, warm-store adaptive vs static ------------------
    records, world = _world()
    store = StatsStore()
    warm_log = []
    t0 = time.monotonic()
    first = _chain(records, world, warm_log).collect(stats_store=store)
    t_first = time.monotonic() - t0

    static_log, adaptive_log = [], []
    static = _chain(records, world, static_log).collect()
    t0 = time.monotonic()
    frame = _chain(records, world, adaptive_log)
    adaptive = frame.collect(adaptive=True, stats_store=store)
    t_adaptive = time.monotonic() - t0

    identical = adaptive.records == static.records == first.records
    calls_static = _calls(static_log)
    calls_adaptive = _calls(adaptive_log)
    saved_pct = 100.0 * (calls_static - calls_adaptive) / max(calls_static, 1)
    replans = [e.kind for e in frame._exec_pair[2].replans]
    emit("adapt/static", 1e6 * t_first, oracle_calls=calls_static,
         rows_out=len(static.records))
    emit("adapt/adaptive_warm", 1e6 * t_adaptive, oracle_calls=calls_adaptive,
         saved_pct=round(saved_pct, 1), identical_records=identical,
         reorders=replans.count("reorder_filters"))

    # -- B: matview sharing across concurrent sessions ---------------------
    mv_records, mv_world = _world(n=60, seed=9)
    sess = _session(mv_world, sample_size=30)
    frames = [SemFrame(mv_records, sess).lazy()
              .sem_filter("the {claim} is broad") for _ in range(N_SESSIONS)]
    t0 = time.monotonic()
    with Gateway(sess, max_inflight=4, window_s=0.005, matview=True) as gw:
        handles = [gw.submit(f) for f in frames]
        rows = [h.result(timeout=300) for h in handles]
        snap = gw.snapshot()
    t_mv = time.monotonic() - t0
    mv_identical = all(r == rows[0] for r in rows)
    emit("adapt/matview", 1e6 * t_mv / N_SESSIONS,
         sessions=N_SESSIONS, builds=snap["matview_builds"],
         hits=snap["matview_hits"], identical_records=mv_identical,
         rows_served=snap["matview_rows_served"])

    # -- C: retrieval switch + fragment resize, record-identical -----------
    sw_records, sw_world, *_ = synth.make_filter_world(400, seed=27)
    synth.add_phrase_predicate(sw_world, sw_records, "is narrow", 0.04,
                               seed=27)

    def search_pipe(log=None):
        return (SemFrame(sw_records, _session(sw_world), log).lazy()
                .sem_map("a short note on {claim}", out_column="note")
                .sem_filter("the {claim} is narrow")
                .sem_search("claim", "claim text 3", k=30))

    kw = dict(index_min_corpus=100, index_shared=True)
    sw_static = search_pipe().collect(**kw)
    sw_frame = search_pipe()
    sw_adaptive = sw_frame.collect(adaptive=True, **kw)
    sw_events = [e for e in sw_frame._exec_pair[2].replans
                 if e.kind == "switch_retrieval"]
    sw_identical = sw_adaptive.records == sw_static.records

    rz_records, rz_world = _world(n=200, seed=5)

    def resize_pipe():
        return (SemFrame(rz_records, _session(rz_world)).lazy()
                .sem_map("a short note on {claim}", out_column="note")
                .sem_filter("the {claim} is narrow")
                .sem_filter("the {claim} is broad"))

    rz_static = resize_pipe().collect(n_partitions=4)
    rz_frame = resize_pipe()
    rz_adaptive = rz_frame.collect(adaptive=True, n_partitions=4)
    rz_events = [e for e in rz_frame._exec_pair[2].replans
                 if e.kind == "resize_fragments"]
    rz_identical = rz_adaptive.records == rz_static.records
    emit("adapt/replans", 0.0, retrieval_switches=len(sw_events),
         fragment_resizes=len(rz_events),
         switch_identical=sw_identical, resize_identical=rz_identical)

    with open("BENCH_adapt.json", "w") as fh:
        json.dump({
            "drift": {"oracle_calls_static": calls_static,
                      "oracle_calls_adaptive": calls_adaptive,
                      "saved_pct": round(saved_pct, 1),
                      "identical_records": identical,
                      "replans": replans},
            "matview": {"sessions": N_SESSIONS,
                        "builds": snap["matview_builds"],
                        "hits": snap["matview_hits"],
                        "identical_records": mv_identical},
            "replan_kinds": {"retrieval_switches": len(sw_events),
                             "fragment_resizes": len(rz_events),
                             "switch_identical": sw_identical,
                             "resize_identical": rz_identical},
        }, fh, indent=2)

    assert identical, "adaptive run diverged from the static records"
    assert saved_pct >= MIN_SAVINGS_PCT, (
        f"adaptive saved only {saved_pct:.1f}% oracle calls "
        f"(need >= {MIN_SAVINGS_PCT}%)")
    assert snap["matview_builds"] == 1, (
        f"{N_SESSIONS} sessions materialized the shared subplan "
        f"{snap['matview_builds']} times (want exactly 1)")
    assert snap["matview_hits"] == N_SESSIONS - 1
    assert mv_identical, "matview-served sessions diverged"
    assert sw_events and sw_identical, "retrieval switch missing or diverged"
    assert rz_events and rz_identical, "fragment resize missing or diverged"


if __name__ == "__main__":
    run()
