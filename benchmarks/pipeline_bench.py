"""Pipeline-level plan optimization: eager vs lazy-optimized execution of a
fact-check-style filter -> filter -> join -> topk pipeline.

The eager path runs each operator in isolation exactly as written (broad
filter first); the lazy path optimizes the whole DAG — filter reordering by
cost x selectivity, prompt dedup through BatchedModelCache — before the
batched executor runs it.  Reports oracle calls, total LM calls, cache hits,
wall-clock, and verifies the optimized output is record-identical to eager.
"""
import time

from benchmarks._util import emit
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session

N_LEFT, N_RIGHT, K = 120, 12, 5
SELECTIVE = "the {abstract} names a checkable claim"
BROAD = "the {abstract} is written in English"
JOIN = "the {abstract} reports the {reaction:right}"
RANK = "the {abstract} reports the highest accuracy"


def _world(seed=0):
    left, right, world, oracle, proxy, emb = synth.make_join_world(
        N_LEFT, N_RIGHT, labels_per_left=2, seed=seed)
    synth.add_phrase_predicate(world, left, "names a checkable claim", 0.15, seed=seed)
    synth.add_phrase_predicate(world, left, "is written in English", 0.85, seed=seed)
    for i, t in enumerate(left):
        world.rank_value[t["id"]] = float(i % 17) / 17.0
    return left, right, world


def _frame(left, world, log):
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world), sample_size=60)
    return SemFrame(left, sess, log)


def _tally(log):
    return {k: sum(st.get(k, 0) for st in log)
            for k in ("oracle_calls", "lm_calls", "cache_hits")}


def run() -> None:
    left, right, world = _world()

    # -- eager: operator-at-a-time, as written ----------------------------
    elog: list = []
    t0 = time.monotonic()
    eager = (_frame(left, world, elog)
             .sem_filter(BROAD)
             .sem_filter(SELECTIVE)
             .sem_join(right, JOIN)
             .sem_topk(RANK, K))
    t_eager = time.monotonic() - t0
    e = _tally(elog)
    emit("pipeline/eager", 1e6 * t_eager / N_LEFT,
         oracle_calls=e["oracle_calls"], lm_calls=e["lm_calls"],
         rows=len(eager.records), wall_s=round(t_eager, 3))

    # -- lazy: whole-pipeline optimize + batched execute ------------------
    llog: list = []
    t0 = time.monotonic()
    lz = (_frame(left, world, llog).lazy()
          .sem_filter(BROAD)
          .sem_filter(SELECTIVE)
          .sem_join(right, JOIN)
          .sem_topk(RANK, K))
    opt = lz.collect()
    t_lazy = time.monotonic() - t0
    o = _tally(llog)
    emit("pipeline/optimized", 1e6 * t_lazy / N_LEFT,
         oracle_calls=o["oracle_calls"], lm_calls=o["lm_calls"],
         cache_hits=o["cache_hits"], rows=len(opt.records),
         rewrites=len(lz.last_rewrites), wall_s=round(t_lazy, 3))

    identical = opt.records == eager.records
    saved = e["oracle_calls"] - o["oracle_calls"]
    emit("pipeline/outcome", 0.0, identical_records=identical,
         oracle_calls_saved=saved,
         saved_pct=round(100.0 * saved / max(e["oracle_calls"], 1), 1))
    assert identical, "optimized pipeline diverged from eager output"
    assert saved > 0, "optimized pipeline did not save oracle calls"


if __name__ == "__main__":
    run()
