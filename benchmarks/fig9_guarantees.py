"""Fig 9 analogue: observed failure probability vs configured delta."""
import numpy as np

from benchmarks._util import emit, set_metrics
from repro.core.backends import synth
from repro.core.frame import Session
from repro.core.operators.filter import sem_filter_cascade, sem_filter_gold

TRIALS = 25  # binomial noise ~ +/-0.08; see tests/test_guarantees.py


def run() -> None:
    for delta in (0.1, 0.2, 0.4):
        fails, ocalls = 0, []
        for t in range(TRIALS):
            records, world, oracle, proxy, _ = synth.make_filter_world(
                400, proxy_alpha=1.5, seed=800 + t)
            sess = Session(oracle=oracle, proxy=proxy)
            gold, _ = sem_filter_gold(records, "{claim} holds", sess.oracle)
            mask, st = sem_filter_cascade(records, "{claim} holds", sess.oracle,
                                          sess.proxy, recall_target=0.9,
                                          precision_target=0.9, delta=delta,
                                          sample_size=100, seed=t)
            r, p = set_metrics(set(np.flatnonzero(mask).tolist()),
                               set(np.flatnonzero(gold).tolist()))
            fails += (r < 0.9) or (p < 0.9)
            ocalls.append(st["oracle_calls"])
        emit(f"fig9/delta{delta}", float("nan"),
             observed_failure=round(fails / TRIALS, 3), configured=delta,
             mean_oracle_calls=round(float(np.mean(ocalls)), 1))
