"""Online guarantee-audit benchmark: detection latency, overhead, identity.

A drifting proxy workload: cascade filter rounds whose thresholds were
calibrated against the live world, audited by a gold oracle reading a
*drifted* world (every truth flipped — the worst case).  Asserts the three
production properties of the auditing plane:

  * **detection within budget** — the precision-CI violation fires during
    the drifted phase using at most one window's gold-call budget;
  * **bounded overhead** — enabling auditing adds < 5% wall time to the
    query path (audits run asynchronously at background priority);
  * **identity** — per-round decision masks and the query-side bill are
    bit-identical with auditing on vs off.

Writes ``BENCH_audit.json``.

    PYTHONPATH=src python -m benchmarks.audit_bench
"""
import json
import time

import numpy as np

from benchmarks._util import emit
from repro.core import accounting
from repro.core.backends import synth
from repro.core.operators.filter import sem_filter_cascade
from repro.obs import audit as A

N_ROWS = 400
ROUNDS = 5
REPS = 4                      # interleaved off/on repeats; min per mode
MAX_OVERHEAD_PCT = 5.0
ABS_SLACK_S = 0.05            # OS-noise floor on a sub-second section
BUDGET = 96                   # gold re-judgments per window


def _worlds(seed=7):
    records, world, oracle, proxy, _ = synth.make_filter_world(
        N_ROWS, proxy_alpha=2.5, seed=seed)
    _, drifted, *_ = synth.make_filter_world(N_ROWS, proxy_alpha=2.5,
                                             seed=seed)
    for rid in drifted.filter_truth:
        drifted.filter_truth[rid] = not drifted.filter_truth[rid]
    return records, world, drifted, oracle, proxy


def _workload(records, oracle, proxy, auditor):
    """ROUNDS cascade rounds; returns (masks, query bill, wall seconds)."""
    masks = []
    t0 = time.monotonic()
    with accounting.track("audit_bench") as st:
        with A.activate_ctx(auditor):
            for r in range(ROUNDS):
                mask, _ = sem_filter_cascade(
                    records, "{claim} holds", oracle, proxy,
                    recall_target=0.9, precision_target=0.9,
                    delta=0.2, sample_size=100, seed=3 + r)
                masks.append(mask)
    bill = {k: v for k, v in st.as_dict().items() if k != "wall_s"}
    return masks, bill, time.monotonic() - t0


def run() -> None:
    records, world, drifted, oracle, proxy = _worlds()
    policy = A.AuditPolicy(sample_fraction=0.25, budget_per_window=BUDGET,
                           window_s=3600.0, min_samples=16, seed=1)

    # -- timing: interleave off/on repeats (min per mode) so OS noise
    # can't land entirely on one configuration --------------------------
    _workload(records, oracle, proxy, None)          # warm caches / JIT
    t_off_list, t_on_list = [], []
    masks_off = bill_off = masks_on = bill_on = None
    events, aud = [], None
    for _ in range(REPS):
        masks_off, bill_off, t = _workload(records, oracle, proxy, None)
        t_off_list.append(t)
        rep_events = []
        a = A.GuaranteeAuditor(synth.SimulatedModel(drifted, "oracle"),
                               policy=policy,
                               on_violation=rep_events.append)
        masks_on, bill_on, t = _workload(records, oracle, proxy, a)
        a.drain()
        t_on_list.append(t)
        if aud is not None:
            aud.close()
        aud, events = a, rep_events
    t_off, t_on = min(t_off_list), min(t_on_list)
    overhead_pct = 100.0 * (t_on - t_off) / max(t_off, 1e-9)

    rep = aud.report()
    granted = rep["budget"]["granted"]
    precision_events = [e for e in events if e.kind == "precision"]
    first_n = precision_events[0].n if precision_events else None
    identical = all(np.array_equal(a, b)
                    for a, b in zip(masks_off, masks_on))
    bills_equal = bill_off == bill_on

    emit("audit/query_wall_off", 1e6 * t_off / ROUNDS, rounds=ROUNDS)
    emit("audit/query_wall_on", 1e6 * t_on / ROUNDS,
         overhead_pct=round(overhead_pct, 2))
    emit("audit/detection", 0.0,
         violations=len(precision_events), first_violation_n=first_n,
         gold_calls=rep["audit_calls"], budget=BUDGET, granted=granted)
    emit("audit/identity", 0.0, identical_records=identical,
         identical_bills=bills_equal)

    with open("BENCH_audit.json", "w") as fh:
        json.dump({
            "rounds": ROUNDS, "rows": N_ROWS,
            "wall_off_s": round(t_off, 4), "wall_on_s": round(t_on, 4),
            "overhead_pct": round(overhead_pct, 2),
            "violations": {k: v for k, v in rep["violations"].items()},
            "first_violation_n": first_n,
            "gold_calls": rep["audit_calls"],
            "budget_per_window": BUDGET, "granted": granted,
            "identical_records": identical, "identical_bills": bills_equal,
        }, fh, indent=2)
    aud.close()

    assert precision_events, "drift did not trip a precision violation"
    assert granted <= BUDGET, (
        f"budgeter granted {granted} > per-window budget {BUDGET}")
    assert first_n is not None and first_n <= BUDGET, (
        f"violation needed {first_n} audits (budget {BUDGET})")
    assert identical, "audit sampling changed the query's decision masks"
    assert bills_equal, "auditing leaked into the query-side bill"
    assert t_on <= t_off * (1 + MAX_OVERHEAD_PCT / 100) + ABS_SLACK_S, (
        f"auditing added {overhead_pct:.2f}% wall "
        f"(limit {MAX_OVERHEAD_PCT}%, {t_on:.3f}s vs {t_off:.3f}s)")


if __name__ == "__main__":
    run()
