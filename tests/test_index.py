"""Retrieval layer: RetrievalBackend interface, IVF recall/degenerate
contracts, Pallas cluster-scan kernel vs jnp reference, k-means fixes, the
exact-vs-IVF cost model, and cross-session index sharing (IndexRegistry)."""
import threading

import numpy as np
import pytest

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.index import (IVFIndex, VectorIndex, build_index, choose_backend,
                         kmeans, load_index, nprobe_for_recall)
from repro.kernels import ops as kops
from repro.serve import Gateway, IndexRegistry


def _clustered(n, d=32, n_centers=20, noise=0.15, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    lab = rng.integers(n_centers, size=n)
    x = centers[lab] + noise * rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return np.asarray(x, np.float32), centers


def _recall(exact_idx, ann_idx):
    k = exact_idx.shape[1]
    return np.mean([len(set(exact_idx[i]) & set(ann_idx[i])) / k
                    for i in range(len(exact_idx))])


# ---------------------------------------------------------------------------
# k-means satellite fixes
# ---------------------------------------------------------------------------


def test_kmeans_converges_and_is_deterministic():
    x, _ = _clustered(300, seed=1)
    c1, a1 = kmeans(x, 8, seed=3)
    c2, a2 = kmeans(x, 8, seed=3)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_allclose(c1, c2)
    assert a1.min() >= 0 and a1.max() < 8         # every point truly assigned
    # k > n clamps
    c3, a3 = kmeans(x[:5], 12, seed=0)
    assert len(c3) == 5 and len(a3) == 5


def test_kmeans_converges_on_first_iteration_stable_data():
    """The old loop compared iteration 0's assignment against the zero-init
    array (and leaned on `_` as the counter): with one tight cluster whose
    points all argmax to center 0, that spuriously 'converged' before any
    center update.  The fix must still run the update sweep."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=16)
    base /= np.linalg.norm(base)
    x = np.stack([base + 1e-3 * rng.normal(size=16) for _ in range(20)])
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    centers, assign = kmeans(x, 1, seed=0)
    # the single center must be the (updated) mean direction, not the raw seed
    mean = x.mean(axis=0)
    mean /= np.linalg.norm(mean)
    np.testing.assert_allclose(centers[0], mean, atol=1e-5)
    assert (assign == 0).all()


def test_kmeans_empty_cluster_reseed_picks_distinct_points():
    """Several empty clusters in one sweep must not all grab the same worst
    point (which left duplicate centers behind)."""
    rng = np.random.default_rng(2)
    tight = rng.normal(size=16)
    tight /= np.linalg.norm(tight)
    x = np.stack([tight + 1e-4 * rng.normal(size=16) for _ in range(30)]
                 + [-tight + 0.3 * rng.normal(size=16) for _ in range(3)])
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    centers, assign = kmeans(x, 6, iters=8, seed=5)
    # no two centers may be numerically identical
    pair_sims = centers @ centers.T
    off_diag = pair_sims[~np.eye(len(centers), dtype=bool)]
    assert not np.any(np.isclose(off_diag, 1.0, atol=1e-12)) or \
        len(np.unique(np.round(centers, 10), axis=0)) == len(centers)


# ---------------------------------------------------------------------------
# IVF correctness contracts
# ---------------------------------------------------------------------------


def test_ivf_recall_meets_target_on_clustered_data():
    x, centers = _clustered(3000, seed=4)
    rng = np.random.default_rng(7)
    q = centers[rng.integers(len(centers), size=24)] \
        + 0.15 * rng.normal(size=(24, 32))
    exact = VectorIndex(x)
    _, ei = exact.search(q, 10)
    ivf = IVFIndex(x, recall_target=0.9, seed=1)
    _, vi = ivf.search(q, 10)
    assert _recall(ei, vi) >= 0.9
    st = ivf.last_stats
    assert st["index"] == "ivf" and st["probed_clusters"] > 0
    assert st["scored_vectors"] < 24 * len(x)      # genuinely pruned


def test_ivf_nprobe_all_clusters_degenerates_to_exact():
    x, _ = _clustered(800, seed=5)
    q = x[::97][:9] + 0.01  # off-corpus queries, no exact ties
    exact = VectorIndex(x)
    es, ei = exact.search(q, 7)
    ivf = IVFIndex(x, n_clusters=16, seed=2)
    vs, vi = ivf.search(q, 7, nprobe=ivf.n_clusters)
    np.testing.assert_array_equal(vi, ei)
    np.testing.assert_allclose(vs, es, atol=1e-5)


def test_ivf_search_returns_k_even_with_tiny_nprobe():
    """nprobe=1 with small clusters: the min-probe floor must widen the scan
    so k unique results always come back."""
    x, _ = _clustered(200, seed=6)
    ivf = IVFIndex(x, n_clusters=50, nprobe=1, seed=3)
    scores, idx = ivf.search(x[:3], 20)
    assert idx.shape == (3, 20)
    assert all(len(set(row.tolist())) == 20 for row in idx)
    assert (scores > -1e29).all()                 # no masked filler leaked


def test_ivf_search_with_empty_query_set():
    """An upstream filter can empty the query side of a sim-join; the ANN
    path must return empty results like the exact path, not crash."""
    x, _ = _clustered(300, seed=16)
    empty = np.zeros((0, 32), np.float32)
    es, ei = VectorIndex(x).search(empty, 5)
    vs, vi = IVFIndex(x, n_clusters=8, seed=1).search(empty, 5)
    assert es.shape == vs.shape == (0, 5)
    assert ei.shape == vi.shape == (0, 5)


def test_ivf_skewed_clusters_rebalanced_and_still_exact_at_full_probe():
    """One dominant cluster must not inflate every padded tile (the store
    pads to the largest list); the bounded-capacity repair keeps L near the
    mean while preserving the degenerate nprobe=all exactness contract."""
    rng = np.random.default_rng(20)
    dominant = rng.normal(size=32)
    dominant /= np.linalg.norm(dominant)
    x = np.concatenate([
        dominant + 0.02 * rng.normal(size=(1500, 32)),   # ~94% in one mode
        rng.normal(size=(100, 32)),
    ])
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    x = np.asarray(x, np.float32)
    ivf = IVFIndex(x, n_clusters=16, seed=6)
    cap = ivf._cluster_cap(len(x))
    assert ivf.cluster_sizes.max() <= cap              # no runaway tile
    assert ivf.store.shape[1] <= -(-cap // 128) * 128  # L stays bounded
    q = np.asarray(x[::211][:6] + 0.01, np.float32)
    _, ei = VectorIndex(x).search(q, 8)
    _, vi = ivf.search(q, 8, nprobe=ivf.n_clusters)
    np.testing.assert_array_equal(vi, ei)              # every vector reachable


def test_executor_auto_build_honors_recall_target():
    """The join sim-prefilter's 'auto' index builds must obey the session's
    recall knob: recall_target=1.0 forces exact even when a registry would
    amortize an IVF build (the record-identical contract)."""
    from repro.core.plan.execute import PlanExecutor
    records, world, *_ = synth.make_filter_world(2500, seed=17)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    texts = [t["claim"] for t in records]
    reg = IndexRegistry()
    ex = PlanExecutor(sess, index_registry=reg, recall_target=0.95,
                      index_min_corpus=500)
    assert ex._build_index(texts, n_queries=4).kind == "ivf"
    ex_exact = PlanExecutor(sess, index_registry=reg, recall_target=1.0,
                            index_min_corpus=500)
    assert ex_exact._build_index(texts, n_queries=4).kind == "exact"


def test_save_load_roundtrip_both_formats(tmp_path):
    x, _ = _clustered(500, seed=8)
    q = x[:5]
    for built in (VectorIndex(x, ids=[f"r{i}" for i in range(len(x))]),
                  IVFIndex(x, n_clusters=12, seed=4)):
        p = str(tmp_path / built.kind)
        built.save(p)
        loaded = load_index(p)
        assert loaded.kind == built.kind and loaded.ids == built.ids
        s0, i0 = built.search(q, 5)
        s1, i1 = loaded.search(q, 5)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_allclose(s0, s1, atol=1e-6)


def test_build_index_dispatch_and_auto():
    x, _ = _clustered(300, seed=9)
    assert build_index(x, kind="exact").kind == "exact"
    assert build_index(x, kind="ivf", n_clusters=8).kind == "ivf"
    assert build_index(x, kind="auto").kind == "exact"   # small corpus
    with pytest.raises(ValueError):
        build_index(x, kind="nope")


# ---------------------------------------------------------------------------
# Pallas kernel vs jnp reference
# ---------------------------------------------------------------------------


def test_ivf_kernel_interpret_matches_ref():
    x, centers = _clustered(600, seed=10)
    ivf = IVFIndex(x, n_clusters=10, seed=5)
    q = np.asarray(centers[:13], np.float32)
    s_ref, p_ref = kops.ivf_search(q, ivf.centroids, ivf.store,
                                   ivf.store_mask, nprobe=3, impl="ref")
    s_int, p_int = kops.ivf_search(q, ivf.centroids, ivf.store,
                                   ivf.store_mask, nprobe=3, impl="interpret")
    np.testing.assert_array_equal(p_ref, p_int)    # shared probe selection
    np.testing.assert_allclose(s_ref, s_int, atol=1e-5)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def test_choose_backend_cost_model():
    assert choose_backend(500, 1) == ("exact", None)          # small corpus
    # without a registry the build dies with the call: one query never pays
    assert choose_backend(50_000, 1)[0] == "exact"
    # a registry amortizes the build over serving traffic -> IVF wins
    kind, nprobe = choose_backend(50_000, 64, shared=True)
    assert kind == "ivf" and 0 < nprobe < 224
    # a big enough un-shared batch pays for its own build
    assert choose_backend(50_000, 20_000)[0] == "ivf"
    assert choose_backend(50_000, 64, recall_target=1.0, shared=True)[0] == "exact"
    # probe count grows with the recall target, up to every cluster
    probes = [nprobe_for_recall(200, r) for r in (0.8, 0.9, 0.95, 0.99, 1.0)]
    assert probes == sorted(probes) and probes[-1] == 200


def test_optimizer_installs_retrieval_choice():
    records, world, *_ = synth.make_filter_world(260, seed=11)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    lz = SemFrame(records, sess).lazy().sem_search("claim", "claim text 7", k=5)
    txt = lz.explain(index_min_corpus=50, index_shared=True)
    assert "choose_retrieval" in txt and "ivf(nprobe=" in txt
    out = lz.collect(index_min_corpus=50, index_shared=True)
    assert len(out.records) == 5
    st = next(s for s in out.stats_log if s["operator"] == "sem_search")
    assert st["index"] == "ivf" and st["scored_vectors"] > 0
    # default threshold: small corpora stay exact (no rewrite noise)
    lz2 = SemFrame(records, sess).lazy().sem_search("claim", "claim text 7", k=5)
    assert "choose_retrieval" not in lz2.explain()


def test_ivf_operator_path_record_identical_at_full_probe():
    """recall_target=1.0 / nprobe=all: the optimized ANN surface must be
    record-identical to the exact path (acceptance criterion)."""
    records, world, *_ = synth.make_filter_world(150, seed=12)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    sf = SemFrame(records, sess)
    exact = sf.sem_search("claim", "claim text 3", k=6, index_kind="exact")
    full_ivf = sf.sem_search("claim", "claim text 3", k=6, index_kind="ivf",
                             nprobe=10_000)
    assert full_ivf.records == exact.records
    ej = sf.sem_sim_join(records[:20], "claim", "claim", k=2,
                         index_kind="exact")
    vj = sf.sem_sim_join(records[:20], "claim", "claim", k=2,
                         index_kind="ivf", nprobe=10_000)
    strip = lambda rows: [{k: v for k, v in t.items() if k != "sim_score"}
                          for t in rows]
    assert strip(vj.records) == strip(ej.records)  # same rows, same order
    np.testing.assert_allclose([t["sim_score"] for t in vj.records],
                               [t["sim_score"] for t in ej.records], atol=1e-5)


# ---------------------------------------------------------------------------
# sem_search satellite: rerank clamp + retrieval accounting
# ---------------------------------------------------------------------------


def test_sem_search_clamps_rerank_and_records_retrieval_details():
    records, world, *_ = synth.make_filter_world(60, seed=13)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    sf = SemFrame(records, sess)
    out = sf.sem_search("claim", "claim text 9", k=4, n_rerank=99,
                        rerank_langex="most relevant: {claim}")
    assert len(out.records) == 4                   # clamped to k, no blowup
    st = next(s for s in out.stats_log if s["operator"] == "sem_search")
    assert st["reranked"] == 4
    assert st["index"] == "exact"
    assert st["scored_vectors"] == 60              # one query x full corpus


# ---------------------------------------------------------------------------
# IndexRegistry: cross-session sharing
# ---------------------------------------------------------------------------


def test_registry_builds_once_under_concurrency():
    reg = IndexRegistry()
    x, _ = _clustered(100, seed=14)
    calls = []
    gate = threading.Event()

    class FakeEmbedder:
        index_key = "fake@1"

    def builder():
        gate.wait(2.0)                             # hold every racer at the latch
        calls.append(1)
        return VectorIndex(x)

    texts = [f"t{i}" for i in range(100)]
    results = [None] * 6

    def worker(i):
        results[i] = reg.get_or_build(texts, FakeEmbedder(), kind="exact",
                                      builder=builder)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    gate.set()
    for t in threads:
        t.join()
    assert len(calls) == 1                         # exactly one build
    assert all(r is results[0] for r in results)   # same shared object
    m = reg.metrics()
    assert m["index_builds"] == 1 and m["index_hits"] == 5


def test_gateway_concurrent_sessions_share_one_index_build():
    records, world, *_ = synth.make_filter_world(200, seed=15)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    sf = SemFrame(records, sess)
    with Gateway(sess, max_inflight=2) as gw:
        h1 = gw.submit(sf.lazy().sem_search("claim", "claim text 5", k=3),
                       tenant="a")
        h2 = gw.submit(sf.lazy().sem_search("claim", "claim text 11", k=3),
                       tenant="b")
        r1, r2 = h1.result(), h2.result()
        snap = gw.snapshot()
    assert len(r1) == 3 and len(r2) == 3
    assert snap["index_builds"] == 1               # one corpus -> one build
    assert snap["index_hits"] >= 1
