"""Serving engine: continuous batching equivalence, paged cache, fault
tolerance / straggler re-queue, predicate scoring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.tokenizer import TOKENIZER
from repro.engine import paged as paged_mod
from repro.engine.engine import InferenceEngine
from repro.engine.runner import ModelRunner
from repro.engine.sampler import Sampler
from repro.engine.scheduler import ContinuousBatchScheduler, Request
from repro.models import registry


@pytest.fixture(scope="module")
def small_engine():
    cfg = get_smoke("llama3.2-3b").with_(vocab_size=TOKENIZER.vocab_size)
    return InferenceEngine(cfg, max_slots=3, max_seq=128)


def _seq_generate(cfg, params, prompt_tokens, n, max_seq=128):
    r = ModelRunner(cfg, params, max_slots=1, max_seq=max_seq)
    logits = r.prefill_into_slot(prompt_tokens, 0)
    out = [int(np.argmax(logits))]
    lens = np.asarray([len(prompt_tokens)], np.int32)
    for _ in range(n - 1):
        logits = r.decode(np.asarray([out[-1]], np.int32), lens)
        out.append(int(np.argmax(logits[0])))
        lens = lens + 1  # fresh array: async dispatch may still read the old one
    return out


def test_continuous_batching_matches_sequential(small_engine):
    eng = small_engine
    prompts = [f"request number {i} about topic {i % 3}" for i in range(5)]
    refs = []
    for p in prompts:
        toks = np.asarray(TOKENIZER.encode(p), np.int32)
        refs.append(_seq_generate(eng.cfg, eng.runner.params, toks, 6))
    sched = ContinuousBatchScheduler(eng.runner)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, tokens=np.asarray(TOKENIZER.encode(p), np.int32),
                             max_new_tokens=6))
    done = {r.rid: r.out_tokens for r in sched.run_to_completion()}
    for i in range(5):
        assert done[i][:6] == refs[i][:6], f"request {i} diverged"


def test_scheduler_fault_injection_requeues(small_engine):
    eng = small_engine
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] in (2, 5):     # two injected worker failures
            raise RuntimeError("injected worker fault")

    sched = ContinuousBatchScheduler(eng.runner, fault_hook=flaky, max_retries=3)
    for i in range(4):
        sched.submit(Request(rid=i, tokens=np.asarray(TOKENIZER.encode(f"p{i}"), np.int32),
                             max_new_tokens=4))
    done = sched.run_to_completion()
    assert len(done) == 4
    assert all(r.done and not r.failed for r in done)
    assert any(r.retries > 0 for r in done)  # at least one recovered


def test_predicate_and_compare_shapes(small_engine):
    eng = small_engine
    passed, score = eng.predicate(["is water wet?"] * 4)
    assert passed.shape == (4,) and score.shape == (4,)
    assert np.all((score >= 0) & (score <= 1))
    pref = eng.compare(["A or B?"] * 3)
    assert pref.shape == (3,)


def test_paged_decode_matches_contiguous():
    cfg = get_smoke("llama3.2-3b").with_(vocab_size=TOKENIZER.vocab_size)
    params = registry.init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 12
    toks = np.random.default_rng(0).integers(0, 256, (B, T)).astype(np.int32)
    cache = registry.init_cache(cfg, B, 32)
    for t in range(T):
        logits_ref, cache = registry.decode_step(cfg, params, jnp.asarray(toks[:, t:t+1]),
                                                 cache, jnp.int32(t))
    alloc = paged_mod.PageAllocator(num_pages=16, page_size=4, max_slots=B,
                                    max_pages_per_slot=8)
    pages = paged_mod.init_pages(cfg, 16, 4)
    lens = np.zeros(B, np.int32)
    step = jax.jit(lambda p, tk, pg, tb, ln: paged_mod.paged_decode_step(cfg, p, tk, pg, tb, ln))
    for t in range(T):
        for s in range(B):
            alloc.ensure(s, t + 1)
        logits, pages = step(params, jnp.asarray(toks[:, t:t+1]), pages,
                             jnp.asarray(alloc.table), jnp.asarray(lens))
        lens = lens + 1  # fresh array: async dispatch may still read the old one
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref), atol=1e-4)


def test_page_allocator_release_reuse():
    alloc = paged_mod.PageAllocator(num_pages=4, page_size=8, max_slots=2,
                                    max_pages_per_slot=4)
    alloc.ensure(0, 30)      # 4 pages
    with pytest.raises(MemoryError):
        alloc.ensure(1, 1)
    alloc.release(0)
    alloc.ensure(1, 8)       # reuse freed pages
    assert len(alloc.free) == 3


def test_sampler_modes():
    logits = np.asarray([[0.0, 5.0, 1.0]])
    assert Sampler(temperature=0.0)(logits)[0] == 1
    s = Sampler(temperature=1.0, top_k=2, seed=0)
    draws = {int(s(logits)[0]) for _ in range(20)}
    assert draws <= {1, 2}  # top-2 only
