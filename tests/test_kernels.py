"""Per-kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp oracles in repro.kernels.ref (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,sq,sk,h,hk,hd", [
    (1, 64, 64, 4, 4, 64),
    (2, 128, 128, 4, 2, 64),
    (1, 100, 100, 8, 8, 32),     # non-multiple of block
    (2, 48, 48, 8, 2, 128),
    (1, 33, 33, 2, 1, 128),      # extreme GQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_attention_sweep(b, sq, sk, h, hk, hd, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, sk, hk, hd), dtype)
    v = jax.random.normal(ks[2], (b, sk, hk, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="interpret", block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,s,h,hk,hd", [
    (2, 64, 4, 4, 64), (3, 96, 8, 2, 64), (1, 130, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, h, hk, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, hk, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, hk, hd), dtype)
    lens = jnp.asarray(np.random.default_rng(0).integers(0, s, b))
    out = ops.decode_attention(q, k, v, lens, impl="interpret", block_k=32)
    want = ref.decode_attention_ref(q, k, v, lens)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("nq,nc,d", [(16, 16, 32), (37, 53, 48), (100, 7, 128)])
@pytest.mark.parametrize("normalize", [True, False])
def test_similarity_sweep(nq, nc, d, normalize, rng):
    q = rng.normal(size=(nq, d)).astype(np.float32)
    c = rng.normal(size=(nc, d)).astype(np.float32)
    out = ops.similarity(q, c, normalize=normalize, impl="interpret",
                         block_q=16, block_c=16)
    want = np.asarray(ref.similarity_ref(q, c, normalize=normalize))
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (130, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), shape, dtype)
    scale = jax.random.normal(jax.random.PRNGKey(3), shape[-1:], jnp.float32)
    out = ops.rmsnorm(x, scale, impl="interpret", block_rows=32)
    want = ref.rmsnorm_ref(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_ops_ref_dispatch_on_cpu():
    """impl='auto' must resolve to the jnp reference off-TPU."""
    q = np.eye(4, dtype=np.float32)
    s = ops.similarity(q, q, impl="auto")
    np.testing.assert_allclose(np.diag(s), np.ones(4), atol=1e-6)
