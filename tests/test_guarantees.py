"""Statistical accuracy guarantees (§2.2, §3, paper Fig. 9).

Property tests on the estimation machinery (hypothesis) + repeated-trial
tests that observed failure rates stay at/below the configured delta.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'test' extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.backends import synth
from repro.core.frame import Session
from repro.core.operators.filter import sem_filter_cascade, sem_filter_gold
from repro.core.operators.groupby import sem_group_by_cascade, sem_group_by_gold
from repro.core.operators.join import sem_join_cascade, sem_join_gold
from repro.core.optimizer import stats
from repro.index.quantile import quantile_calibrate


# ---------------------------------------------------------------------------
# hypothesis property tests on the estimators
# ---------------------------------------------------------------------------


@given(st.lists(st.floats(-1e3, 1e3), min_size=1, max_size=200))
def test_quantile_calibrate_range_and_order(xs):
    a = np.asarray(xs)
    q = quantile_calibrate(a)
    assert np.all(q > 0) and np.all(q <= 1)
    order = np.argsort(a, kind="stable")
    assert np.all(np.diff(q[order]) >= 0)  # monotone in the raw score


@given(st.integers(10, 300), st.floats(0.05, 0.95), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_importance_weights_unbiased(n, rate, seed):
    """Hajek-weighted positive-count estimates concentrate on the truth."""
    rng = np.random.default_rng(seed)
    truth = rng.random(n) < rate
    scores = np.clip(truth * 0.6 + rng.random(n) * 0.4, 0, 1)
    probs = stats.defensive_importance_probs(scores)
    ests = []
    for t in range(30):
        idx = stats.importance_sample(np.random.default_rng((seed, t)), probs, 200)
        w = 1.0 / (n * probs[idx])
        ests.append(np.sum(w * truth[idx]) / np.sum(w) * n)
    got = float(np.mean(ests))
    want = float(truth.sum())
    assert abs(got - want) <= max(4.0, 0.35 * n)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_threshold_fallbacks_are_safe(seed):
    """Degenerate samples must fall back to the safe thresholds."""
    rng = np.random.default_rng(seed)
    n = 50
    probs = np.full(n, 1.0 / n)
    idx = rng.integers(0, n, 30)
    # all-negative sample: nothing should be auto-accepted
    sample = stats.Sample(idx=idx, probs=probs,
                          labels=np.zeros(30, bool), scores=rng.random(30))
    assert stats.pt_threshold(sample, 0.9, 0.1) == np.inf
    assert stats.rt_threshold(sample, 0.9, 0.1) == -np.inf


def test_rt_pt_monotone_in_target():
    rng = np.random.default_rng(3)
    n = 400
    truth = rng.random(n) < 0.5
    scores = np.clip(0.55 * truth + 0.45 * rng.random(n), 0, 1)
    probs = stats.defensive_importance_probs(scores)
    idx = stats.importance_sample(rng, probs, 150)
    sample = stats.Sample(idx=idx, probs=probs, labels=truth[idx], scores=scores[idx])
    rts = [stats.rt_threshold(sample, g, 0.1) for g in (0.5, 0.7, 0.9, 0.99)]
    assert all(a >= b or b == -np.inf for a, b in zip(rts, rts[1:]))  # stricter -> lower tau-
    pts = [stats.pt_threshold(sample, g, 0.1) for g in (0.5, 0.7, 0.9)]
    assert all(a <= b or b == np.inf for a, b in zip(pts, pts[1:]))   # stricter -> higher tau+


# ---------------------------------------------------------------------------
# repeated-trial guarantee tests (Fig. 9 analogues)
# ---------------------------------------------------------------------------

TRIALS = 25


@pytest.mark.parametrize("alpha", [2.5, 1.0])  # strong / weak proxy
def test_filter_cascade_guarantees(alpha):
    delta, target = 0.2, 0.9
    fails_r = fails_p = 0
    oracle_fracs = []
    for t in range(TRIALS):
        records, world, oracle, proxy, _ = synth.make_filter_world(
            400, proxy_alpha=alpha, seed=1000 + t)
        sess = Session(oracle=oracle, proxy=proxy)
        gold, _ = sem_filter_gold(records, "{claim} holds", sess.oracle)
        opt, stt = sem_filter_cascade(records, "{claim} holds", sess.oracle, sess.proxy,
                                      recall_target=target, precision_target=target,
                                      delta=delta, sample_size=100, seed=t)
        inter = (gold & opt).sum()
        fails_r += inter / max(gold.sum(), 1) < target
        fails_p += inter / max(opt.sum(), 1) < target
        oracle_fracs.append(stt["oracle_calls"] / len(records))
    # observed failure rate must not exceed delta (with binomial slack)
    assert fails_r / TRIALS <= delta + 0.1
    assert fails_p / TRIALS <= delta + 0.1
    if alpha > 2:  # a strong proxy must actually save oracle calls
        assert np.mean(oracle_fracs) < 0.5


def test_weak_proxy_needs_more_oracle_calls():
    """Fig 9c: at fixed targets, the weaker proxy routes more to the oracle."""
    fracs = {}
    for alpha in (2.5, 0.8):
        vals = []
        for t in range(8):
            records, _, oracle, proxy, _ = synth.make_filter_world(
                400, proxy_alpha=alpha, seed=2000 + t)
            sess = Session(oracle=oracle, proxy=proxy)
            _, stt = sem_filter_cascade(records, "{claim} holds", sess.oracle, sess.proxy,
                                        recall_target=0.9, precision_target=0.9,
                                        delta=0.2, sample_size=100, seed=t)
            vals.append(stt["oracle_calls"])
        fracs[alpha] = np.mean(vals)
    assert fracs[0.8] > fracs[2.5]


def test_join_cascade_guarantee_and_plan_choice():
    delta, target = 0.2, 0.8
    fails = 0
    plans = []
    for t in range(12):
        left, right, world, oracle, proxy, emb = synth.make_join_world(
            30, 20, labels_per_left=1, sim_correlation=0.0, seed=3000 + t)
        sess = Session(oracle=oracle, proxy=proxy, embedder=emb)
        gold, _ = sem_join_gold(left, right, "the {abstract} reports the {reaction:right}",
                                sess.oracle)
        mask, stt = sem_join_cascade(left, right,
                                     "the {abstract} reports the {reaction:right}",
                                     sess.oracle, sess.embedder,
                                     recall_target=target, precision_target=target,
                                     delta=delta, sample_size=150, seed=t)
        inter = (gold & mask).sum()
        fails += inter / max(gold.sum(), 1) < target
        plans.append(stt["plan"])
    assert fails / 12 <= delta + 0.15
    # with zero raw-similarity correlation, projection is the better proxy
    assert plans.count("project-sim-filter") > plans.count("sim-filter")


def test_groupby_cascade_guarantee():
    delta, target = 0.2, 0.85
    fails = 0
    for t in range(10):
        records, world, model, emb = synth.make_topic_world(200, 4, seed=4000 + t)
        sess = Session(oracle=model, embedder=emb)
        gold = sem_group_by_gold(records, "the topic of {paper}", 4,
                                 sess.oracle, sess.embedder, seed=t)
        opt = sem_group_by_cascade(records, "the topic of {paper}", 4,
                                   sess.oracle, sess.embedder,
                                   accuracy_target=target, delta=delta,
                                   sample_size=80, seed=t)
        agree = float(np.mean(gold.assignment == opt.assignment))
        fails += agree < target
    assert fails / 10 <= delta + 0.15
