"""Block-prompted semantic joins: blocking, multi-pair oracle prompts, and
transitivity-based verdict inference.

Covers the contract that verdicts are never silently dropped or misaligned:
``parse_block_response`` rejects every truncated / miscounted / duplicated
response outright, ``BlockJudge`` retries then falls back pairwise so each
pair gets exactly one verdict, and the calibration sample agreement-checks
block labels against pairwise gold.  End-to-end, ``sem_join_block`` on the
equivalence entity world must reach the recall target with a fraction of
the gold bill, ``strategy="cascade"`` must stay bit-identical to the
historical dispatch, and rule 4b / the adaptive executor / the auditor /
the metrics plane must all see the new strategy.
"""
import dataclasses

import numpy as np

from repro.core.backends import synth
from repro.core.frame import Session
from repro.core.langex import Langex, as_langex
from repro.core.operators.join import sem_join_block
from repro.core.optimizer import blocks, cascades
from repro.core.plan import nodes as N
from repro.core.plan.adaptive import AdaptivePlanExecutor
from repro.core.plan.optimize import (PlanOptimizer, block_join_cost,
                                      cascade_join_cost,
                                      resolve_join_strategy)
from repro.obs import audit as A
from repro.serve.metrics import GatewayMetrics

JOIN_LX = "the {mention} refers to the same entity as {entity:right}"


def _count_truth(got: np.ndarray, world, left, right):
    want = {(i, j) for i in range(len(left)) for j in range(len(right))
            if world.join_truth.get((left[i]["id"], right[j]["id"]))}
    have = {(i, j) for i, j in zip(*np.nonzero(got))}
    inter = len(want & have)
    recall = inter / max(len(want), 1)
    precision = inter / max(len(have), 1)
    return recall, precision


class _Counting:
    """Wraps a backend model counting every prompt sent to it."""

    def __init__(self, model):
        self._m = model
        self.prompts = 0

    def predicate(self, prompts):
        self.prompts += len(prompts)
        return self._m.predicate(prompts)

    def generate(self, prompts):
        self.prompts += len(prompts)
        return self._m.generate(prompts)


# ---------------------------------------------------------------------------
# parse_block_response: partial parses are never trusted
# ---------------------------------------------------------------------------


def test_parse_valid_block_response_ordered():
    got = blocks.parse_block_response("1: YES\n2: NO\n3: YES", 3)
    assert got == [True, False, True]


def test_parse_tolerates_chatter_and_verdict_synonyms():
    text = ("Sure, here are my verdicts:\n"
            "1. yes\n2) no match\n3 - TRUE\nHope that helps!")
    assert blocks.parse_block_response(text, 3) == [True, False, True]


def test_parse_rejects_truncated_response():
    assert blocks.parse_block_response("1: YES\n2: NO", 4) is None
    assert blocks.parse_block_response("", 2) is None
    assert blocks.parse_block_response(None, 2) is None


def test_parse_rejects_wrong_verdict_count():
    # over-produced: a verdict for a pair id past the block size
    assert blocks.parse_block_response("1: YES\n2: NO\n3: NO", 2) is None


def test_parse_rejects_duplicate_pair_ids():
    assert blocks.parse_block_response("1: YES\n1: NO\n2: YES", 3) is None


def test_parse_rejects_out_of_range_pair_id():
    assert blocks.parse_block_response("0: YES\n1: NO", 2) is None
    assert blocks.parse_block_response("1: YES\n7: NO", 2) is None


def test_parse_unparseable_verdict_lines_mean_miscount():
    # the verdict line itself is garbage -> treated as missing -> None
    assert blocks.parse_block_response("1: MAYBE\n2: NO", 2) is None


# ---------------------------------------------------------------------------
# BlockJudge: validate-retry-fallback, verdicts never dropped or misaligned
# ---------------------------------------------------------------------------


class _StubOracle:
    """Pairwise truth from a function; block responses from a script
    (one entry per generate() *wave*, each applied to all prompts)."""

    def __init__(self, truth_fn, block_script):
        self.truth = truth_fn
        self.script = list(block_script)
        self.generate_prompts = 0
        self.predicate_prompts = 0

    def generate(self, prompts):
        self.generate_prompts += len(prompts)
        mode = self.script.pop(0) if self.script else "garbage"
        out = []
        for p in prompts:
            n = sum(1 for ln in p.splitlines()
                    if ln.strip() and ln.strip()[0].isdigit()
                    and "." in ln.split()[0])
            if mode == "garbage":
                out.append("I cannot answer that.")
            elif mode == "truncated":
                out.append("\n".join(f"{k}: YES" for k in range(1, n)))
            else:  # "valid": all YES
                out.append("\n".join(f"{k}: YES" for k in range(1, n + 1)))
        return out

    def predicate(self, prompts):
        self.predicate_prompts += len(prompts)
        v = np.asarray([self.truth(p) for p in prompts], bool)
        return v, v.astype(float)


def _mk_judge(oracle, n=10, block_size=4):
    left = [{"a": f"L{i}"} for i in range(n)]
    right = [{"b": f"R{j}"} for j in range(n)]
    lx = as_langex("{a} matches {b:right}")
    return blocks.BlockJudge(
        oracle, lx, left, right,
        lambda prs: [f"pair:{i},{j}" for i, j in prs], block_size=block_size)


def test_block_judge_fallback_judges_every_pair_pairwise():
    truth = lambda p: int(p.split(":")[1].split(",")[0]) % 2 == 0
    oracle = _StubOracle(truth, ["garbage", "garbage"])
    judge = _mk_judge(oracle)
    pairs = [(i, i) for i in range(10)]
    got = judge.judge_pairs(pairs)
    want = np.asarray([i % 2 == 0 for i in range(10)])
    assert np.array_equal(got, want)       # aligned, none dropped
    assert judge.stats.block_fallbacks == 3          # ceil(10/4) blocks
    assert judge.stats.pairs_fallback_judged == 10
    assert judge.stats.pairs_block_judged == 0
    assert judge.stats.block_retries == 3  # one strict retry wave
    assert oracle.predicate_prompts == 10


def test_block_judge_strict_retry_recovers_without_fallback():
    oracle = _StubOracle(lambda p: False, ["truncated", "valid"])
    judge = _mk_judge(oracle, n=8, block_size=4)
    got = judge.judge_pairs([(i, i) for i in range(8)])
    assert got.all()                       # the retried block verdicts land
    assert judge.stats.block_retries == 2
    assert judge.stats.block_fallbacks == 0
    assert judge.stats.pairs_block_judged == 8
    assert oracle.predicate_prompts == 0


def test_block_judge_clean_parse_single_wave():
    oracle = _StubOracle(lambda p: True, ["valid"])
    judge = _mk_judge(oracle, n=8, block_size=4)
    got = judge.judge_pairs([(i, i) for i in range(8)])
    assert got.all()
    assert judge.stats.block_prompts == 2
    assert judge.stats.block_retries == 0
    assert judge.stats.pairs_block_judged == 8


# ---------------------------------------------------------------------------
# MatchInference: transitivity closure with enemy propagation
# ---------------------------------------------------------------------------


def test_match_inference_positive_transitivity():
    inf = blocks.MatchInference(3, 2)
    inf.observe(0, 0, True)       # left0 ~ right0
    inf.observe(1, 0, True)       # left1 ~ right0  => left0 ~ left1
    assert inf.implied(0, 0) is True
    assert inf.implied(1, 0) is True
    assert inf.implied(2, 0) is None     # never observed
    assert inf.implied(0, 1) is None


def test_match_inference_negative_propagates_through_classes():
    inf = blocks.MatchInference(3, 2)
    inf.observe(0, 0, True)
    inf.observe(1, 0, True)
    inf.observe(0, 1, False)      # class{l0,l1,r0} disjoint from r1
    assert inf.implied(1, 1) is False    # inferred through the class
    assert inf.resolve(1, 1) is False
    assert inf.inferred == 1
    assert inf.n_classes() >= 1


def test_detect_equivalence_accepts_consistent_classes():
    pairs = [(0, 0), (1, 0), (0, 1), (1, 1), (2, 0), (2, 1)]
    labels = [True, True, True, True, False, False]
    assert blocks.detect_equivalence(pairs, labels) is True


def test_detect_equivalence_rejects_transitivity_violation():
    # positives say l0~r0 and l1~r0 (so l0~l1), but (l1, r1) is negative
    # while (l0, r1) is positive: the closure implies True for a labeled
    # negative -> not an equivalence
    pairs = [(0, 0), (1, 0), (0, 1), (1, 1)]
    labels = [True, True, True, False]
    assert blocks.detect_equivalence(pairs, labels) is False


def test_detect_equivalence_needs_overlapping_evidence():
    # disjoint pairs: nothing overlaps, no structure to test
    pairs = [(0, 0), (1, 1), (2, 2), (3, 3), (4, 4)]
    labels = [True, True, True, True, True]
    assert blocks.detect_equivalence(pairs, labels) is False


# ---------------------------------------------------------------------------
# block-labeled calibration with pairwise agreement checks
# ---------------------------------------------------------------------------


def test_block_labeled_sample_rejudges_disagreeing_blocks():
    truth = lambda p: "pair:0," in p or p.startswith("pair:2")

    class _Inverted(_StubOracle):
        def generate(self, prompts):
            self.generate_prompts += len(prompts)
            out = []
            for p in prompts:
                lines = [ln for ln in p.splitlines()
                         if ln.strip() and ln.strip()[0].isdigit()
                         and "." in ln.split()[0]]
                # valid format, inverted verdicts: all NO where truth varies
                out.append("\n".join(f"{k}: NO"
                                     for k in range(1, len(lines) + 1)))
            return out

    oracle = _Inverted(truth, [])
    judge = _mk_judge(oracle, n=8, block_size=4)
    pairs = [(0, j) for j in range(4)] + [(1, j) for j in range(4)]
    gold = lambda prs: np.asarray([truth(f"pair:{i},{j}") for i, j in prs],
                                  bool)
    cal = cascades.block_labeled_sample(pairs, judge, gold,
                                        rng=np.random.default_rng(0),
                                        agreement_floor=0.95)
    # the first block (left row 0: all true) disagrees with the inverted
    # block oracle and is fully re-judged pairwise
    assert cal.blocks_rejudged >= 1
    want = np.asarray([truth(f"pair:{i},{j}") for i, j in pairs], bool)
    assert np.array_equal(np.asarray(cal.labels, bool), want)
    assert cal.checked > 0 and cal.agreement < 1.0


def test_block_labeled_sample_trusts_agreeing_blocks():
    oracle = _StubOracle(lambda p: True, ["valid", "valid"])
    judge = _mk_judge(oracle, n=8, block_size=4)
    pairs = [(i, i) for i in range(8)]
    cal = cascades.block_labeled_sample(
        pairs, judge, lambda prs: np.ones(len(prs), bool),
        rng=np.random.default_rng(0))
    assert cal.blocks_rejudged == 0
    assert cal.agreement == 1.0
    assert np.asarray(cal.labels, bool).all()


# ---------------------------------------------------------------------------
# sem_join_block end-to-end on the equivalence entity world
# ---------------------------------------------------------------------------


def test_sem_join_block_recall_with_fraction_of_gold_bill():
    left, right, world, oracle, _, emb = synth.make_entity_world(
        48, 30, 10, seed=5)
    counted = _Counting(oracle)
    mask, st = sem_join_block(left, right, JOIN_LX, counted, emb,
                              recall_target=0.9, precision_target=0.9,
                              sample_size=80, seed=3)
    recall, precision = _count_truth(mask, world, left, right)
    assert recall >= 0.8, f"recall {recall:.3f} vs target 0.9 (delta 0.2)"
    assert precision >= 0.7, f"precision {precision:.3f}"
    assert counted.prompts < 48 * 30 / 2, \
        f"{counted.prompts} prompts is no win over gold {48 * 30}"
    assert st["strategy"] == "block"
    assert st["candidate_pairs"] < 48 * 30
    assert st["equivalence"] is True     # detected from the calibration set
    assert st["block_prompts"] >= 1
    assert "pairs_pruned_by_inference" in st


def test_sem_join_block_empty_sides():
    left, right, world, oracle, _, emb = synth.make_entity_world(
        4, 4, 2, seed=1)
    mask, st = sem_join_block([], right, JOIN_LX, oracle, emb)
    assert mask.shape == (0, 4) and st["candidate_pairs"] == 0
    mask, st = sem_join_block(left, [], JOIN_LX, oracle, emb)
    assert mask.shape == (4, 0) and st["candidate_pairs"] == 0


def test_sem_join_block_respects_declared_equivalence():
    left, right, world, oracle, _, emb = synth.make_entity_world(
        24, 16, 6, seed=7)
    lx = Langex(JOIN_LX, equivalence=True)
    mask, st = sem_join_block(left, right, lx, oracle, emb,
                              sample_size=60, seed=2)
    assert st["equivalence"] is True


# ---------------------------------------------------------------------------
# dispatch: strategy="cascade" bit-identical to the historical path
# ---------------------------------------------------------------------------


def _entity_session(world, seed=0):
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world),
                   sample_size=60, seed=seed)


def test_strategy_cascade_identical_to_default_dispatch():
    from repro.core.frame import SemFrame
    left, right, world, *_ = synth.make_entity_world(20, 12, 5, seed=9)
    strip = lambda st: {k: v for k, v in st.items() if k != "wall_s"}
    outs, logs = [], []
    for strategy in (None, "cascade"):
        log = []
        sf = SemFrame(left, _entity_session(world), log)
        out = sf.sem_join(right, JOIN_LX, recall_target=0.9,
                          precision_target=0.9, strategy=strategy)
        outs.append(out.records)
        logs.append([strip(s) for s in log])
    assert outs[0] == outs[1]
    assert logs[0] == logs[1]


def test_strategy_block_through_frame_and_plan_label():
    from repro.core.frame import SemFrame
    left, right, world, *_ = synth.make_entity_world(32, 20, 8, seed=4)
    log = []
    sf = SemFrame(left, _entity_session(world), log)
    out = sf.sem_join(right, JOIN_LX, recall_target=0.9, strategy="block")
    assert out.records                    # matches survive
    st = next(s for s in log if s.get("operator") == "sem_join_block")
    assert st["strategy"] == "block" and st["candidate_pairs"] > 0
    node = N.Join(N.Scan(left), N.Scan(right), JOIN_LX, strategy="block")
    assert "Join[block]" in node.label()


# ---------------------------------------------------------------------------
# optimizer rule 4b + the adaptive re-choice
# ---------------------------------------------------------------------------


def test_resolve_join_strategy_cost_crossover():
    assert resolve_join_strategy(200, 200) == "block"
    assert resolve_join_strategy(5, 5) == "cascade"
    assert block_join_cost(200, 200) < cascade_join_cost(200, 200)
    assert cascade_join_cost(5, 5) < block_join_cost(5, 5)


def test_optimizer_chooses_join_strategy_for_auto():
    left, right, world, *_ = synth.make_entity_world(120, 80, 10, seed=2)
    sess = _entity_session(world)
    plan = N.Join(N.Scan(left), N.Scan(right), JOIN_LX,
                  recall_target=0.9, strategy="auto")
    opt = PlanOptimizer(sess)
    out = opt.optimize(plan)
    join = next(n for n in _iter_nodes(out) if isinstance(n, N.Join))
    assert join.strategy == "block"       # 9600 pairs: blocking wins
    assert join.strategy_auto is True
    assert any(r.rule == "choose_join_strategy" for r in opt.applied)


def test_optimizer_leaves_pinned_strategy_alone():
    left, right, world, *_ = synth.make_entity_world(120, 80, 10, seed=2)
    plan = N.Join(N.Scan(left), N.Scan(right), JOIN_LX,
                  recall_target=0.9, strategy="cascade")
    opt = PlanOptimizer(_entity_session(world))
    out = opt.optimize(plan)
    join = next(n for n in _iter_nodes(out) if isinstance(n, N.Join))
    assert join.strategy == "cascade" and join.strategy_auto is False
    assert not any(r.rule == "choose_join_strategy" for r in opt.applied)


def _iter_nodes(node):
    yield node
    for c in node.children():
        yield from _iter_nodes(c)


def test_adaptive_executor_switches_join_strategy_on_drift():
    big_l, big_r, world, *_ = synth.make_entity_world(200, 150, 12, seed=6)
    sess = _entity_session(world)
    log = []
    ex = AdaptivePlanExecutor(sess, stats_log=log, oracle=sess.oracle,
                              embedder=sess.embedder)
    # the optimizer priced the full scans (200x150 -> block), but upstream
    # filtering left a tiny grid at runtime: the adaptive executor re-prices
    # and switches back to the cascade before judging
    node = N.Join(N.Scan(big_l), N.Scan(big_r), JOIN_LX,
                  recall_target=0.9, strategy="block", strategy_auto=True)
    mask, st = ex._join_dispatch(node, big_l[:10], big_r[:8])
    assert any(e.kind == "switch_join_strategy" for e in ex.replans)
    assert st["operator"] == "sem_join"   # the cascade path ran
    # a user-pinned strategy never switches
    ex2 = AdaptivePlanExecutor(sess, stats_log=[], oracle=sess.oracle,
                               embedder=sess.embedder)
    pinned = dataclasses.replace(node, strategy_auto=False)
    _, st2 = ex2._join_dispatch(pinned, big_l[:10], big_r[:8])
    assert not ex2.replans and st2["operator"] == "sem_join_block"


# ---------------------------------------------------------------------------
# guarantee auditing: block verdicts re-judged pairwise
# ---------------------------------------------------------------------------


def test_auditor_checks_block_verdicts_and_fires_on_disagreement():
    left, right, world, oracle, *_ = synth.make_entity_world(24, 16, 6,
                                                             seed=8)
    from repro.core.operators.join import _pair_prompts
    lx = as_langex(JOIN_LX)
    pairs = [(i, j) for i in range(24) for j in range(16)][:64]
    truth = np.asarray([bool(world.join_truth.get(
        (left[i]["id"], right[j]["id"]))) for i, j in pairs])
    events = []
    aud = A.GuaranteeAuditor(
        oracle, policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8,
                                     budget_per_window=512, seed=1),
        on_violation=events.append)
    try:
        with A.activate_ctx(aud):
            # inverted block verdicts: agreement collapses, the CI must fire
            n = A.emit_block_join(
                "Join", lx.template, pairs, (~truth).tolist(),
                lambda sel: _pair_prompts(lx, left, right,
                                          [pairs[int(f)] for f in sel]),
                agreement_target=0.9)
        assert n > 0
        aud.drain()
        rep = aud.report()
        blk = next(b for b in rep["block_joins"])
        assert blk["pairs_seen"] == 64 and blk["audited"] > 0
        assert blk["violations"] >= 1
        assert any(e.kind == "block_agreement" for e in events)
    finally:
        aud.close()


def test_auditor_block_join_passes_on_agreement():
    left, right, world, oracle, *_ = synth.make_entity_world(24, 16, 6,
                                                             seed=8)
    from repro.core.operators.join import _pair_prompts
    lx = as_langex(JOIN_LX)
    pairs = [(i, j) for i in range(24) for j in range(16)][:64]
    truth = np.asarray([bool(world.join_truth.get(
        (left[i]["id"], right[j]["id"]))) for i, j in pairs])
    events = []
    aud = A.GuaranteeAuditor(
        oracle, policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8,
                                     budget_per_window=512, seed=1),
        on_violation=events.append)
    try:
        with A.activate_ctx(aud):
            A.emit_block_join(
                "Join", lx.template, pairs, truth.tolist(),
                lambda sel: _pair_prompts(lx, left, right,
                                          [pairs[int(f)] for f in sel]),
                agreement_target=0.9)
        aud.drain()
        blk = aud.report()["block_joins"][0]
        assert blk["violations"] == 0
        assert blk["agreement"]["point"] == 1.0
        assert not events
    finally:
        aud.close()


def test_emit_block_join_noop_without_auditor():
    assert A.emit_block_join("Join", "t", [(0, 0)], [True],
                             lambda s: ["p"], agreement_target=0.9) == 0


# ---------------------------------------------------------------------------
# metrics + observability plumbing
# ---------------------------------------------------------------------------


def test_gateway_metrics_join_series():
    from repro.obs.metrics import MetricsRegistry
    m = GatewayMetrics()
    m.on_join_stats({"candidate_pairs": 120, "pairs_pruned_by_inference": 30,
                     "block_prompts": 12, "block_fallbacks": 2})
    m.on_join_stats({"candidate_pairs": 80, "block_prompts": 5})
    reg = MetricsRegistry()
    m.collect(reg)
    text = reg.render()
    assert "repro_join_candidate_pairs_total 200" in text
    assert "repro_join_pairs_pruned_total 30" in text
    assert 'repro_join_block_prompts_total{outcome="ok"} 15' in text
    assert 'repro_join_block_prompts_total{outcome="fallback"} 2' in text
    snap = m.snapshot()
    assert snap["join_candidate_pairs"] == 200
    assert snap["join_block_prompts"] == 17


def test_trace_and_analyze_aggregate_join_counters():
    from repro.obs.analyze import _OBS_COUNTERS
    from repro.obs.trace import _COUNTER_KEYS
    for k in ("candidate_pairs", "pairs_pruned_by_inference",
              "block_prompts", "block_fallbacks"):
        assert k in _COUNTER_KEYS
        assert k in _OBS_COUNTERS


def test_lazy_gold_join_batches_are_row_major():
    """The lazy pair generator must preserve the eager row-major prompt
    order (bit-identical gold joins across the refactor)."""
    from repro.core.operators.join import sem_join_gold
    left, right, world, oracle, *_ = synth.make_entity_world(7, 5, 3, seed=3)
    seen = []

    class _Spy(_Counting):
        def predicate(self, prompts):
            seen.extend(prompts)
            return super().predicate(prompts)

    mask, _ = sem_join_gold(left, right, JOIN_LX, _Spy(oracle), batch=11)
    lx = as_langex(JOIN_LX)
    from repro.core.operators.filter import predicate_prompt
    want = [predicate_prompt(lx, left[i], right[j])
            for i in range(7) for j in range(5)]
    assert seen == want
    recall, precision = _count_truth(mask, world, left, right)
    assert recall == 1.0 and precision == 1.0
