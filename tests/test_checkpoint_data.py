"""Checkpointing (atomic/async/keep-n/bf16) + data pipeline determinism."""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import Prefetcher, SyntheticSource, TextFileSource, packed_batch
from repro.data.tokenizer import TOKENIZER


def _tree():
    return {"a": {"w": jnp.asarray([[1.5, 2.5]], jnp.bfloat16)},
            "b": jnp.arange(4, dtype=jnp.int32)}


def test_roundtrip_bf16_and_manifest():
    d = tempfile.mkdtemp()
    ckpt.save(d, 3, {"params": _tree()})
    step, out = ckpt.load(d)
    assert step == 3
    assert out["params"]["a"]["w"].dtype.name == "bfloat16"
    np.testing.assert_allclose(np.asarray(out["params"]["a"]["w"], np.float32),
                               [[1.5, 2.5]])
    np.testing.assert_array_equal(out["params"]["b"], np.arange(4))


def test_keep_n_pruning_and_latest():
    d = tempfile.mkdtemp()
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, {"t": {"x": jnp.zeros(1)}}, keep=2)
    assert ckpt.latest_step(d) == 4
    steps = sorted(os.listdir(d))
    assert steps == ["step_00000003", "step_00000004"]


def test_async_checkpointer_surfaces_errors_and_waits():
    d = tempfile.mkdtemp()
    ac = ckpt.AsyncCheckpointer(d, keep=2)
    ac.save(1, {"t": {"x": jnp.ones(8)}})
    ac.wait()
    assert ckpt.latest_step(d) == 1
    # error path: unwritable target
    ac2 = ckpt.AsyncCheckpointer("/proc/definitely/not/writable")
    ac2.save(1, {"t": {"x": jnp.ones(2)}})
    with pytest.raises(Exception):
        ac2.wait()


def test_atomicity_no_tmp_left_behind():
    d = tempfile.mkdtemp()
    ckpt.save(d, 7, {"t": {"x": jnp.zeros(2)}})
    assert not any(p.endswith(".tmp") for p in os.listdir(d))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_packed_batch_deterministic_and_shifted():
    src = SyntheticSource(seed=1)
    b1 = packed_batch(src, 5, batch=3, seq_len=64, seed=9)
    b2 = packed_batch(src, 5, batch=3, seq_len=64, seed=9)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_shards_disjoint_streams():
    src = SyntheticSource(seed=1)
    a = packed_batch(src, 0, batch=2, seq_len=32, shard_id=0, num_shards=2, seed=3)
    b = packed_batch(src, 0, batch=2, seq_len=32, shard_id=1, num_shards=2, seed=3)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetcher_straggler_fallback():
    calls = []

    def make(step):
        calls.append(step)
        return {"tokens": np.full((1, 4), step)}

    pre = Prefetcher(make, depth=2, deadline_s=0.5).start(0)
    try:
        for s in range(4):
            out = pre.get(s)
            assert out["tokens"][0, 0] == s
    finally:
        pre.stop()
    # asking for a far-future step forces the synchronous straggler path
    pre2 = Prefetcher(make, depth=1, deadline_s=0.2).start(0)
    try:
        out = pre2.get(50)
        assert out["tokens"][0, 0] == 50
        assert pre2.stragglers == 1
    finally:
        pre2.stop()


def test_textfile_source(tmp_path):
    p = tmp_path / "docs.txt"
    p.write_text("hello world\nsecond doc\n")
    src = TextFileSource(str(p))
    toks = src.doc_tokens(0)
    assert TOKENIZER.decode(toks) == "hello world"
    batch = packed_batch(src, 0, batch=1, seq_len=16)
    assert batch["tokens"].shape == (1, 16)
