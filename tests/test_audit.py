"""Online guarantee auditing and the metrics plane.

Covers the PR-9 tentpole and satellites: binomial interval edge cases and
monotonicity, budgeter hard caps (property-based), bill identity with
auditing on vs off, drift detection end to end (violation event + stats
poison + cache recalibration), IVF exact-rescan recall audits, corrupt
state-file tolerance, Prometheus exposition validity, and the
``explain_analyze`` audit columns.
"""
import json
import threading

import numpy as np
import pytest

try:                                       # property tests prefer hypothesis,
    from hypothesis import given, settings # but the budget invariant is still
    from hypothesis import strategies as st  # fuzzed without it
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import accounting
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.core.operators.filter import sem_filter_cascade
from repro.index import IVFIndex, VectorIndex
from repro.index.backend import exact_topk
from repro.obs import audit as A
from repro.obs.analyze import explain_analyze
from repro.obs.metrics import MetricsRegistry, parse_exposition
from repro.obs.stats_store import StatsStore, predicate_fingerprint
from repro.serve import Gateway


# ---------------------------------------------------------------------------
# interval math
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fn", [A.wilson_interval, A.clopper_pearson])
def test_interval_edges(fn):
    assert fn(0, 0) == (0.0, 1.0)            # no evidence: vacuous interval
    lo, hi = fn(0, 10)
    assert lo == 0.0 and 0.0 < hi < 1.0      # zero successes pins the floor
    lo, hi = fn(10, 10)
    assert 0.0 < lo < 1.0 and hi == 1.0      # all successes pins the ceiling
    lo, hi = fn(1, 2)                        # tiny n: wide but proper
    assert 0.0 <= lo < 0.5 < hi <= 1.0
    # bounds always bracket the point estimate and stay in [0, 1]
    for s, n in [(0, 1), (1, 1), (3, 7), (50, 100), (999, 1000)]:
        lo, hi = fn(s, n)
        assert 0.0 <= lo <= s / n <= hi <= 1.0


@pytest.mark.parametrize("fn", [A.wilson_interval, A.clopper_pearson])
def test_interval_narrows_with_n(fn):
    """At a fixed success ratio, more samples must never widen the CI."""
    widths = [hi - lo for hi, lo in
              ((b, a) for a, b in (fn(n // 2, n)
                                   for n in (4, 16, 64, 256, 1024)))]
    assert all(w1 <= w0 + 1e-12 for w0, w1 in zip(widths, widths[1:]))
    assert widths[-1] < widths[0] / 3


def test_clopper_pearson_contains_wilson():
    """CP is exact-conservative: it should cover at least what Wilson does
    away from the boundary."""
    for s, n in [(3, 10), (30, 100), (70, 100)]:
        wlo, whi = A.wilson_interval(s, n, delta=0.05)
        clo, chi = A.clopper_pearson(s, n, delta=0.05)
        assert clo <= wlo + 1e-9 and chi >= whi - 1e-9


def test_clopper_pearson_known_value():
    # Beta quantile cross-check: CP upper for s=0 is 1-(delta/2)^(1/n)
    _, hi = A.clopper_pearson(0, 20, delta=0.05)
    assert hi == pytest.approx(1.0 - (0.025) ** (1 / 20), abs=1e-9)


def test_binomial_interval_dispatch():
    assert A.binomial_interval(5, 10, method="wilson") == \
        A.wilson_interval(5, 10)
    assert A.binomial_interval(5, 10, method="clopper-pearson") == \
        A.clopper_pearson(5, 10)
    with pytest.raises(ValueError):
        A.binomial_interval(5, 10, method="laplace")


def test_template_match_token():
    assert A.template_match_token("the {abstract} is checkable") == \
        "is checkable"
    assert A.template_match_token("{claim} holds") == "holds"
    assert A.template_match_token("plain text") == "plain text"


# ---------------------------------------------------------------------------
# budgeter: the per-window cap is hard
# ---------------------------------------------------------------------------


def _check_budget_invariant(steps, budget):
    """Property: grants within any single budgeter window never exceed the
    budget, grants never exceed asks, and grant+deny conserves the asks."""
    clock = [0.0]
    b = A.AuditBudgeter(budget, window_s=10.0, now_fn=lambda: clock[0])
    window_spent = 0
    window_start = None
    for dt, n in steps:
        clock[0] += dt
        if window_start is None or clock[0] - window_start >= 10.0:
            window_start, window_spent = clock[0], 0   # mirror the lazy roll
        got = b.take(n)
        assert 0 <= got <= n
        window_spent += got
        assert window_spent <= budget      # the hard per-window cap
    assert b.granted_total + b.denied_total == sum(n for _, n in steps)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.floats(0.0, 5.0), st.integers(0, 40)),
                    min_size=1, max_size=60),
           st.integers(0, 25))
    @settings(max_examples=60, deadline=None)
    def test_budgeter_never_exceeds_window_budget(steps, budget):
        _check_budget_invariant(steps, budget)
else:
    @pytest.mark.parametrize("seed", range(40))
    def test_budgeter_never_exceeds_window_budget(seed):
        rng = np.random.default_rng(seed)
        steps = [(float(rng.uniform(0.0, 5.0)), int(rng.integers(0, 41)))
                 for _ in range(int(rng.integers(1, 61)))]
        _check_budget_invariant(steps, int(rng.integers(0, 26)))


def test_budgeter_window_roll_and_remaining():
    clock = [0.0]
    b = A.AuditBudgeter(5, window_s=1.0, now_fn=lambda: clock[0])
    assert b.take(3) == 3 and b.remaining() == 2
    assert b.take(10) == 2          # cap hit within the window
    assert b.take(1) == 0
    clock[0] += 1.0                 # window rolls: full budget again
    assert b.remaining() == 5 and b.take(7) == 5


def test_budgeter_exact_cap_under_threads():
    b = A.AuditBudgeter(100, window_s=3600.0)
    got = []

    def taker():
        for _ in range(50):
            got.append(b.take(3))

    threads = [threading.Thread(target=taker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(got) == 100          # hard cap, no over-grant under racing


# ---------------------------------------------------------------------------
# end-to-end: drift detection, recalibration, bill identity
# ---------------------------------------------------------------------------


def _filter_worlds(n=400, seed=7):
    """(records, live world, drifted world): same corpus, inverted truth."""
    records, world, oracle, proxy, _ = synth.make_filter_world(
        n, proxy_alpha=2.5, seed=seed)
    _, drifted, *_ = synth.make_filter_world(n, proxy_alpha=2.5, seed=seed)
    for rid in drifted.filter_truth:
        drifted.filter_truth[rid] = not drifted.filter_truth[rid]
    return records, world, drifted, oracle, proxy


def test_audit_confirms_healthy_cascade():
    records, world, _, oracle, proxy = _filter_worlds()
    aud = A.GuaranteeAuditor(
        synth.SimulatedModel(world, "oracle"),
        policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8, seed=1))
    with A.activate_ctx(aud):
        sem_filter_cascade(records, "{claim} holds", oracle, proxy,
                           recall_target=0.9, precision_target=0.9,
                           delta=0.2, sample_size=100, seed=3)
    assert aud.drain()
    est = aud.report()["cascades"][0]
    assert est["precision"] is not None and est["precision"]["lo"] > 0.9
    assert est["violations"] == 0
    assert aud.report()["audit_calls"] == aud.report()["budget"]["granted"]
    aud.close()


def test_drift_fires_violation_and_poisons_stats():
    records, world, drifted, oracle, proxy = _filter_worlds()
    store = StatsStore()
    fp = predicate_fingerprint("Filter", "{claim} holds")
    store.observe("Filter", fp, rows_in=400, rows_out=150, wall_s=0.5,
                  stats={"oracle_calls": 100})
    assert store.get("Filter", fp) is not None
    events = []
    # the audit oracle reads the *drifted* world: gold truth moved after the
    # cascade's thresholds were calibrated
    aud = A.GuaranteeAuditor(
        synth.SimulatedModel(drifted, "oracle"),
        policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8, seed=1),
        stats_store=store, on_violation=events.append)
    with A.activate_ctx(aud):
        mask, _ = sem_filter_cascade(records, "{claim} holds", oracle, proxy,
                                     recall_target=0.9, precision_target=0.9,
                                     delta=0.2, sample_size=100, seed=3)
    assert aud.drain()
    kinds = {e.kind for e in events}
    assert "precision" in kinds
    ev = next(e for e in events if e.kind == "precision")
    assert ev.lower < 0.9 and ev.fingerprint == fp
    assert ev.match_token == "holds"
    assert ev.n >= 8
    # the stale selectivity entry is gone and the alert counters are up
    assert store.get("Filter", fp) is None and store.poisoned >= 1
    assert aud.violation_counts["precision"] >= 1
    # violation events serialize (structured alerting surface)
    assert json.loads(json.dumps(ev.as_dict()))["kind"] == "precision"
    aud.close()


def test_bill_identity_with_auditing_on_vs_off():
    """The query's own bill and records must be bit-identical whether the
    auditor is observing or not — audit traffic lives on its own role."""
    records, world, drifted, oracle, proxy = _filter_worlds()

    def run(auditor):
        with accounting.track("query") as st:
            with A.activate_ctx(auditor):
                mask, _ = sem_filter_cascade(
                    records, "{claim} holds", oracle, proxy,
                    recall_target=0.9, precision_target=0.9,
                    delta=0.2, sample_size=100, seed=3)
        return mask, st.as_dict()

    mask_off, bill_off = run(None)
    aud = A.GuaranteeAuditor(
        synth.SimulatedModel(drifted, "oracle"),
        policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8, seed=1))
    mask_on, bill_on = run(aud)
    assert aud.drain()
    np.testing.assert_array_equal(mask_off, mask_on)
    bill_off.pop("wall_s"), bill_on.pop("wall_s")  # wall time is not a bill
    assert bill_off == bill_on                     # byte-identical OpStats
    assert bill_on["audit_calls"] == 0             # query bill: no audit kind
    # the audit calls all landed on the auditor's own ledger instead
    assert aud.stats.audit_calls == aud.report()["budget"]["granted"] > 0
    aud.close()


def test_violation_resets_estimation_window():
    """After a violation the accumulators restart: post-recalibration
    evidence is not averaged with the drifted rule's."""
    records, world, drifted, oracle, proxy = _filter_worlds()
    aud = A.GuaranteeAuditor(
        synth.SimulatedModel(drifted, "oracle"),
        policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8, seed=1))
    with A.activate_ctx(aud):
        sem_filter_cascade(records, "{claim} holds", oracle, proxy,
                           recall_target=0.9, precision_target=0.9,
                           delta=0.2, sample_size=100, seed=3)
    assert aud.drain()
    est = aud.report()["cascades"][0]
    assert est["violations"] >= 1
    assert est["audited_accepts"] == 0 and est["precision"] is None
    aud.close()


# ---------------------------------------------------------------------------
# gateway integration: recalibration + metrics plane + bill identity
# ---------------------------------------------------------------------------


def _gw_session(world):
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   proxy=synth.SimulatedModel(world, "proxy", alpha=2.5),
                   embedder=synth.SimulatedEmbedder(world), sample_size=100)


def _cascade_pipeline(records, session):
    return (SemFrame(records, session).lazy()
            .sem_filter("{claim} holds", recall_target=0.9,
                        precision_target=0.9))


def test_gateway_bill_identity_and_recalibration():
    records, world, drifted, *_ = _filter_worlds()

    def run(audit):
        gw = Gateway(_gw_session(world), max_inflight=2, window_s=0.005,
                     audit=audit)
        if audit and gw.auditor is not None:
            # point the audit role's gold oracle at the drifted world
            from repro.core.backends.base import CountedModel
            gw.auditor._oracle = CountedModel(
                synth.SimulatedModel(drifted, "oracle"), "audit")
        h = gw.submit(_cascade_pipeline(records, gw.session),
                      tenant="acme")
        recs = h.result(timeout=30.0)
        bill = dict(h.summary()["stats"])
        if gw.auditor is not None:
            gw.auditor.drain()
        snap = gw.snapshot()
        inval = gw.store.stats()["invalidations"]
        text = gw.metrics_text()
        gw.close()
        return recs, bill, snap, inval, text

    recs_off, bill_off, _, _, _ = run(False)
    recs_on, bill_on, snap, inval, text = run(
        A.AuditPolicy(sample_fraction=1.0, min_samples=8, seed=1))
    assert recs_off == recs_on
    for b in (bill_off, bill_on):          # sid and wall differ run to run
        b.pop("wall_s"), b.pop("operator")
    assert bill_off == bill_on              # satellite 1: identical bills
    # drifted gold => violation => gateway purged the predicate's cache rows
    assert snap["audit"]["violations"].get("precision", 0) >= 1
    assert snap["violations"] >= 1
    assert inval > 0
    # per-tenant SLO series reached the exposition
    samples = parse_exposition(text)
    assert samples[
        'repro_tenant_sessions_total{tenant="acme",status="completed"}'] == 1
    assert samples['repro_guarantee_violations_total{kind="precision"}'] >= 1


def test_gateway_metrics_text_is_valid_exposition():
    records, world, *_ = _filter_worlds(n=120)
    gw = Gateway(_gw_session(world), max_inflight=2, window_s=0.005,
                 audit=A.AuditPolicy(sample_fraction=0.5, seed=0))
    h = gw.submit(_cascade_pipeline(records, gw.session), tenant="t0")
    h.result(timeout=30.0)
    gw.auditor.drain()
    text = gw.metrics_text()
    gw.close()
    samples = parse_exposition(text)       # raises on malformed exposition
    for name in ("repro_gateway_sessions_total", "repro_gateway_latency_seconds",
                 "repro_dispatch_prompts_total", "repro_cache_events_total",
                 "repro_audit_oracle_calls_total", "repro_tenant_latency_seconds",
                 "repro_tenant_latency_quantile_seconds"):
        assert any(k == name or k.startswith(name + "{")
                   or k.startswith(name + "_") for k in samples), \
            f"missing family {name}"
    # histogram invariants: cumulative buckets end at +Inf == _count
    buckets = [(k, v) for k, v in samples.items()
               if k.startswith("repro_gateway_latency_seconds_bucket")]
    assert buckets and buckets[-1][0].endswith('le="+Inf"}')
    vals = [v for _, v in buckets]
    assert all(a <= b for a, b in zip(vals, vals[1:]))
    assert vals[-1] == samples["repro_gateway_latency_seconds_count"] == 1


# ---------------------------------------------------------------------------
# metrics registry unit behavior
# ---------------------------------------------------------------------------


def test_metrics_registry_render_and_parse_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("jobs_total", "jobs processed", ("status",))
    c.inc(status="ok")
    c.inc(2, status="err")
    g = reg.gauge("queue_depth", "pending jobs")
    g.set(7)
    hst = reg.histogram("latency_seconds", "op latency",
                        buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        hst.observe(v)
    text = reg.render()
    assert "# TYPE jobs_total counter" in text
    samples = parse_exposition(text)
    assert samples['jobs_total{status="err"}'] == 2.0
    assert samples['jobs_total{status="ok"}'] == 1.0
    assert samples["queue_depth"] == 7.0
    assert samples['latency_seconds_bucket{le="0.1"}'] == 1.0
    assert samples['latency_seconds_bucket{le="+Inf"}'] == 4.0
    assert samples["latency_seconds_count"] == 4.0
    assert samples["latency_seconds_sum"] == pytest.approx(55.55)


def test_metrics_registry_label_isolation_and_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("hits_total", "hits", ("shard",))

    def worker(shard):
        for _ in range(500):
            c.inc(shard=shard)

    threads = [threading.Thread(target=worker, args=(f"s{i}",))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(4):
        assert c.value(shard=f"s{i}") == 500
    # re-registering the same family returns the same collector
    assert reg.counter("hits_total", "hits", ("shard",)) is c


def test_parse_exposition_rejects_garbage():
    with pytest.raises(ValueError):
        parse_exposition("this is not prometheus\n")
    with pytest.raises(ValueError):
        parse_exposition("# TYPE x bogus_kind\nx 1\n")


# ---------------------------------------------------------------------------
# ANN retrieval: sampled exact re-scans
# ---------------------------------------------------------------------------


def _vectors(n=600, d=24, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_exact_topk_matches_flat_index():
    x = _vectors()
    q = x[:5] + 0.01
    es, ei = VectorIndex(x).search(q, 8)
    s2, i2 = exact_topk(x, q, 8)
    np.testing.assert_allclose(np.sort(s2, axis=1), np.sort(es, axis=1),
                               atol=1e-5)


def test_ivf_search_audit_estimates_recall():
    x = _vectors()
    q = _vectors(40, seed=9)
    policy = A.AuditPolicy(search_sample_fraction=1.0, min_search_samples=16,
                           seed=2)
    events = []
    aud = A.GuaranteeAuditor(None, policy=policy, on_violation=events.append)
    # well-probed index: high recall, no violation
    good = IVFIndex(x, n_clusters=16, recall_target=0.5, seed=1)
    with A.activate_ctx(aud):
        good.search(q, 10, nprobe=16)
    assert aud.drain()
    est = {e["key"]: e for e in aud.report()["searches"]}
    ci = est["ivf"]["recall_at_k"]
    assert ci is not None and ci["point"] > 0.95 and not events
    aud.close()


def test_ivf_starved_probe_fires_recall_violation():
    x = _vectors()
    q = _vectors(60, seed=11)
    events = []
    aud = A.GuaranteeAuditor(
        None, policy=A.AuditPolicy(search_sample_fraction=1.0,
                                   min_search_samples=16, seed=2),
        on_violation=events.append)
    starved = IVFIndex(x, n_clusters=50, nprobe=1, recall_target=0.95, seed=3)
    with A.activate_ctx(aud):
        starved.search(q, 20)
    assert aud.drain()
    assert any(e.kind == "recall_at_k" for e in events)
    ev = next(e for e in events if e.kind == "recall_at_k")
    assert ev.lower < 0.95 and ev.operator == "Search"
    aud.close()


def test_ivf_delta_and_int8_paths_are_audited():
    x = _vectors()
    q = _vectors(30, seed=13)
    aud = A.GuaranteeAuditor(
        None, policy=A.AuditPolicy(search_sample_fraction=1.0, seed=2))
    idx = IVFIndex(x[:500], n_clusters=12, recall_target=0.5, seed=4,
                   quantize="int8")
    idx.add(x[500:])                       # rows land in the delta buffer
    with A.activate_ctx(aud):
        idx.search(q, 10, nprobe=12)
    assert aud.drain()
    est = {e["key"]: e for e in aud.report()["searches"]}
    assert "ivf/int8" in est               # quantized path keyed separately
    assert est["ivf/int8"]["queries_audited"] == 30
    aud.close()


# ---------------------------------------------------------------------------
# satellite 2: corrupt/truncated state files are log-and-continue
# ---------------------------------------------------------------------------


def test_stats_store_load_corrupt_files(tmp_path):
    s = StatsStore()
    # missing file
    assert s.load(tmp_path / "nope.json") == 0
    # empty + truncated + garbage
    for name, payload in [("empty.json", b""),
                          ("garbage.json", b"\x00\xffnot json"),
                          ("truncated.json", b'{"entries": [{"fingerprint"')]:
        p = tmp_path / name
        p.write_bytes(payload)
        assert s.load(p) == 0
        with pytest.raises(Exception):
            s.load(p, strict=True)
    # malformed entries inside a valid document are skipped, good ones kept
    doc = {"entries": [
        {"fingerprint": "good", "operator": "Filter", "runs": 3,
         "rows_in": 10.0, "rows_out": 4.0, "oracle_calls": 5.0,
         "wall_s": 0.1},
        "not-a-dict",
        {"operator": "Filter"},            # no fingerprint
    ]}
    p = tmp_path / "mixed.json"
    p.write_text(json.dumps(doc))
    loaded = s.load(p)
    assert loaded == 1 and s.get("Filter", "good") is not None
    assert s.get("Filter", "good").oracle_calls == 5


def test_auditor_state_roundtrip_and_corrupt_load(tmp_path):
    records, world, drifted, oracle, proxy = _filter_worlds()
    path = str(tmp_path / "audit.json")
    aud = A.GuaranteeAuditor(
        synth.SimulatedModel(world, "oracle"), path=path,
        policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8, seed=1))
    with A.activate_ctx(aud):
        sem_filter_cascade(records, "{claim} holds", oracle, proxy,
                           recall_target=0.9, precision_target=0.9,
                           delta=0.2, sample_size=100, seed=3)
    aud.close()                            # drains and persists
    audited = aud.report()["cascades"][0]["audited"]
    assert audited > 0
    # a fresh auditor resumes the accumulators from disk
    aud2 = A.GuaranteeAuditor(synth.SimulatedModel(world, "oracle"),
                              path=path)
    assert aud2.report()["cascades"][0]["audited"] == audited
    aud2.close()
    # corrupt state file: fresh start, no raise
    with open(path, "w") as f:
        f.write('{"cascades": [{"oper')
    aud3 = A.GuaranteeAuditor(synth.SimulatedModel(world, "oracle"),
                              path=path)
    assert aud3.report()["cascades"] == []
    with pytest.raises(Exception):
        aud3.load(path, strict=True)
    aud3.close()


# ---------------------------------------------------------------------------
# explain_analyze integration
# ---------------------------------------------------------------------------


def test_explain_analyze_shows_audited_ci_next_to_tau():
    records, world, *_ = _filter_worlds(n=300)
    sess = _gw_session(world)
    aud = A.GuaranteeAuditor(
        synth.SimulatedModel(world, "oracle"),
        policy=A.AuditPolicy(sample_fraction=1.0, min_samples=8, seed=1))
    frame = _cascade_pipeline(records, sess)
    rep = explain_analyze(frame, auditor=aud)
    text = rep.render()
    filt = next(r for r in rep.nodes if type(r.node).__name__ == "Filter")
    assert filt.audit is not None and filt.audit["precision"] is not None
    assert filt.observed.get("tau_plus") is not None
    assert "tau " in text and "audit P~" in text and "n=" in text
    aud.close()
