"""Streaming-subsystem tests: CorpusTable versioning/delta log, incremental
index maintenance (exact append, IVF delta buffer + drift retrain), the
versioned IndexRegistry reuse path, continuous queries through the gateway
(delta-only oracle traffic, record-identity vs from-scratch), and the
satellite fixes (store log compaction, registry eviction pin/latch release,
nprobe interpolation).
"""
import gc
import os
import threading
import weakref

import numpy as np
import pytest

from repro.core.backends import synth
from repro.core.backends.testing import CountingBackend
from repro.core.frame import SemFrame, Session
from repro.core.plan import nodes as N
from repro.index import IVFIndex, VectorIndex, build_index, nprobe_for_recall
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.serve import Gateway, IndexRegistry, SharedSemanticCache
from repro.stream import CorpusTable, pin_stream_scans


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _clustered(n, d=32, n_centers=16, noise=0.15, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    lab = rng.integers(n_centers, size=n)
    x = centers[lab] + noise * rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return np.asarray(x, np.float32)


class _LookupEmbedder:
    """texts are integer strings indexing a fixed vector matrix."""

    index_key = "lookup@test"

    def __init__(self, vectors):
        self.vectors = vectors
        self.calls = 0

    @property
    def dim(self):
        return self.vectors.shape[1]

    def embed(self, texts):
        self.calls += len(texts)
        return self.vectors[[int(t) for t in texts]]


def _filter_world(n=40, seed=7):
    records, world, *_ = synth.make_filter_world(n, seed=seed)
    return records, world


def _new_rows(world, start, n, *, rate=0.5, seed=123):
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(start, start + n):
        rid = f"claim{i}"
        world.filter_truth[rid] = bool(rng.random() < rate)
        rows.append({"id": rid, "claim": f"claim text {i} {synth.tag(rid)}"})
    return rows


# ---------------------------------------------------------------------------
# CorpusTable: versions, snapshots, delta log
# ---------------------------------------------------------------------------


def test_table_versions_snapshots_and_delta():
    t = CorpusTable([{"a": 1}, {"a": 2}])
    assert t.version == 1 and len(t) == 2
    v2 = t.append([{"a": 3}])
    rid = t.row_ids()[0]
    v3 = t.update(rid, {"a": 10})
    v4 = t.delete(t.row_ids()[1])
    assert (v2, v3, v4) == (2, 3, 4)
    # historical snapshots replay the log exactly
    assert [r["a"] for r in t.snapshot(1)] == [1, 2]
    assert [r["a"] for r in t.snapshot(2)] == [1, 2, 3]
    assert [r["a"] for r in t.snapshot(3)] == [10, 2, 3]
    assert [r["a"] for r in t.snapshot()] == [10, 3]
    # net delta over the whole range: the updated row, the deleted row, the
    # appended row
    d = t.delta(1)
    assert [r["a"] for _, r in d.added] == [3]
    assert [r["a"] for _, r in d.updated] == [10]
    assert len(d.deleted) == 1 and not d.appends_only
    # appends-only window satisfies the alignment contract
    d12 = t.delta(1, 2)
    assert d12.appends_only
    assert t.snapshot(2) == t.snapshot(1) + [r for _, r in d12.added]


def test_table_add_then_delete_cancels_and_listeners_fire():
    t = CorpusTable([{"a": 1}])
    seen = []
    t.add_listener(seen.append)
    v = t.append([{"a": 2}])
    rid = t.row_ids()[-1]
    t.delete(rid)
    d = t.delta(v - 1)
    assert not d.added and not d.deleted and not d.updated  # net no-op
    assert seen == [2, 3]
    t.remove_listener(seen.append)
    t.append([{"a": 4}])
    assert seen == [2, 3]


# ---------------------------------------------------------------------------
# incremental index maintenance
# ---------------------------------------------------------------------------


def test_exact_index_add_matches_fresh_build():
    x = _clustered(600, seed=1)
    base = VectorIndex(x[:500])
    base.add(x[500:])
    fresh = VectorIndex(x)
    q = x[500:508] + 0.01
    s1, i1 = base.search(q, 10)
    s2, i2 = fresh.search(q, 10)
    assert np.array_equal(i1, i2) and np.allclose(s1, s2)


def test_ivf_append_search_recall_contract():
    x = _clustered(4400, seed=2)
    ivf = IVFIndex(x[:4000], seed=3, retrain="off")
    ivf.add(x[4000:])
    assert ivf.delta_rows == 400 and ivf.drift() == pytest.approx(0.1)
    q = x[4000:4016] + 0.01
    _, ei = VectorIndex(x).search(q, 10)
    _, ii = ivf.search(q, 10)
    recall = np.mean([len(set(ei[r]) & set(ii[r])) / 10 for r in range(len(q))])
    # delta rows are exact-scanned: queries near them must recover them
    assert recall >= 0.95
    st = ivf.last_stats
    assert st["delta_rows"] == 400 and st["delta_scored"] == len(q) * 400
    assert st["scored_vectors"] < len(q) * len(x)  # still pruned vs exact


def test_ivf_degenerate_with_delta_is_exact():
    x = _clustered(1000, seed=5)
    ivf = IVFIndex(x[:900], n_clusters=24, seed=5, retrain="off")
    ivf.add(x[900:])
    q = x[::173][:6] + 0.01
    _, de = VectorIndex(x).search(q, 8)
    _, dv = ivf.search(q, 8, nprobe=ivf.n_clusters)
    assert np.array_equal(de, dv)


def test_ivf_spill_then_retrain_equivalent_to_fresh_build():
    x = _clustered(3000, seed=4)
    ivf = IVFIndex(x[:2500], seed=9, retrain="sync", spill_threshold=0.10)
    ivf.add(x[2500:])                       # 20% spill -> sync retrain
    assert ivf.retrains == 1 and ivf.delta_rows == 0
    fresh = IVFIndex(x, seed=9)
    assert np.allclose(ivf.centroids, fresh.centroids)
    assert np.array_equal(ivf.assign, fresh.assign)
    q = x[::311][:8] + 0.01
    s1, i1 = ivf.search(q, 10)
    s2, i2 = fresh.search(q, 10)
    assert np.array_equal(i1, i2) and np.allclose(s1, s2)


def test_ivf_background_retrain_swaps_atomically():
    x = _clustered(3000, seed=6)
    ivf = IVFIndex(x[:2500], seed=6, retrain="background",
                   spill_threshold=0.10)
    ivf.add(x[2500:])
    ivf.wait_retrain(timeout=60.0)
    assert ivf.retrains == 1 and ivf.delta_rows == 0
    _, i1 = ivf.search(x[:4] + 0.01, 5)
    _, i2 = IVFIndex(x, seed=6).search(x[:4] + 0.01, 5)
    assert np.array_equal(i1, i2)


def test_search_max_pos_cutoff_bounds_results_to_snapshot():
    x = _clustered(1000, seed=19)
    exact_prefix = VectorIndex(x[:700])
    q = x[690:698] + 0.01
    se, ie = exact_prefix.search(q, 8)
    # exact: cutoff == searching the prefix corpus
    full = VectorIndex(x)
    sc, ic = full.search(q, 8, max_pos=700)
    assert np.array_equal(ic, ie) and np.allclose(sc, se)
    # IVF degenerate (nprobe=all, delta buffer included): cutoff == exact
    # over the prefix
    ivf = IVFIndex(x[:900], n_clusters=16, seed=19, retrain="off")
    ivf.add(x[900:])
    si, ii = ivf.search(q, 8, nprobe=ivf.n_clusters, max_pos=700)
    assert np.array_equal(ii, ie)
    assert (ii < 700).all()


def test_max_pos_probe_floor_still_yields_k_results():
    # delta rows beyond the cutoff must not count toward the k-candidate
    # probe floor: a version-pinned search still has to fill k slots from
    # the main store
    from repro.index.backend import MASKED_SCORE
    x = _clustered(60, n_centers=10, seed=23)
    ivf = IVFIndex(x[:50], n_clusters=10, nprobe=1, seed=23, retrain="off")
    ivf.add(x[50:])                               # nd = 10 = k
    s, i = ivf.search(x[:4] + 0.01, 10, max_pos=50)
    assert (i < 50).all()
    assert (s > MASKED_SCORE / 2).all()           # every slot filled
    assert all(len(set(row.tolist())) == 10 for row in i)


def test_ivf_delta_search_matches_jnp_reference():
    x = _clustered(1200, seed=8)
    ivf = IVFIndex(x[:1000], n_clusters=16, seed=8, retrain="off")
    ivf.add(x[1000:])
    q = x[:5] + 0.02
    s_op, p_op = kops.ivf_delta_search(
        q, ivf.centroids, ivf.store, ivf.store_mask, ivf._delta_unit,
        nprobe=4, block_q=ivf.block_q)
    s_ref, p_ref = ref.ivf_delta_search_ref(
        q, ivf.centroids, ivf.store, ivf.store_mask, ivf._delta_unit,
        nprobe=4, block_q=ivf.block_q)
    assert np.array_equal(p_op, np.asarray(p_ref))
    np.testing.assert_allclose(s_op, np.asarray(s_ref), rtol=1e-5, atol=1e-5)


def test_ivf_save_load_preserves_delta_buffer(tmp_path):
    x = _clustered(1200, seed=11)
    ivf = IVFIndex(x[:1000], n_clusters=16, seed=11, retrain="off")
    ivf.add(x[1000:])
    path = os.path.join(tmp_path, "ivf")
    ivf.save(path)
    from repro.index import load_index
    back = load_index(path)
    assert isinstance(back, IVFIndex)
    assert back.delta_rows == 200 and len(back) == 1200
    q = x[1000:1004] + 0.01
    s1, i1 = ivf.search(q, 6)
    s2, i2 = back.search(q, 6)
    assert np.array_equal(i1, i2) and np.allclose(s1, s2)


# ---------------------------------------------------------------------------
# versioned IndexRegistry
# ---------------------------------------------------------------------------


def _reg_fixture(n=800, n_delta=80, seed=13):
    x = _clustered(n + n_delta, seed=seed)
    emb = _LookupEmbedder(x)
    table = CorpusTable([{"t": str(i)} for i in range(n)])
    reg = IndexRegistry()

    def builder(records):
        return build_index(emb.embed([r["t"] for r in records]), kind="exact")

    def updater(index, added):
        index.add(emb.embed([r["t"] for r in added]))

    return x, emb, table, reg, builder, updater


def test_registry_applies_only_the_delta_on_append():
    x, emb, table, reg, builder, updater = _reg_fixture()
    i0 = reg.get_or_update(table, emb, kind="exact", builder=builder,
                           updater=updater)
    assert emb.calls == 800
    table.append([{"t": str(i)} for i in range(800, 880)])
    i1 = reg.get_or_update(table, emb, kind="exact", builder=builder,
                           updater=updater)
    assert i1 is i0 and len(i1) == 880
    assert emb.calls == 880                     # delta rows only
    m = reg.metrics()
    assert m["index_builds"] == 1 and m["index_updates"] == 1
    assert m["index_delta_rows"] == 80
    # delta results match a fresh build (exact backend: identical)
    fresh = VectorIndex(x[:880])
    q = x[800:804]
    assert np.array_equal(i1.search(q, 5)[1], fresh.search(q, 5)[1])


def test_stream_key_stable_as_corpus_grows():
    # the size-derived auto nprobe must NOT land in the stream key: corpus
    # growth would churn the key and turn every append into a full rebuild
    records, world = _filter_world(40, seed=33)
    table = CorpusTable(records)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    with Gateway(sess, max_inflight=1,
                 optimizer_kw={"index_min_corpus": 10}) as gw:
        q = "claim text 3"
        gw.submit(table.lazy(sess).sem_search("claim", q, k=3,
                                              index_kind="ivf")
                  ).result(timeout=120)
        table.append(_new_rows(world, 40, 25, seed=44))   # sqrt(n) shifts
        gw.submit(table.lazy(sess).sem_search("claim", q, k=3,
                                              index_kind="ivf")
                  ).result(timeout=120)
        m = gw.snapshot()
        assert m["index_builds"] == 1 and m["index_updates"] == 1
        assert m["index_delta_rows"] == 25


def test_registry_rebuilds_on_update_or_delete():
    _, emb, table, reg, builder, updater = _reg_fixture()
    reg.get_or_update(table, emb, kind="exact", builder=builder, updater=updater)
    table.update(table.row_ids()[0], {"t": "7"})
    i1 = reg.get_or_update(table, emb, kind="exact", builder=builder,
                           updater=updater)
    assert reg.metrics()["index_builds"] == 2 and len(i1) == 800


def test_registry_pinned_old_version_never_sees_future_rows():
    _, emb, table, reg, builder, updater = _reg_fixture()
    v0 = table.version
    reg.get_or_update(table, emb, kind="exact", builder=builder, updater=updater)
    table.append([{"t": str(i)} for i in range(800, 880)])
    reg.get_or_update(table, emb, kind="exact", builder=builder, updater=updater)
    old = reg.get_or_update(table, emb, kind="exact", builder=builder,
                            updater=updater, version=v0)
    assert len(old) == 800                      # fresh, uncached, at v0
    assert reg.metrics()["index_stale_misses"] == 1


def test_registry_one_update_under_concurrent_sessions():
    _, emb, table, reg, builder, updater = _reg_fixture()
    reg.get_or_update(table, emb, kind="exact", builder=builder, updater=updater)
    table.append([{"t": str(i)} for i in range(800, 880)])
    gate = threading.Event()
    applied = []

    def slow_updater(index, added):
        gate.wait(5.0)
        applied.append(len(added))
        updater(index, added)

    results = [None] * 6

    def worker(i):
        results[i] = reg.get_or_update(table, emb, kind="exact",
                                       builder=builder, updater=slow_updater)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    gate.set()
    for t in threads:
        t.join()
    assert applied == [80]                      # exactly one delta application
    assert all(r is results[0] for r in results)
    assert reg.metrics()["index_updates"] == 1


def test_registry_eviction_releases_pins_and_latches():
    reg = IndexRegistry(capacity=2)
    x = _clustered(64, seed=17)

    class Emb:
        def __init__(self, key):
            self.index_key = key

    embs = [Emb(f"e{i}") for i in range(4)]
    for i, e in enumerate(embs):
        reg.get_or_build([f"t{i}"], e, kind="exact",
                         builder=lambda: VectorIndex(x))
    assert reg.metrics()["indexes_resident"] == 2
    assert reg.metrics()["index_evictions"] == 2
    # evicted keys must not keep their embedder pinned or a stale latch
    assert len(reg._pins) == 2 and not reg._building
    live_keys = set(reg._indexes)
    assert set(reg._pins) == live_keys
    # the evicted embedders are collectable (no registry pin holds them)
    refs = [weakref.ref(e) for e in embs[:2]]
    del embs
    gc.collect()
    assert all(r() is None for r in refs)
    reg.clear()
    assert not reg._pins and not reg._versions and not reg._building


# ---------------------------------------------------------------------------
# satellite: nprobe interpolation
# ---------------------------------------------------------------------------


def test_nprobe_for_recall_interpolates_between_calibration_points():
    # calibration points themselves are unchanged
    assert nprobe_for_recall(200, 0.95) == 20     # 0.10 * 200
    assert nprobe_for_recall(200, 0.90) == 10     # 0.05 * 200
    # between points: linear, not a jump to the next point's fraction
    mid = nprobe_for_recall(200, 0.91)
    assert 10 < mid < 20
    assert mid == 12                              # 0.05 + 0.2*(0.10-0.05) = 0.06
    # monotone over a fine sweep, every cluster at 1.0
    sweep = [nprobe_for_recall(200, r) for r in np.linspace(0.5, 0.999, 40)]
    assert sweep == sorted(sweep)
    assert nprobe_for_recall(200, 1.0) == 200


# ---------------------------------------------------------------------------
# satellite: store log compaction
# ---------------------------------------------------------------------------


def _lines(path):
    with open(path) as fh:
        return [line for line in fh if line.strip()]


def test_store_compacts_dead_log_on_close(tmp_path):
    path = os.path.join(tmp_path, "cache.jsonl")
    store = SharedSemanticCache(persist_path=path)
    keys = [("oracle", "predicate", f"p{i}") for i in range(10)]
    for round_ in range(5):                     # 4 dead lines per key
        store.put_many(keys, [[True, float(round_)]] * len(keys), owner="s1")
    store.flush()
    assert len(_lines(path)) == 50
    store.close()
    assert store.compactions == 1
    lines = _lines(path)
    assert len(lines) == 10                     # live entries only
    # a reload serves the latest values
    back = SharedSemanticCache(persist_path=path)
    got = back.get_many(keys)
    assert all(hit for hit, _ in got)
    assert all(row == [True, 4.0] for _, row in got)
    back.close()
    assert back.compactions == 0                # nothing dead: no rewrite


def test_store_close_without_dead_majority_keeps_log(tmp_path):
    path = os.path.join(tmp_path, "cache.jsonl")
    store = SharedSemanticCache(persist_path=path)
    keys = [("oracle", "predicate", f"p{i}") for i in range(6)]
    store.put_many(keys, [[True, 1.0]] * 6, owner="s1")
    store.put(keys[0], [False, 0.0], owner="s1")   # 1 dead of 7: live majority
    store.close()
    assert store.compactions == 0 and len(_lines(path)) == 7


# ---------------------------------------------------------------------------
# continuous queries through the gateway
# ---------------------------------------------------------------------------


def test_subscription_emits_initial_and_delta_only_oracle_traffic():
    records, world = _filter_world(40)
    table = CorpusTable(records)
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    sess = Session(oracle=backend, embedder=synth.SimulatedEmbedder(world))
    with Gateway(sess, max_inflight=2) as gw:
        sub = gw.subscribe(table.lazy(sess)
                           .sem_filter("the {claim} is supported"))
        em0 = sub.poll(timeout=60)
        assert em0.error is None and em0.version == 1
        assert backend.n_prompts == 40
        table.append(_new_rows(world, 40, 10))
        em1 = sub.poll(timeout=60)
        assert em1.error is None and em1.version == 2
        # monotone op: only the 10 delta rows reach the oracle; the shared
        # cache covers every already-judged row
        assert backend.n_prompts == 50
        new_tags = {synth.tag(f"claim{i}") for i in range(40, 50)}
        late = [p for b in backend.batches[1:] for p in b]
        assert late and all(any(t in p for t in new_tags) for p in late)
        # emitted records are identical to a from-scratch run at v2
        fresh_sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                             embedder=synth.SimulatedEmbedder(world))
        fresh = SemFrame(table.snapshot(), fresh_sess).sem_filter(
            "the {claim} is supported")
        assert em1.records == fresh.records
        assert set(map(str, em1.added)) <= set(map(str, fresh.records))
        snap = gw.snapshot()
        assert snap["subscriptions"] == 1 and snap["emissions"] == 2


def test_subscription_update_and_delete_reflected_in_emissions():
    records, world = _filter_world(20, seed=9)
    # make row 0 pass so we can watch it disappear
    world.filter_truth["claim0"] = True
    world.filter_truth["claim1"] = True
    table = CorpusTable(records)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    with Gateway(sess, max_inflight=1) as gw:
        sub = gw.subscribe(table.lazy(sess)
                           .sem_filter("the {claim} is supported"))
        em0 = sub.poll(timeout=60)
        assert any(r["id"] == "claim0" for r in em0.records)
        table.delete(table.row_ids()[0])        # drop claim0
        em1 = sub.poll(timeout=60)
        assert not any(r["id"] == "claim0" for r in em1.records)
        assert any(r["id"] == "claim0" for r in em1.removed)
        # records still identical to a from-scratch run after the delete
        fresh_sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                             embedder=synth.SimulatedEmbedder(world))
        fresh = SemFrame(table.snapshot(), fresh_sess).sem_filter(
            "the {claim} is supported")
        assert em1.records == fresh.records


def test_subscription_coalesces_rapid_commits():
    records, world = _filter_world(16, seed=4)
    table = CorpusTable(records)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    with Gateway(sess, max_inflight=1) as gw:
        sub = gw.subscribe(table.lazy(sess)
                           .sem_filter("the {claim} is supported"),
                           emit_initial=False)
        for i in range(5):                      # 5 commits in a burst
            table.append(_new_rows(world, 16 + i, 1, seed=100 + i))
        # the subscription catches up to the LATEST version; burst commits
        # coalesce instead of producing one emission each
        deadline_emissions = []
        em = sub.poll(timeout=60)
        while em is not None:
            deadline_emissions.append(em)
            if em.version == table.version:
                break
            em = sub.poll(timeout=60)
        assert deadline_emissions[-1].version == table.version
        assert len(deadline_emissions) <= 5
        assert len(deadline_emissions[-1].records) >= 0
        sub.cancel()
        assert sub.cancelled


def test_subscription_cancel_discards_gateway_reference():
    records, world = _filter_world(8, seed=2)
    table = CorpusTable(records)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"))
    with Gateway(sess, max_inflight=1) as gw:
        sub = gw.subscribe(table.lazy(sess)
                           .sem_filter("the {claim} is supported"),
                           emit_initial=False)
        assert sub in gw._subscriptions
        sub.cancel()
        assert sub not in gw._subscriptions       # no leak across cycles


def test_subscription_requires_a_stream_scan():
    records, world = _filter_world(8)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"))
    with Gateway(sess, max_inflight=1) as gw:
        with pytest.raises(ValueError, match="CorpusTable"):
            gw.subscribe(SemFrame(records, sess).lazy()
                         .sem_filter("the {claim} is supported"))


def test_pin_stream_scans_freezes_floating_versions():
    records, _ = _filter_world(6)
    table = CorpusTable(records)
    plan = N.Filter(N.StreamScan(table), "the {claim} is supported")
    pinned = pin_stream_scans(plan)
    assert pinned.child.version == table.version
    table.append([{"id": "x", "claim": "x"}])
    assert pinned.child.version == table.version - 1   # still the old pin
    repinned = pin_stream_scans(plan, {table.table_id: table.version})
    assert repinned.child.version == table.version
    assert len(pinned.child.records) == 6
    assert len(repinned.child.records) == 7


# ---------------------------------------------------------------------------
# executor delta routing: stream search through the versioned registry
# ---------------------------------------------------------------------------


def test_stream_search_reuses_base_index_and_embeds_only_delta():
    n, nd = 60, 12
    records, world = _filter_world(n, seed=21)
    table = CorpusTable(records)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    with Gateway(sess, max_inflight=1) as gw:
        q = "claim text 3"
        h0 = gw.submit(table.lazy(sess).sem_search("claim", q, k=5,
                                                   index_kind="exact"))
        r0 = h0.result(timeout=120)
        assert len(r0) == 5
        m0 = gw.snapshot()
        assert m0["index_builds"] == 1 and m0["index_updates"] == 0
        table.append(_new_rows(world, n, nd, seed=77))
        h1 = gw.submit(table.lazy(sess).sem_search("claim", q, k=5,
                                                   index_kind="exact"))
        r1 = h1.result(timeout=120)
        assert len(r1) == 5
        m1 = gw.snapshot()
        # appended corpus re-used the base index: delta rows only
        assert m1["index_builds"] == 1 and m1["index_updates"] == 1
        assert m1["index_delta_rows"] == nd
        # result identical to a frozen-corpus run of the same search
        frozen = SemFrame(table.snapshot(), sess).sem_search(
            "claim", q, k=5, index_kind="exact")
        assert [r["id"] for r in r1] == [r["id"] for r in frozen.records]
