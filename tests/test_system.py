"""End-to-end system tests: full LOTUS pipelines over (a) simulated worlds and
(b) the real JAX serving stack (random weights — validates the dataflow the
paper runs on vLLM: batched prefill/decode, logprob proxy scores, cascades,
vector search), mirroring the paper's applications.
"""

from repro.core import accounting
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session


def test_factcheck_pipeline_map_search_filter():
    """Table 2 analogue: map -> search -> filter beats naive scan cost."""
    records, world, oracle, proxy, emb = synth.make_filter_world(
        300, positive_rate=0.45, proxy_alpha=2.5, seed=42)
    # certification under the Wilson-guarded bounds needs ~50 observed
    # positives for a 0.9 recall target (see core/optimizer/stats.py)
    sess = Session(oracle=oracle, proxy=proxy, embedder=emb, sample_size=150)
    claims = SemFrame(records, sess)
    # map: claims -> search queries (row-wise projection)
    mapped = claims.sem_map("write a search query for {claim}", out_column="query")
    assert len(mapped) == 300 and all(t["query"] for t in mapped.records)
    # filter with guarantees (the FacTool verification step)
    verdicts = mapped.sem_filter("the {claim} is supported",
                                 recall_target=0.9, precision_target=0.9, delta=0.2)
    st = mapped.last_stats()
    assert st["oracle_calls"] < 300          # cascade saved oracle calls
    gold = claims.sem_filter("the {claim} is supported")
    inter = len({t["id"] for t in verdicts.records} & {t["id"] for t in gold.records})
    assert inter / max(len(gold), 1) > 0.7   # loose single-trial sanity


def test_biodex_pipeline_join_rank():
    """Table 3 analogue: extreme multilabel via optimized join + ranking."""
    left, right, world, oracle, proxy, emb = synth.make_join_world(
        40, 30, labels_per_left=2, sim_correlation=0.0, seed=43)
    sess = Session(oracle=oracle, proxy=proxy, embedder=emb, sample_size=150)
    articles = SemFrame(left, sess)
    matched = articles.sem_join(right, "the {abstract} reports the {reaction:right}",
                                recall_target=0.8, precision_target=0.8, delta=0.2)
    st = articles.last_stats()
    assert st["lm_calls"] < 40 * 30          # far below the quadratic gold cost
    assert st["plan"] in ("sim-filter", "project-sim-filter")


def test_topic_analysis_pipeline():
    """Fig 7/8 analogue: group-by + per-group aggregation."""
    records, world, model, emb = synth.make_topic_world(150, 4, seed=44)
    sess = Session(oracle=model, embedder=emb, sample_size=60)
    papers = SemFrame(records, sess)
    grouped = papers.sem_group_by("the topic of each {paper}", 4,
                                  accuracy_target=0.85, delta=0.2)
    assert {t["group"] for t in grouped.records} <= set(range(4))
    summaries = grouped.sem_agg("summarize: {paper}", group_by="group_label")
    assert all(isinstance(v, str) and v for v in summaries.values())


def test_ranking_pipeline_with_pivot_opt():
    records, world, model, emb, piv = synth.make_rank_world(80, seed=45)
    sess = Session(oracle=model, embedder=emb)
    papers = SemFrame(records, sess)
    top = papers.sem_topk("the {abstract} reports the highest accuracy", 10,
                          pivot_query="highest accuracy")
    truth = sorted(records, key=lambda t: -world.rank_value[t["id"]])[:10]
    overlap = len({t["id"] for t in top.records} & {t["id"] for t in truth})
    assert overlap >= 7


def test_nested_accounting_rolls_up():
    records, world, model, emb = synth.make_topic_world(40, 3, seed=46)
    sess = Session(oracle=model, embedder=emb)
    with accounting.track("outer") as outer:
        SemFrame(records, sess).sem_map("label {paper}")
    assert outer.generate_calls == 40


def test_full_jax_stack_pipeline():
    """The paper's dataflow on the real substrate: engine-served oracle/proxy
    LLMs + encoder embedder (random weights; checks plumbing, not accuracy)."""
    from repro.core.backends.jax_engine import make_session
    sess = make_session(max_seq=192)
    records = [{"claim": f"statement number {i} about thing {i % 5}"} for i in range(12)]
    sf = SemFrame(records, sess)
    gold = sf.sem_filter("the {claim} is plausible")
    assert sf.last_stats()["oracle_calls"] == 12
    opt = sf.sem_filter("the {claim} is plausible",
                        recall_target=0.8, precision_target=0.8, delta=0.3)
    st = sf.last_stats()
    assert st["proxy_calls"] == 12           # proxy scored every tuple
    assert 0 < st["oracle_calls"] <= 12
    mapped = sf.sem_map("shorten {claim}")
    assert all(isinstance(t["mapped"], str) for t in mapped.records)
    idx = sf.sem_index("claim")
    hits = sf.sem_search("claim", "statement number 3", k=2, index=idx)
    assert len(hits) == 2
