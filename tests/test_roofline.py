"""HLO analyzer calibration: exact FLOP counting through scan loops (the
whole reason hlo_analysis exists — XLA's cost_analysis does not multiply
while-loop trip counts), byte/collective parsing, roofline terms.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import roofline
from repro.launch.hlo_analysis import analyze_text, shape_bytes

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _flops_of(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_text(c.as_text()).flops, c


def test_plain_matmul_exact():
    A = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    flops, _ = _flops_of(lambda a, b: a @ b, A, A)
    assert flops == 2 * 256 ** 3


def test_scan_trip_counts_multiplied():
    A = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(a, w):
        x, _ = jax.lax.scan(lambda x, _: (x @ w, None), a, None, length=12)
        return x

    flops, c = _flops_of(scanned, A, A)
    assert flops == 12 * 2 * 128 ** 3
    # document the XLA undercount this module corrects for:
    xla = float(roofline.xla_cost_analysis(c).get("flops", 0.0))
    assert xla < flops / 5


def test_nested_scan_trips():
    A = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(a, w):
        def outer(x, _):
            y, _ = jax.lax.scan(lambda x, _: (x @ w, None), x, None, length=5)
            return y, None
        x, _ = jax.lax.scan(outer, a, None, length=4)
        return x

    flops, _ = _flops_of(nested, A, A)
    assert flops == 20 * 2 * 64 ** 3


def test_shape_bytes_parsing():
    assert shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert shape_bytes("pred[16]") == 16
    assert shape_bytes("token[]") == 0


def test_collective_bytes_multi_device_subprocess():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_text
        from repro.dist.sharding import set_mesh
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((8,), ("d",))
        x = jax.ShapeDtypeStruct((1024, 64), jnp.float32)
        with set_mesh(mesh):
            # contraction over the sharded dim forces an all-reduce
            c = jax.jit(lambda a: (a * a).sum(),
                        in_shardings=NamedSharding(mesh, P("d", None))).lower(x).compile()
        costs = analyze_text(c.as_text())
        assert costs.coll.get("all-reduce", 0) > 0, costs.coll
        print("OK")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH=SRC), timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_roofline_terms_and_bottleneck():
    rl = roofline.Roofline(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops_per_dev=197e12,          # exactly 1s of compute
        hlo_bytes_per_dev=819e9 * 0.5,     # 0.5s of memory
        coll_bytes_per_dev=50e9 * 0.25,    # 0.25s of collectives
        model_flops=256 * 197e12 * 0.5, mem_per_dev={}, coll_breakdown={})
    assert rl.bottleneck == "compute"
    assert abs(rl.step_time - 1.0) < 1e-9
    assert abs(rl.mfu - 0.5) < 1e-9
    assert abs(rl.useful_flops_ratio - 0.5) < 1e-9


def test_flash_adjustment_reduces_memory_term():
    rl = roofline.Roofline(
        arch="x", shape="prefill_32k", mesh="single", chips=256,
        hlo_flops_per_dev=1e12, hlo_bytes_per_dev=1e12,
        coll_bytes_per_dev=0.0, model_flops=1e14, mem_per_dev={},
        coll_breakdown={}, scopes={"attn_core": [5e11, 9e11]}, seq_len=32768)
    assert rl.flash_adjusted_bytes < rl.hlo_bytes_per_dev
    assert rl.t_memory_flash < rl.t_memory


def test_model_flops_for_cell():
    from repro.configs import SHAPES, get_config
    cfg = get_config("llama3.2-3b")
    f_train = roofline.model_flops_for_cell(cfg, SHAPES["train_4k"])
    f_dec = roofline.model_flops_for_cell(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert abs(f_train - 6 * n * 256 * 4096) / f_train < 1e-9
    assert abs(f_dec - 2 * n * 128) / f_dec < 1e-9
