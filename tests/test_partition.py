"""Partitioned parallel execution: guarantee preservation across the stack.

The contract under test is the tentpole's: fragmentation redistributes
*work*, never *results*.  Partitioned filter cascades learn the same
thresholds and pass-set as the unpartitioned run (one global importance
sample); partitioned top-k / agg / join are record-identical; sharded
similarity retrieval (jnp contract on one device, shard_map in a forced
multi-device subprocess) matches the exact scan; and the comparator's
in-batch dedup never re-prompts a repeated or mirrored pair.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.core.operators.topk import _Comparator, sem_topk_partitioned
from repro.core.plan import nodes as N
from repro.core.plan import parallel
from repro.core.plan.optimize import PlanOptimizer, explain_plan
from repro.kernels import ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _session(world, *, with_proxy=False, log=None, sample_size=40):
    return Session(
        oracle=synth.SimulatedModel(world, "oracle"),
        proxy=synth.SimulatedModel(world, "proxy") if with_proxy else None,
        embedder=synth.SimulatedEmbedder(world), sample_size=sample_size)


PART_KW = dict(n_partitions=4, partition_min_rows=8)


# ---------------------------------------------------------------------------
# splitters
# ---------------------------------------------------------------------------


def test_contiguous_partitions_cover_in_order():
    parts = parallel.contiguous_partitions(10, 4)
    assert [len(p) for p in parts] == [2, 3, 2, 3]
    assert np.concatenate(parts).tolist() == list(range(10))


def test_hash_partitions_keep_groups_whole():
    records = [{"g": f"k{i % 5}"} for i in range(40)]
    parts = parallel.hash_partitions(records, 3, "g")
    assert sorted(i for p in parts for i in p) == list(range(40))
    for p in parts:
        keys = {records[i]["g"] for i in p}
        for q in parts:
            if p is not q:
                assert not keys & {records[i]["g"] for i in q}
    # equality classes match the unpartitioned group dict: 1 and 1.0 are ONE
    # group, so they must land in one partition
    mixed = [{"g": 1}, {"g": 1.0}, {"g": 2}, {"g": True}]
    mparts = parallel.hash_partitions(mixed, 3, "g")
    home = {pi for pi, p in enumerate(mparts) for i in p
            if mixed[i]["g"] in (1, 1.0, True)}
    assert len(home) == 1


def test_range_partitions_are_key_ordered():
    records = [{"v": f"{(i * 7) % 20:03d}"} for i in range(20)]
    parts = parallel.range_partitions(records, 4, "v")
    flat = [records[i]["v"] for p in parts for i in p]
    assert flat == sorted(flat)
    # numeric keys order numerically, not lexicographically ("10" < "2")
    nums = [{"v": (i * 7) % 20} for i in range(20)]
    nparts = parallel.range_partitions(nums, 4, "v")
    nflat = [nums[i]["v"] for p in nparts for i in p]
    assert nflat == sorted(nflat)


def test_subtree_partitions_align_to_reduce_tree():
    # 100 leaves, fanout 8 -> depth 3, chunks of 64: partitions [64, 36]
    parts = parallel.subtree_partitions(100, 8, 4)
    assert [len(p) for p in parts] == [64, 36]
    # n <= fanout: the whole reduce is one root prompt, one partition
    assert [len(p) for p in parallel.subtree_partitions(6, 8, 4)] == [6]


# ---------------------------------------------------------------------------
# filter: thresholds + pass-set preserved
# ---------------------------------------------------------------------------


def test_partitioned_gold_filter_identical():
    records, world, *_ = synth.make_filter_world(90, seed=31)
    synth.add_phrase_predicate(world, records, "is rare", 0.3, seed=31)
    base = (SemFrame(records, _session(world)).lazy()
            .sem_filter("the {claim} is rare").collect())
    lz = (SemFrame(records, _session(world)).lazy()
          .sem_filter("the {claim} is rare"))
    part = lz.collect(**PART_KW, fragment_workers=4)
    assert part.records == base.records
    assert any(r.rule == "plan_partitions" for r in lz.last_rewrites)


def test_partitioned_cascade_same_thresholds_and_pass_set():
    """The acceptance contract: identical tau_plus/tau_minus (the cascade
    calibrates on ONE global importance sample regardless of partitioning),
    identical pass-set, identical oracle bill, for the same seed."""
    records, world, *_ = synth.make_filter_world(120, seed=32)
    synth.add_phrase_predicate(world, records, "is checkable", 0.4, seed=32)

    log_base, log_part = [], []
    base = (SemFrame(records, _session(world, with_proxy=True), log_base)
            .lazy().sem_filter("the {claim} is checkable",
                               recall_target=0.9, precision_target=0.85)
            .collect())
    part = (SemFrame(records, _session(world, with_proxy=True), log_part)
            .lazy().sem_filter("the {claim} is checkable",
                               recall_target=0.9, precision_target=0.85)
            .collect(**PART_KW, fragment_workers=4))
    assert part.records == base.records
    st_b = next(s for s in log_base if s["operator"] == "sem_filter")
    st_p = next(s for s in log_part if s["operator"] == "sem_filter")
    assert st_p["tau_plus"] == st_b["tau_plus"]
    assert st_p["tau_minus"] == st_b["tau_minus"]
    assert st_p["oracle_region"] == st_b["oracle_region"]
    assert st_p["oracle_calls"] == st_b["oracle_calls"]
    assert st_p["proxy_calls"] == st_b["proxy_calls"]
    assert st_p["n_partitions"] == 4


# ---------------------------------------------------------------------------
# topk / agg: record-identical
# ---------------------------------------------------------------------------


def test_partitioned_topk_record_identical():
    records, world, model, emb, piv = synth.make_rank_world(
        64, compare_noise=0.0, seed=33)
    base = (SemFrame(records, _session(world)).lazy()
            .sem_topk("most accurate {abstract}", 6).collect())
    part = (SemFrame(records, _session(world)).lazy()
            .sem_topk("most accurate {abstract}", 6)
            .collect(**PART_KW, fragment_workers=4))
    # noiseless comparator -> both recover the true top-6, in rank order
    assert part.records == base.records


def test_partitioned_topk_merge_reuses_comparator_cache():
    records, world, model, emb, piv = synth.make_rank_world(
        40, compare_noise=0.0, seed=34)
    idx, st = sem_topk_partitioned(records, "most accurate {abstract}", 5,
                                   model, [list(range(0, 20)),
                                           list(range(20, 40))], seed=0)
    truth = sorted(range(40), key=lambda i: -world.rank_value[f"doc{i}"])[:5]
    assert idx == truth
    assert st["n_partitions"] == 2 and st["merge_candidates"] == 10


@pytest.mark.parametrize("n", [30, 64, 100, 130])
def test_partitioned_agg_record_identical(n):
    """Record-identical AND prompt-count-identical: the count catches a
    level-misaligned tree (e.g. a small trailing subtree skipping the
    unpartitioned run's singleton re-prompt at n=130) that an idempotent
    simulated backend would otherwise mask."""
    records, world, model, emb = synth.make_topic_world(n, 3, seed=35)
    log_b, log_p = [], []
    base = (SemFrame(records, _session(world), log_b).lazy()
            .sem_agg("summarize {paper}").collect())
    part = (SemFrame(records, _session(world), log_p).lazy()
            .sem_agg("summarize {paper}")
            .collect(**PART_KW, fragment_workers=4))
    assert part.records == base.records  # subtree-aligned => same prompts
    calls = lambda log: sum(st.get("generate_calls", 0) for st in log)
    assert calls(log_p) == calls(log_b)


def test_partitioned_groupby_agg_identical_rows_and_order():
    records, world, model, emb = synth.make_topic_world(60, 4, seed=36)
    for i, t in enumerate(records):
        # mixed-type keys for one bucket (1 vs 1.0 are ONE group under dict
        # equality): the hash partitioner must keep them together
        t["bucket"] = (1 if i % 8 == 0 else 1.0 if i % 8 == 4
                       else f"b{i % 4}")
    base = (SemFrame(records, _session(world)).lazy()
            .sem_agg("summarize {paper}", group_by="bucket").collect())
    part = (SemFrame(records, _session(world)).lazy()
            .sem_agg("summarize {paper}", group_by="bucket")
            .collect(**PART_KW, fragment_workers=4))
    assert part.records == base.records  # same answers, same key order


# ---------------------------------------------------------------------------
# join / sim-join: record-identical under both exchange strategies
# ---------------------------------------------------------------------------


def test_partitioned_join_broadcast_and_grid_identical():
    left, right, world, *_ = synth.make_join_world(36, 9, seed=37)
    base = (SemFrame(left, _session(world)).lazy()
            .sem_join(right, "the {abstract} reports the {reaction:right}")
            .collect())
    bcast = (SemFrame(left, _session(world)).lazy()
             .sem_join(right, "the {abstract} reports the {reaction:right}")
             .collect(**PART_KW, fragment_workers=4))
    grid_lz = (SemFrame(left, _session(world)).lazy()
               .sem_join(right, "the {abstract} reports the {reaction:right}"))
    grid = grid_lz.collect(**PART_KW, broadcast_max_rows=4, fragment_workers=4)
    assert bcast.records == base.records
    assert grid.records == base.records
    assert any("fragment grid" in r.detail for r in grid_lz.last_rewrites)


def test_partitioned_simjoin_identical():
    left, right, world, *_ = synth.make_join_world(30, 8, seed=38)
    base = (SemFrame(left, _session(world)).lazy()
            .sem_sim_join(right, "abstract", "reaction", k=2,
                          index_kind="exact").collect())
    part = (SemFrame(left, _session(world)).lazy()
            .sem_sim_join(right, "abstract", "reaction", k=2,
                          index_kind="exact")
            .collect(**PART_KW, fragment_workers=4))
    assert part.records == base.records


# ---------------------------------------------------------------------------
# sharded retrieval: exactness (jnp contract path on one device)
# ---------------------------------------------------------------------------


def test_sharded_search_matches_exact_scan(rng):
    corpus = rng.normal(size=(600, 24)).astype(np.float32)
    queries = rng.normal(size=(9, 24)).astype(np.float32)
    sims = ops.similarity(queries, corpus)
    exact_idx = np.argsort(-sims, axis=1)[:, :7]
    scores, idx = ops.sharded_search(queries, corpus, 7, shards=4)
    np.testing.assert_array_equal(idx, exact_idx)
    np.testing.assert_allclose(
        scores, np.take_along_axis(sims, exact_idx, axis=1), rtol=1e-5)


def test_sharded_ivf_scores_identical_to_unsharded(rng):
    from repro.index.ivf_index import IVFIndex
    corpus = rng.normal(size=(900, 16)).astype(np.float32)
    queries = rng.normal(size=(5, 16)).astype(np.float32)
    ivf = IVFIndex(corpus, n_clusters=24, seed=2)
    s_u, p_u = ops.ivf_search(queries, ivf.centroids, ivf.store,
                              ivf.store_mask, nprobe=6)
    s_s, p_s = ops.sharded_ivf_search(queries, ivf.centroids, ivf.store,
                                      ivf.store_mask, nprobe=6, shards=4)
    np.testing.assert_array_equal(p_u, p_s)
    np.testing.assert_allclose(s_u, s_s, rtol=1e-6)


def test_sharded_index_degenerate_equals_exact(rng):
    """Acceptance: sharded search at nprobe=n_clusters == ops.similarity
    exact scan, and the sharded exact index == the unsharded one."""
    from repro.index.ivf_index import IVFIndex
    from repro.index.vector_index import VectorIndex
    corpus = rng.normal(size=(800, 16)).astype(np.float32)
    queries = rng.normal(size=(6, 16)).astype(np.float32)
    _, base_idx = VectorIndex(corpus).search(queries, 10)
    sharded_exact = VectorIndex(corpus, shards=4)
    _, se_idx = sharded_exact.search(queries, 10)
    np.testing.assert_array_equal(se_idx, base_idx)
    st = sharded_exact.last_stats
    assert st["shards"] == 4
    assert st["scored_vectors_per_shard"] == 6 * 200

    deg = IVFIndex(corpus, n_clusters=16, seed=3, shards=4)
    _, dv = deg.search(queries, 10, nprobe=deg.n_clusters)
    np.testing.assert_array_equal(dv, base_idx)
    assert deg.last_stats["shards"] == 4


def test_sharded_index_save_load_roundtrip(tmp_path, rng):
    from repro.index.backend import load_index
    from repro.index.vector_index import VectorIndex
    corpus = rng.normal(size=(300, 8)).astype(np.float32)
    VectorIndex(corpus, shards=4).save(str(tmp_path / "ix"))
    back = load_index(str(tmp_path / "ix"))
    assert back.shards == 4


# ---------------------------------------------------------------------------
# comparator dedup (satellite regression)
# ---------------------------------------------------------------------------


class _CountingCompareModel:
    def __init__(self, model):
        self._m = model
        self.prompts: list[str] = []

    def compare(self, prompts):
        self.prompts.extend(prompts)
        return self._m.compare(prompts)


def test_comparator_batch_dedupes_repeats_and_mirrors():
    records, world, model, emb, piv = synth.make_rank_world(6, seed=40)
    counting = _CountingCompareModel(model)
    cmp = _Comparator(records, "most accurate {abstract}", counting)
    out = cmp.batch([(0, 1), (0, 1), (1, 0), (2, 3), (3, 2), (2, 3)])
    # one prompt per *unordered* pair: {0,1} and {2,3}
    assert len(counting.prompts) == 2
    # mirrors are consistent by construction (no independent re-sampling)
    assert bool(out[0]) == bool(out[1])
    assert bool(out[2]) != bool(out[0])
    assert bool(out[4]) != bool(out[3])
    assert bool(out[5]) == bool(out[3])
    # cached pairs never re-prompt
    cmp.batch([(1, 0), (3, 2)])
    assert len(counting.prompts) == 2


# ---------------------------------------------------------------------------
# explain / gateway surface
# ---------------------------------------------------------------------------


def test_explain_surfaces_partition_stats():
    records, world, *_ = synth.make_filter_world(80, seed=41)
    synth.add_phrase_predicate(world, records, "is rare", 0.2, seed=41)
    lz = (SemFrame(records, _session(world)).lazy()
          .sem_filter("the {claim} is rare"))
    txt = lz.explain(**PART_KW)
    assert "Exchange[gather, P=4]" in txt
    assert "Partition[contiguous, P=4]" in txt
    assert "frag_oracle~" in txt


def test_agg_partition_count_matches_subtree_alignment():
    """The Exchange/Partition metadata for an Agg reflects the subtree-
    aligned fragment count (fixed by n and fanout), not the configured
    n_partitions — 100 leaves at fanout 8 -> chunks of 64 -> 2 fragments."""
    records, world, model, emb = synth.make_topic_world(100, 3, seed=45)
    opt = PlanOptimizer(_session(world), n_partitions=4, partition_min_rows=8)
    plan = opt.optimize(N.Agg(N.Scan(records), "summarize {paper}", fanout=8))
    assert isinstance(plan, N.Exchange) and plan.n_partitions == 2
    assert plan.child.child.n_partitions == 2
    assert any("2 subtree partitions" in r.detail for r in opt.applied)


def test_optimizer_skips_small_inputs_and_cascade_joins():
    left, right, world, *_ = synth.make_join_world(20, 6, seed=42)
    sess = _session(world, with_proxy=True)
    opt = PlanOptimizer(sess, n_partitions=4, partition_min_rows=64)
    plan = opt.optimize(N.Filter(N.Scan(left), "the {abstract} holds"))
    assert isinstance(plan, N.Filter)  # 20 rows < min: untouched
    opt2 = PlanOptimizer(sess, n_partitions=4, partition_min_rows=8)
    cascade = N.Join(N.Scan(left), N.Scan(right),
                     "the {abstract} reports the {reaction:right}",
                     recall_target=0.9)
    plan2 = opt2.optimize(cascade)
    assert isinstance(plan2, N.Join)   # cascade join: global sample stays

    wrapped = opt2.optimize(N.Filter(N.Scan(left), "the {abstract} holds"))
    assert isinstance(wrapped, N.Exchange)
    assert "Exchange" in explain_plan(wrapped)


def test_gateway_runs_fragments_and_preserves_records():
    records, world, *_ = synth.make_filter_world(100, seed=43)
    synth.add_phrase_predicate(world, records, "is rare", 0.25, seed=43)
    from repro.serve import Gateway
    sess = _session(world, with_proxy=True)
    sf = SemFrame(records, sess)
    base = sf.lazy().sem_filter("the {claim} is rare").collect()
    with Gateway(sess, max_inflight=2, n_partitions=4, fragment_workers=3,
                 optimizer_kw={"partition_min_rows": 16}) as gw:
        handles = [gw.submit(sf.lazy().sem_filter("the {claim} is rare"),
                             tenant=f"t{i}") for i in range(2)]
        outs = [h.result(timeout=120) for h in handles]
        snap = gw.snapshot()
    for out in outs:
        assert [t["id"] for t in out] == [t["id"] for t in base.records]
    assert snap["fragments_run"] >= 8       # 4 fragments x 2 sessions
    assert snap["partitioned_ops"] >= 2
    # fragment traffic still rolls up into each session's scope (the shared
    # semantic cache may hand the slower session its answers for free, so
    # assert activity — oracle calls or cross-session cache hits — per scope)
    assert any(h.stats.oracle_calls > 0 for h in handles)
    assert all(h.stats.oracle_calls + h.stats.cache_hits > 0 for h in handles)


def test_base_executor_treats_markers_as_transparent():
    records, world, *_ = synth.make_filter_world(40, seed=44)
    synth.add_phrase_predicate(world, records, "is rare", 0.3, seed=44)
    from repro.core.plan.execute import PlanExecutor
    sess = _session(world)
    plan = N.Exchange(N.Filter(N.Partition(N.Scan(records), 4),
                               "the {claim} is rare"), "gather", 4)
    out = PlanExecutor(sess).run(plan)
    gold = (SemFrame(records, _session(world))
            .sem_filter("the {claim} is rare"))
    assert out == gold.records


# ---------------------------------------------------------------------------
# multi-device shard_map path (forced 4-device CPU topology, subprocess —
# device count locks at first jax init, so it cannot share this process)
# ---------------------------------------------------------------------------


def test_shard_map_paths_match_ref_on_four_devices():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    code = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 4
        from repro.kernels import ops
        from repro.index.vector_index import VectorIndex
        rng = np.random.default_rng(0)
        corpus = rng.normal(size=(1030, 16)).astype(np.float32)
        q = rng.normal(size=(7, 16)).astype(np.float32)
        s_r, i_r = ops.sharded_search(q, corpus, 5, shards=4, impl="ref")
        s_m, i_m = ops.sharded_search(q, corpus, 5, shards=4,
                                      impl="shard_map")
        assert np.array_equal(i_r, i_m) and np.allclose(s_r, s_m)
        # auto dispatch takes the shard_map path on a multi-device host and
        # the index surfaces per-shard accounting
        ix = VectorIndex(corpus, shards=4)
        _, idx = ix.search(q, 5)
        assert np.array_equal(idx, i_r)
        assert ix.last_stats["shards"] == 4
        from repro.index.ivf_index import IVFIndex
        ivf = IVFIndex(corpus, n_clusters=18, seed=1)
        s1, p1 = ops.sharded_ivf_search(q, ivf.centroids, ivf.store,
                                        ivf.store_mask, nprobe=5, shards=4,
                                        impl="ref")
        s2, p2 = ops.sharded_ivf_search(q, ivf.centroids, ivf.store,
                                        ivf.store_mask, nprobe=5, shards=4,
                                        impl="shard_map")
        assert np.array_equal(p1, p2) and np.allclose(s1, s2)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
