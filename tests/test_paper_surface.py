"""Coverage for the remaining paper-surface features (§4.2 conveniences):
sem_topk group_by, sem_agg partitioner override (footnote 4), sem_search
re-ranking, scheduler deadlines, analyzer edge cases."""
import time

import numpy as np

from repro.core import accounting
from repro.core.backends import synth
from repro.core.backends.base import CountedModel
from repro.core.frame import SemFrame, Session
from repro.core.operators.agg import sem_agg_hierarchical
from repro.launch.hlo_analysis import analyze_text, parse, shape_bytes, shape_elems


def test_sem_topk_group_by():
    """Fig 5: per-group top-k over standard equality groups."""
    records, world, model, emb, piv = synth.make_rank_world(60, compare_noise=1e-9, seed=50)
    for i, t in enumerate(records):
        t["domain"] = "cs.DB" if i % 2 == 0 else "cs.IR"
    sess = Session(oracle=model, embedder=emb)
    sf = SemFrame(records, sess)
    top = sf.sem_topk("{abstract} highest accuracy", 3, group_by="domain")
    assert len(top) == 6
    by_dom = {}
    for t in top.records:
        by_dom.setdefault(t["domain"], []).append(t)
    for dom, recs in by_dom.items():
        pool = [t for t in records if t["domain"] == dom]
        want = sorted(pool, key=lambda t: -world.rank_value[t["id"]])[:3]
        assert [t["id"] for t in recs] == [t["id"] for t in want], dom


def test_sem_agg_partitioner_override():
    """Footnote 4: user-controlled grouping/ordering of the first reduce level."""
    records, world, model, _ = synth.make_topic_world(24, 2, seed=51)
    model = CountedModel(model, "oracle")
    calls = {}

    def partitioner(items):
        calls["groups"] = [items[:4], items[4:]]   # deliberately uneven
        return calls["groups"]

    out, st = sem_agg_hierarchical(records, "summarize {paper}", model,
                                   fanout=8, partitioner=partitioner)
    assert out and "groups" in calls
    assert st["generate_calls"] >= 3  # 2 first-level groups + >=1 upper level


def test_sem_search_with_rerank():
    """§4.2 n_rerank: similarity retrieval then LLM re-ranking."""
    records, world, model, emb, piv = synth.make_rank_world(40, compare_noise=1e-9, seed=52)
    sess = Session(oracle=model, embedder=emb)
    sf = SemFrame(records, sess)
    idx = sf.sem_index("abstract")
    hits = sf.sem_search("abstract", "highest accuracy paper", k=10, index=idx,
                         n_rerank=3, rerank_langex="{abstract} highest accuracy")
    assert len(hits) == 3
    st = sf.last_stats()
    assert st["compare_calls"] > 0     # the re-rank actually used the LLM


def test_scheduler_deadline_requeues():
    """Straggler guard: a request over its wall-clock budget is re-dispatched."""
    from repro.configs import get_smoke
    from repro.data.tokenizer import TOKENIZER
    from repro.engine.runner import ModelRunner
    from repro.engine.scheduler import ContinuousBatchScheduler, Request
    from repro.models import registry
    import jax

    cfg = get_smoke("llama3.2-3b").with_(vocab_size=TOKENIZER.vocab_size)
    runner = ModelRunner(cfg, registry.init_params(cfg, jax.random.PRNGKey(0)),
                         max_slots=2, max_seq=96)
    sched = ContinuousBatchScheduler(runner, max_retries=1)
    r = Request(rid=0, tokens=np.asarray(TOKENIZER.encode("slow req"), np.int32),
                max_new_tokens=4, deadline_s=0.001)  # near-instantly-expired budget
    sched.submit(r)
    sched.step()                        # prefill
    r.started_at = time.monotonic() - 10
    sched.step()                        # deadline check fires -> requeue
    done = sched.run_to_completion()
    assert len(done) == 1
    assert done[0].retries >= 1


def test_accounting_operator_labels():
    records, world, model, emb = synth.make_topic_world(10, 2, seed=53)
    sess = Session(oracle=model, embedder=emb)
    sf = SemFrame(records, sess)
    sf.sem_map("x {paper}")
    assert sf.last_stats()["operator"] == "sem_map"
    assert sf.last_stats()["wall_s"] >= 0


# ---------------------------------------------------------------------------
# hlo_analysis edges
# ---------------------------------------------------------------------------


def test_shape_helpers():
    assert shape_elems("bf16[4,8]{1,0}") == 32
    assert shape_elems("(f32[2,2], s32[3])") == 7
    assert shape_bytes("f8e4m3fn[10]") == 10


def test_analyzer_handles_empty_and_garbage():
    costs = analyze_text("HloModule empty\n")
    assert costs.flops == 0 and costs.bytes == 0
    m = parse("not hlo at all\n{}\n")
    assert m.entry == ""


def test_analyzer_dus_inplace_accounting():
    """An in-place cache update inside jit must be charged the slice, not the
    buffer (the measurement bug behind §Perf decode iteration 1)."""
    import jax, jax.numpy as jnp

    def step(cache, x):
        def body(c, _):
            c = jax.lax.dynamic_update_slice_in_dim(c, x, 0, axis=0)
            return c, None
        c, _ = jax.lax.scan(body, cache, None, length=50)
        return c

    cache = jax.ShapeDtypeStruct((1 << 14, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 128), jnp.float32)
    c = jax.jit(step).lower(cache, x).compile()
    costs = analyze_text(c.as_text())
    buffer_bytes = (1 << 14) * 128 * 4
    # traffic must be far below 50 full-buffer writes
    assert costs.bytes < 5 * buffer_bytes, costs.bytes
