"""Quantized retrieval hot path: int8 quantization reference, the fused
dequantize+score kernel vs its jnp contract, IVF int8 + exact-rerank recall,
the bit-identical ``quantize="none"`` contract, persistence, sharding, the
byte-aware cost model, and registry key separation across precisions."""
import tempfile

import numpy as np
import pytest

from repro.index import (IVFIndex, VectorIndex, bytes_per_vector,
                         choose_backend, choose_retrieval_config,
                         dequantize_rows, quantize_rows, quantize_tiles,
                         quantized_scores)
from repro.index.backend import QUANT_MIN_CORPUS
from repro.index.quant import INT8_MAX
from repro.kernels import ops as kops
from repro.serve import IndexRegistry


def _clustered(n, d=32, n_centers=20, noise=0.15, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    lab = rng.integers(n_centers, size=n)
    x = centers[lab] + noise * rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return np.asarray(x, np.float32), centers


def _recall(exact_idx, ann_idx):
    k = exact_idx.shape[1]
    return np.mean([len(set(exact_idx[i]) & set(ann_idx[i])) / k
                    for i in range(len(exact_idx))])


# ---------------------------------------------------------------------------
# quantization reference (pure numpy)
# ---------------------------------------------------------------------------


def test_roundtrip_error_bound():
    """Per-element |v - dequant(quant(v))| <= scale/2 = absmax/254."""
    rng = np.random.default_rng(0)
    v = rng.normal(size=(200, 48)).astype(np.float32) * \
        rng.uniform(0.01, 10.0, size=(200, 1)).astype(np.float32)
    q, scales = quantize_rows(v)
    back = dequantize_rows(q, scales)
    absmax = np.abs(v).max(axis=1)
    bound = absmax / (2 * INT8_MAX) + 1e-7
    assert np.all(np.abs(back - v) <= bound[:, None])
    assert q.dtype == np.int8 and scales.dtype == np.float32
    assert q.min() >= -INT8_MAX  # symmetric: -128 never used


def test_zero_norm_row_guard():
    """All-zero rows (tile padding) must quantize with scale pinned to 1.0:
    no divide-by-zero, no NaN, exact-zero round-trip."""
    v = np.zeros((3, 16), np.float32)
    v[1, 4] = 2.5  # one live row between two dead ones
    with np.errstate(all="raise"):  # a division by zero would raise here
        q, scales = quantize_rows(v)
    assert scales[0] == 1.0 and scales[2] == 1.0
    assert np.all(q[0] == 0) and np.all(q[2] == 0)
    back = dequantize_rows(q, scales)
    assert np.all(back[0] == 0.0) and np.all(np.isfinite(back))
    np.testing.assert_allclose(back[1, 4], 2.5, rtol=0.01)
    # tile form runs the guard on every padding lane
    store = np.zeros((2, 8, 16), np.float32)
    store[0, 0] = v[1]
    tq, ts = quantize_tiles(store)
    assert tq.shape == store.shape and ts.shape == (2, 8)
    assert np.all(ts[0, 1:] == 1.0) and np.all(ts[1] == 1.0)


def test_quantized_scores_matches_dequantized_matmul():
    rng = np.random.default_rng(1)
    v = rng.normal(size=(50, 24)).astype(np.float32)
    queries = rng.normal(size=(6, 24)).astype(np.float32)
    q, scales = quantize_rows(v)
    fused = quantized_scores(queries, q, scales)
    explicit = queries @ dequantize_rows(q, scales).T
    np.testing.assert_allclose(fused, explicit, rtol=1e-5, atol=1e-5)


def test_bytes_per_vector():
    assert bytes_per_vector(64, "none") == 256.0
    assert bytes_per_vector(64, "int8") == 68.0
    with pytest.raises(ValueError):
        bytes_per_vector(64, "int4")


# ---------------------------------------------------------------------------
# kernel vs jnp contract
# ---------------------------------------------------------------------------


def test_ivf_search_q_interpret_matches_ref():
    """The Pallas kernel body (interpreter) and the jnp contract implement
    the same fused dequantize+score numerics."""
    rng = np.random.default_rng(2)
    kc, L, d = 8, 128, 32
    store = rng.normal(size=(kc, L, d)).astype(np.float32)
    mask = (rng.random((kc, L)) > 0.25).astype(np.float32)
    store[mask == 0] = 0.0
    store_q, scales = quantize_tiles(store)
    cents = rng.normal(size=(kc, d)).astype(np.float32)
    queries = rng.normal(size=(11, d)).astype(np.float32)
    s_ref, p_ref = kops.ivf_search_q(queries, cents, store_q, scales, mask,
                                     nprobe=3, impl="ref")
    s_int, p_int = kops.ivf_search_q(queries, cents, store_q, scales, mask,
                                     nprobe=3, impl="interpret")
    np.testing.assert_array_equal(p_ref, p_int)
    np.testing.assert_allclose(s_ref, s_int, rtol=1e-5, atol=1e-5)


def test_sharded_ivf_search_q_matches_unsharded():
    rng = np.random.default_rng(3)
    kc, L, d = 10, 128, 32
    store = rng.normal(size=(kc, L, d)).astype(np.float32)
    mask = np.ones((kc, L), np.float32)
    store_q, scales = quantize_tiles(store)
    cents = rng.normal(size=(kc, d)).astype(np.float32)
    queries = rng.normal(size=(7, d)).astype(np.float32)
    s1, p1 = kops.ivf_search_q(queries, cents, store_q, scales, mask,
                               nprobe=4, impl="ref")
    s4, p4 = kops.sharded_ivf_search_q(queries, cents, store_q, scales, mask,
                                       nprobe=4, shards=4)
    np.testing.assert_array_equal(p1, p4)
    np.testing.assert_allclose(s1, s4, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# IVFIndex(quantize="int8")
# ---------------------------------------------------------------------------


def test_quantized_rerank_recall_contract():
    """int8 scan + exact fp32 rerank must hold the measured recall contract:
    >= 0.99 of the fp32 IVF path's recall@10 vs exact, and rerank scores are
    exact (match the fp32 scores on shared hits)."""
    x, centers = _clustered(4000, seed=4)
    rng = np.random.default_rng(44)
    queries = np.asarray(
        centers[rng.integers(len(centers), size=24)]
        + 0.15 * rng.normal(size=(24, 32)), np.float32)
    _, exact_idx = VectorIndex(x).search(queries, 10)
    fp = IVFIndex(x, nprobe=6, seed=5)
    fp_scores, fp_idx = fp.search(queries, 10)
    q8 = IVFIndex(x, nprobe=6, seed=5, quantize="int8")
    q_scores, q_idx = q8.search(queries, 10)
    assert _recall(exact_idx, q_idx) >= 0.99 * _recall(exact_idx, fp_idx)
    # rerank scores are exact fp32: identical (to fp tolerance) wherever the
    # two paths retrieved the same row
    for r in range(len(queries)):
        fp_map = dict(zip(fp_idx[r].tolist(), fp_scores[r].tolist()))
        for i, s in zip(q_idx[r].tolist(), q_scores[r].tolist()):
            if i in fp_map:
                assert abs(s - fp_map[i]) < 1e-4
    st = q8.last_stats
    assert st["quantize"] == "int8" and st["reranked"] > 0
    # dtype-aware byte accounting: strictly fewer bytes than the fp32 scan
    assert st["scanned_bytes"] < fp.last_stats["scanned_bytes"]
    assert fp.last_stats["quantize"] == "none"


def test_quantize_none_bit_identical():
    x, _ = _clustered(1500, seed=6)
    queries = x[::201][:8] + 0.01
    a = IVFIndex(x, nprobe=5, seed=1)
    b = IVFIndex(x, nprobe=5, seed=1, quantize="none")
    sa, ia = a.search(queries, 7)
    sb, ib = b.search(queries, 7)
    np.testing.assert_array_equal(sa, sb)
    np.testing.assert_array_equal(ia, ib)


def test_quantized_delta_add_and_retrain():
    """add() quantizes incrementally; new rows are findable immediately and
    a sync retrain folds them into int8 tiles."""
    x, _ = _clustered(1200, seed=7)
    idx = IVFIndex(x, nprobe=4, seed=2, quantize="int8", retrain="off")
    extra, _ = _clustered(30, seed=77)
    idx.add(extra)
    assert len(idx._delta_q) == 30 and len(idx._delta_scales) == 30
    _, hits = idx.search(extra[:5], 1)
    np.testing.assert_array_equal(hits[:, 0], np.arange(1200, 1205))
    idx.retrain(wait=True)
    assert idx.delta_rows == 0 and idx.store_q.shape[2] == 32
    _, hits2 = idx.search(extra[:5], 1)
    np.testing.assert_array_equal(hits2[:, 0], np.arange(1200, 1205))


def test_quantized_save_load_roundtrip():
    x, _ = _clustered(900, seed=8)
    queries = x[::97][:6] + 0.01
    idx = IVFIndex(x, nprobe=4, seed=3, quantize="int8", rerank_factor=3)
    s1, i1 = idx.search(queries, 5)
    with tempfile.TemporaryDirectory() as td:
        idx.save(td)
        loaded = IVFIndex.load(td)
        assert loaded.quantize == "int8" and loaded.rerank_factor == 3
        np.testing.assert_array_equal(loaded.store_q, idx.store_q)
        np.testing.assert_array_equal(loaded.store_scales, idx.store_scales)
        s2, i2 = loaded.search(queries, 5)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(s1, s2, rtol=1e-6)


def test_quantized_sharded_matches_unsharded():
    x, _ = _clustered(2000, seed=9)
    queries = x[::151][:9] + 0.01
    plain = IVFIndex(x, nprobe=5, seed=4, quantize="int8")
    sharded = IVFIndex(x, nprobe=5, seed=4, quantize="int8", shards=4)
    s1, i1 = plain.search(queries, 6)
    s2, i2 = sharded.search(queries, 6)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)


def test_int8_store_replaces_fp32_tiles():
    x, _ = _clustered(800, seed=10)
    idx = IVFIndex(x, nprobe=4, quantize="int8")
    assert idx.store is None and idx.store_q.dtype == np.int8
    assert idx.describe()["quantize"] == "int8"
    assert idx.describe()["bytes_per_vector"] == bytes_per_vector(32, "int8")
    fp = IVFIndex(x, nprobe=4)
    assert fp.store_q is None and fp.describe()["quantize"] == "none"


# ---------------------------------------------------------------------------
# byte-aware cost model + plan integration
# ---------------------------------------------------------------------------


def test_choose_retrieval_config_byte_trade():
    # legacy 2-tuple contract untouched
    assert choose_backend(500, 1) == ("exact", None)
    small = choose_retrieval_config(500, 1)
    assert small == {"kind": "exact", "nprobe": None, "quantize": "none",
                     "costs": None}
    # a registry-amortized serving corpus past QUANT_MIN_CORPUS: the byte
    # win beats the rerank overhead -> int8
    big = choose_retrieval_config(50_000, 100, shared=True)
    assert big["kind"] == "ivf" and big["quantize"] == "int8"
    assert big["costs"]["ivf_q"] < big["costs"]["ivf"]
    assert (big["costs"]["ivf_q_bytes_per_query"]
            < big["costs"]["ivf_bytes_per_query"])
    # below the quantization floor the same IVF choice stays fp32
    floor = choose_retrieval_config(QUANT_MIN_CORPUS - 1, 100, shared=True)
    assert floor["kind"] == "ivf" and floor["quantize"] == "none"
    # pins override the model in both directions
    assert choose_retrieval_config(50_000, 100, shared=True,
                                   quantize="none")["quantize"] == "none"
    pinned = choose_retrieval_config(QUANT_MIN_CORPUS - 1, 100, shared=True,
                                     quantize="int8")
    assert pinned["quantize"] == "int8"
    with pytest.raises(ValueError):
        choose_retrieval_config(1000, 1, quantize="int4")


def _find_node(root, cls):
    stack = [root]
    while stack:
        n = stack.pop()
        if isinstance(n, cls):
            return n
        stack.extend(n.children())
    return None


def test_optimizer_installs_quantize():
    from repro.core.backends import synth
    from repro.core.frame import SemFrame, Session
    from repro.core.plan import nodes as N
    from repro.core.plan.optimize import PlanOptimizer
    records, world, *_ = synth.make_filter_world(40, seed=11)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world))
    right = [{"text": f"doc {i}"} for i in range(3000)]
    plan = SemFrame(records, sess).lazy().sem_sim_join(
        right, "claim", "text", k=3).plan
    opt = PlanOptimizer(sess, index_min_corpus=100, index_shared=True,
                        quant_min_corpus=100)
    node = _find_node(opt.optimize(plan), N.SimJoin)
    assert node is not None
    assert node.index_kind == "ivf" and node.quantize == "int8"
    assert any("int8" in r.detail for r in opt.applied
               if r.rule == "choose_retrieval")
    # pinning quantize="none" through the node wins over the cost model
    plan2 = SemFrame(records, sess).lazy().sem_sim_join(
        right, "claim", "text", k=3, quantize="none").plan
    opt2 = PlanOptimizer(sess, index_min_corpus=100, index_shared=True,
                         quant_min_corpus=100)
    node2 = _find_node(opt2.optimize(plan2), N.SimJoin)
    assert node2.index_kind == "ivf" and node2.quantize == "none"


def test_registry_keys_separate_precisions():
    """A cached int8 build must never alias the fp32 build of the same
    corpus: the quantize param lands in both key flavors."""
    class _E:
        index_key = "emb-test"
    texts = ["a", "b", "c"]
    k_fp = IndexRegistry.key_for(texts, _E(), kind="ivf",
                                 params={"nprobe": 4})
    k_q = IndexRegistry.key_for(texts, _E(), kind="ivf",
                                params={"nprobe": 4, "quantize": "int8"})
    assert k_fp != k_q

    class _T:
        table_id = "tbl1"
    s_fp = IndexRegistry.stream_key_for(_T(), _E(), kind="ivf",
                                        params={"recall_target": 0.95})
    s_q = IndexRegistry.stream_key_for(
        _T(), _E(), kind="ivf",
        params={"recall_target": 0.95, "quantize": "int8"})
    assert s_fp != s_q


def test_exact_index_reports_scanned_bytes():
    x, _ = _clustered(300, seed=12)
    idx = VectorIndex(x)
    idx.search(x[:4], 5)
    st = idx.last_stats
    assert st["scanned_bytes"] == st["scored_vectors"] * 4 * 32
    assert st["quantize"] == "none"
