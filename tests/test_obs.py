"""Observability layer: span tracing, EXPLAIN ANALYZE, and the stats store.

Covers the tentpole surface — span nesting/propagation across threads,
trace export formats, explain_analyze's predicted-vs-observed comparison,
StatsStore accumulation + persistence, gateway trace integration — plus the
satellite fixes: the accounting details roll-up, the log-scale latency
histogram, explain_plan's predicted selectivity, and the shared-OpStats
concurrency stress test.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import accounting
from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.core.plan.optimize import explain_plan, predicted_node_metrics
from repro.kernels import ops
from repro.obs import (StatsStore, Tracer, explain_analyze,
                       node_fingerprint, predicate_fingerprint)
from repro.obs import trace as T
from repro.serve import Gateway
from repro.serve.metrics import GatewayMetrics, LatencyHistogram


def _session(world, *, with_proxy=False, sample_size=40):
    return Session(
        oracle=synth.SimulatedModel(world, "oracle"),
        proxy=synth.SimulatedModel(world, "proxy") if with_proxy else None,
        embedder=synth.SimulatedEmbedder(world), sample_size=sample_size)


def _join_world(n=30, m=8, seed=7):
    left, right, world, *_ = synth.make_join_world(n, m, seed=seed)
    synth.add_phrase_predicate(world, left, "is checkable", 0.4, seed=seed)
    return left, right, world


def _pipeline(left, right, world):
    return (SemFrame(left, _session(world)).lazy()
            .sem_filter("the {abstract} is checkable")
            .sem_join(right, "the {abstract} reports the {reaction:right}"))


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_spans_nest_and_parent_on_the_active_thread():
    tr = Tracer()
    with T.activate(tr):
        with T.span("outer", kind="session", sid="s1"):
            with T.span("inner", kind="operator") as sp:
                sp.add("oracle_calls", 3)
    outer, inner = tr.spans()
    assert (outer.name, outer.kind, outer.parent_id) == ("outer", "session", None)
    assert inner.parent_id == outer.span_id
    assert inner.attrs["oracle_calls"] == 3
    assert inner.t0 >= outer.t0 and inner.t1 <= outer.t1


def test_tracing_off_is_a_shared_noop():
    assert T.current_tracer() is None
    cm = T.span("anything", kind="operator", x=1)
    assert cm is T._NOOP_CM
    with cm as sp:
        sp.set(a=1)
        sp.add("b", 2)          # silently absorbed
    assert T.span_in(None, "x") is T._NOOP_CM


def test_capture_activate_parents_spans_across_threads():
    tr = Tracer()
    with T.activate(tr):
        with T.span("coordinator", kind="operator"):
            ctx = accounting.capture()

            def work():
                with accounting.activate(ctx):
                    with T.span("remote", kind="fragment"):
                        pass

            th = threading.Thread(target=work)
            th.start()
            th.join()
    remote = tr.spans(kind="fragment")[0]
    coord = tr.spans(kind="operator")[0]
    assert remote.parent_id == coord.span_id
    assert remote.thread != coord.thread


def test_track_copies_opstats_onto_the_operator_span():
    tr = Tracer()
    with T.activate(tr):
        with accounting.track("sem_filter"):
            accounting.record("oracle", 4)
            accounting.record("cache_hit", 2)
    (sp,) = tr.spans(kind="operator")
    assert sp.name == "sem_filter"
    assert sp.attrs["oracle_calls"] == 4
    assert sp.attrs["cache_hits"] == 2
    assert sp.attrs["wall_s"] >= 0


def test_tracer_caps_spans_and_counts_drops():
    tr = Tracer(max_spans=2)
    with T.activate(tr):
        for i in range(5):
            with T.span(f"s{i}"):
                pass
    assert len(tr.spans()) == 2 and tr.dropped == 3


# ---------------------------------------------------------------------------
# satellite: accounting details roll-up + concurrency stress
# ---------------------------------------------------------------------------


def test_nested_track_merges_numeric_details_additively():
    with accounting.track("parent") as parent:
        parent.details["scanned_bytes"] = 100
        parent.details["index_kind"] = "ivf"
        with accounting.track("child") as child:
            child.details["scanned_bytes"] = 40
            child.details["rerank_rows"] = 7
            child.details["index_kind"] = "exact"   # non-numeric: parent wins
    assert parent.details["scanned_bytes"] == 140
    assert parent.details["rerank_rows"] == 7
    assert parent.details["index_kind"] == "ivf"


def test_shared_opstats_concurrent_records_sum_exactly():
    """Many fragment threads add into ONE shared OpStats (the partitioned
    executor's contract); totals must be exact, not approximately right —
    this is the regression guard on the ``_add_lock`` serialization."""
    n_threads, n_iter = 12, 300
    with accounting.track("parent") as parent:
        ctx = accounting.capture()

        def fragment(pi):
            with accounting.activate(ctx):
                with accounting.track(f"fragment[{pi}]") as st:
                    for _ in range(n_iter):
                        accounting.record("oracle", 1)
                        accounting.record("cache_hit", 2)
                    st.details["scanned_bytes"] = 10

        threads = [threading.Thread(target=fragment, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert parent.oracle_calls == n_threads * n_iter
    assert parent.cache_hits == 2 * n_threads * n_iter
    assert parent.details["scanned_bytes"] == 10 * n_threads


def test_fragment_spans_parent_into_the_partitioned_operator():
    records, world, *_ = synth.make_filter_world(60, seed=31)
    synth.add_phrase_predicate(world, records, "is rare", 0.3, seed=31)
    tr = Tracer()
    with T.activate(tr):
        out = (SemFrame(records, _session(world)).lazy()
               .sem_filter("the {claim} is rare")
               .collect(n_partitions=4, partition_min_rows=8,
                        fragment_workers=4))
    assert out.records
    frags = tr.spans(kind="fragment")
    assert len(frags) >= 2
    by_id = {s.span_id: s for s in tr.spans()}
    for f in frags:
        assert f.parent_id in by_id          # parented, not orphaned
        assert by_id[f.parent_id].kind in ("operator", "plan_stage")


# ---------------------------------------------------------------------------
# export formats
# ---------------------------------------------------------------------------


def test_jsonl_export_is_one_valid_span_per_line(tmp_path):
    tr = Tracer()
    with T.activate(tr):
        with T.span("a", kind="session"):
            with T.span("b", kind="operator", oracle_calls=2):
                pass
    p = tmp_path / "trace.jsonl"
    assert tr.export_jsonl(str(p)) == 2
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert len(lines) == 2
    for row in lines:
        assert {"span_id", "parent_id", "name", "kind", "ts_us", "dur_us",
                "attrs"} <= set(row)


def test_chrome_export_is_loadable_trace_event_json(tmp_path):
    tr = Tracer()
    with T.activate(tr):
        with T.span("sess", kind="session"):
            with T.span("op", kind="operator"):
                pass
    p = tmp_path / "trace.json"
    tr.export_chrome(str(p))
    doc = json.loads(p.read_text())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert len(events) == 2
    for ev in events:
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], (int, float))
        assert isinstance(ev["dur"], (int, float))
        assert {"name", "cat", "pid", "tid", "args"} <= set(ev)


# ---------------------------------------------------------------------------
# kernel spans
# ---------------------------------------------------------------------------


def test_kernel_dispatch_spans_only_when_traced(rng):
    q = rng.normal(size=(4, 16)).astype(np.float32)
    c = rng.normal(size=(32, 16)).astype(np.float32)
    ops.similarity(q, c)                    # untraced: no tracer to record to
    tr = Tracer()
    with T.activate(tr):
        ops.similarity(q, c)
    (sp,) = tr.spans(kind="kernel")
    assert sp.name == "kernel/similarity"
    assert sp.attrs["nq"] == 4 and sp.attrs["nc"] == 32
    assert "impl" in sp.attrs


# ---------------------------------------------------------------------------
# explain_plan / explain_analyze
# ---------------------------------------------------------------------------


def test_explain_plan_prints_predicted_selectivity():
    left, right, world = _join_world()
    lz = _pipeline(left, right, world)
    text = explain_plan(lz.plan)
    assert "sel~" in text
    assert "sel~" in lz.explain()


def test_predicted_node_metrics_shape():
    left, right, world = _join_world()
    lz = _pipeline(left, right, world)
    pred = predicted_node_metrics(lz.plan)
    assert set(pred) == {"rows", "selectivity", "oracle_calls"}
    assert pred["rows"] >= 0 and pred["oracle_calls"] >= 0


def test_explain_analyze_reports_predicted_and_observed_per_node():
    left, right, world = _join_world()
    lz = _pipeline(left, right, world)
    store = StatsStore()
    rep = explain_analyze(lz, stats_store=store)
    # records match a plain collect() of the same pipeline
    expect = _pipeline(left, right, world).collect()
    assert rep.records == expect.records
    text = rep.render()
    assert "EXPLAIN ANALYZE" in text
    executed = [r for r in rep.nodes if r.observed is not None]
    assert executed, "no node carried observations"
    for r in executed:
        assert r.predicted["rows"] >= 0
        assert r.observed["rows_out"] >= 0
        assert r.observed["wall_s"] >= 0
    flt = next(r for r in rep.nodes if type(r.node).__name__ == "Filter")
    assert flt.observed["rows_in"] == len(left)
    assert 0 < flt.observed["selectivity"] < 1
    assert flt.observed["oracle_calls"] > 0
    # the stats store now knows this predicate's observed selectivity
    assert len(store) >= 2
    obs_sel = store.selectivity_for_node(flt.node)
    assert obs_sel == pytest.approx(flt.observed["selectivity"])


def test_explain_analyze_flags_cost_model_drift():
    left, right, world = _join_world()
    rep = explain_analyze(_pipeline(left, right, world), tolerance=1e-6)
    # with a near-zero tolerance at least one node must drift (wall-clock
    # perfect predictions don't exist), and the flag renders
    assert rep.drifted
    assert "!! drift" in rep.render()


def test_explain_analyze_unoptimized_matches_collect():
    left, right, world = _join_world(seed=9)
    expect = _pipeline(left, right, world).collect(optimize=False)
    rep = explain_analyze(_pipeline(left, right, world), optimize=False)
    assert rep.records == expect.records


# ---------------------------------------------------------------------------
# stats store
# ---------------------------------------------------------------------------


def test_fingerprint_depends_on_semantics_not_data():
    fp1 = predicate_fingerprint("Filter", "the {a} is x")
    fp2 = predicate_fingerprint("Filter", "the {a} is x")
    fp3 = predicate_fingerprint("Filter", "the {a} is y")
    assert fp1 == fp2 != fp3
    left, right, world = _join_world()
    lz_small = (SemFrame(left[:5], _session(world)).lazy()
                .sem_filter("the {abstract} is checkable"))
    lz_big = (SemFrame(left, _session(world)).lazy()
              .sem_filter("the {abstract} is checkable"))
    assert node_fingerprint(lz_small.plan) == node_fingerprint(lz_big.plan)
    assert node_fingerprint(lz_small.plan.children()[0]) is None  # Scan


def test_stats_store_accumulates_and_persists(tmp_path):
    s = StatsStore()
    s.observe("filter", "abc", rows_in=100, rows_out=30, wall_s=0.5,
              stats={"oracle_calls": 100})
    s.observe("filter", "abc", rows_in=50, rows_out=20, wall_s=0.5,
              stats={"oracle_calls": 50})
    obs = s.get("filter", "abc")
    assert obs.runs == 2
    assert obs.selectivity == pytest.approx(50 / 150)
    assert obs.oracle_calls == 150
    assert obs.mean_wall_s == pytest.approx(0.5)
    p = tmp_path / "stats.json"
    s.save(str(p))
    # load merges additively: same entry twice -> doubled counts
    merged = StatsStore(str(p))
    merged.load(str(p))
    m = merged.get("filter", "abc")
    assert m.runs == 4 and m.rows_in == 300 and m.oracle_calls == 300
    assert m.selectivity == pytest.approx(50 / 150)


# ---------------------------------------------------------------------------
# latency histogram (satellite)
# ---------------------------------------------------------------------------


def test_latency_histogram_percentiles_within_bucket_error(rng):
    h = LatencyHistogram()
    xs = rng.lognormal(mean=-2.0, sigma=1.0, size=5000)
    for x in xs:
        h.record(x)
    for q in (50, 95, 99):
        exact = float(np.percentile(xs, q))
        got = h.percentile(q)
        assert abs(got - exact) / exact < 0.08   # half-bucket ≈ 3.7%
    assert len(h) == 5000
    assert h.percentile(0) <= h.percentile(50) <= h.percentile(100)


def test_latency_histogram_clamps_out_of_range():
    h = LatencyHistogram()
    h.record(1e-9)
    h.record(1e9)
    assert h.percentile(0) == LatencyHistogram.LO
    assert h.percentile(100) == LatencyHistogram.HI


def test_metrics_snapshot_keeps_field_names_and_adds_p99():
    m = GatewayMetrics()
    for x in (0.01, 0.02, 0.04, 0.08, 0.5):
        m.on_finish("done", x, 1)
    snap = m.snapshot()
    assert {"p50_latency_s", "p95_latency_s", "p99_latency_s"} <= set(snap)
    assert snap["p50_latency_s"] == pytest.approx(0.04, rel=0.1)
    assert snap["completed"] == 5
    empty = GatewayMetrics().snapshot()
    assert empty["p50_latency_s"] is None and empty["p99_latency_s"] is None


# ---------------------------------------------------------------------------
# gateway integration
# ---------------------------------------------------------------------------


def test_gateway_tracing_off_by_default():
    left, right, world = _join_world()
    with Gateway(_session(world), max_inflight=2) as gw:
        sess = gw.submit(_pipeline(left, right, world))
        assert sess.result(timeout=30.0)
        assert gw.tracer is None
        assert "stages" not in gw.snapshot()
        with pytest.raises(RuntimeError):
            gw.export_trace("/dev/null")


def test_gateway_trace_spans_sessions_and_exports(tmp_path):
    left, right, world = _join_world()
    with Gateway(_session(world), max_inflight=2, trace=True) as gw:
        s1 = gw.submit(_pipeline(left, right, world))
        s2 = gw.submit(_pipeline(left, right, world), tenant="b")
        r1, r2 = s1.result(timeout=30.0), s2.result(timeout=30.0)
        assert r1 == r2
        # one root session span per serve session, tagged with its sid
        roots = gw.tracer.session_spans()
        assert {s.attrs["sid"] for s in roots} == {s1.sid, s2.sid}
        # the session subtree spans layers: plan stages, operators, and the
        # dispatcher's fused batches (which run on the dispatcher thread)
        kinds = {s.kind for s in gw.session_trace(s1.sid)}
        assert {"session", "plan_stage", "operator"} <= kinds
        all_kinds = {s.kind for s in gw.tracer.spans()}
        assert "dispatch_batch" in all_kinds
        assert "cache_lookup" in all_kinds
        for sp in gw.tracer.spans(kind="dispatch_batch"):
            assert "fused_calls" in sp.attrs
        # snapshot carries the span-derived stage breakdown
        stages = gw.snapshot()["stages"]
        assert any(k.startswith("session/") for k in stages)
        assert any(k.startswith("operator/") for k in stages)
        # exports: JSONL lines and a Perfetto-loadable chrome trace
        pj = tmp_path / "gw.jsonl"
        pc = tmp_path / "gw.json"
        n = gw.export_trace(str(pj))
        assert n == len(gw.tracer.spans())
        assert all(json.loads(l) for l in pj.read_text().splitlines())
        gw.export_trace(str(pc), fmt="chrome")
        doc = json.loads(pc.read_text())
        assert len(doc["traceEvents"]) == n


def test_gateway_persists_stats_store_next_to_cache(tmp_path):
    left, right, world = _join_world()
    persist = str(tmp_path / "cache.json")
    with Gateway(_session(world), max_inflight=1,
                 persist_path=persist) as gw:
        gw.submit(_pipeline(left, right, world)).result(timeout=30.0)
        assert len(gw.stats_store) >= 1
    saved = StatsStore(persist + ".stats.json")
    assert len(saved) >= 1
    assert any(e["selectivity"] is not None for e in saved.snapshot())
    # a second gateway warm-starts from the persisted observations
    with Gateway(_session(world), max_inflight=1,
                 persist_path=persist) as gw2:
        assert len(gw2.stats_store) >= 1


def test_traced_run_is_record_identical_to_untraced():
    left, right, world = _join_world(seed=13)
    untraced = _pipeline(left, right, world).collect()
    tr = Tracer()
    with T.activate(tr):
        traced = _pipeline(left, right, world).collect()
    assert traced.records == untraced.records
    assert tr.spans(kind="plan_stage")
