"""Lazy plan layer: IR building, rewrite rules, batched execution.

Covers the tentpole acceptance surface: filter reordering picks the
cheap/selective predicate first (asserted via accounting oracle-call
counts), join pushdown preserves the gold output set, BatchedModelCache
dedups repeated prompts, and lazy pipelines reproduce the eager path
record-for-record (and stat-for-stat with optimization off).
"""
import numpy as np
import pytest

from repro.core import accounting
from repro.core.backends import synth
from repro.core.backends.base import CountedModel
from repro.core.frame import LazySemFrame, SemFrame, Session
from repro.core.plan import BatchedModelCache, Filter, Join, Map, Scan
from repro.core.plan.optimize import PlanOptimizer


def _session(world, *, with_proxy=False, log=None):
    return Session(oracle=synth.SimulatedModel(world, "oracle"),
                   proxy=synth.SimulatedModel(world, "proxy") if with_proxy else None,
                   embedder=synth.SimulatedEmbedder(world), sample_size=60)


def _frame(records, world, **kw):
    log = kw.pop("log", None)
    return SemFrame(records, _session(world, **kw), log)


def _calls(log, kind="oracle_calls"):
    return sum(st.get(kind, 0) for st in log)


# ---------------------------------------------------------------------------
# lazy == eager
# ---------------------------------------------------------------------------


def test_lazy_unoptimized_matches_eager_records_and_stats():
    left, right, world, *_ = synth.make_join_world(25, 8, seed=11)
    synth.add_phrase_predicate(world, left, "is checkable", 0.4, seed=11)

    elog, llog = [], []
    eager = (_frame(left, world, log=elog)
             .sem_filter("the {abstract} is checkable")
             .sem_join(right, "the {abstract} reports the {reaction:right}"))
    lazy = (_frame(left, world, log=llog).lazy()
            .sem_filter("the {abstract} is checkable")
            .sem_join(right, "the {abstract} reports the {reaction:right}")
            .collect(optimize=False))
    assert lazy.records == eager.records
    strip = lambda st: {k: v for k, v in st.items() if k != "wall_s"}
    assert [strip(s) for s in llog] == [strip(s) for s in elog]


def test_lazy_optimized_matches_eager_records_with_fewer_oracle_calls():
    """The acceptance pipeline: filter -> join, identical records, explain
    shows a rewrite, accounting shows strictly fewer oracle calls."""
    left, right, world, *_ = synth.make_join_world(40, 10, seed=12)
    synth.add_phrase_predicate(world, left, "is checkable", 0.2, seed=12)
    synth.add_phrase_predicate(world, left, "is in English", 0.85, seed=12)

    def build(sf):
        return (sf.sem_filter("the {abstract} is in English")
                  .sem_filter("the {abstract} is checkable")
                  .sem_join(right, "the {abstract} reports the {reaction:right}"))

    elog, llog = [], []
    eager = build(_frame(left, world, log=elog))
    lazy_frame = build(_frame(left, world, log=llog).lazy())
    out = lazy_frame.collect()
    assert out.records == eager.records
    assert any(r.rule == "reorder_filters" for r in lazy_frame.last_rewrites)
    assert _calls(llog) < _calls(elog)


# ---------------------------------------------------------------------------
# rewrite rules
# ---------------------------------------------------------------------------


def test_filter_reorder_picks_selective_predicate_first():
    records, world, oracle, proxy, emb = synth.make_filter_world(120, seed=13)
    synth.add_phrase_predicate(world, records, "is rare", 0.1, seed=13)
    synth.add_phrase_predicate(world, records, "is common", 0.9, seed=13)

    log = []
    lz = (_frame(records, world, log=log).lazy()
          .sem_filter("the {claim} is common")       # broad first, as written
          .sem_filter("the {claim} is rare"))
    out = lz.collect()
    # optimized order runs the rare predicate over all N and the common one
    # only over the ~0.1*N survivors (plus the shared probe sample)
    n = len(records)
    assert _calls(log) < n + int(0.9 * n)            # << the as-written cost
    assert any(r.rule == "reorder_filters" for r in lz.last_rewrites)
    # output identical to the as-written eager chain
    eager = (_frame(records, world)
             .sem_filter("the {claim} is common")
             .sem_filter("the {claim} is rare"))
    assert out.records == eager.records


def test_join_pushdown_preserves_gold_output_set():
    left, right, world, *_ = synth.make_join_world(20, 8, seed=14)
    synth.add_phrase_predicate(world, left, "is recent", 0.35, seed=14)

    elog, llog = [], []
    eager = (_frame(left, world, log=elog)
             .sem_join(right, "the {abstract} reports the {reaction:right}")
             .sem_filter("the {abstract} is recent"))
    lz = (_frame(left, world, log=llog).lazy()
          .sem_join(right, "the {abstract} reports the {reaction:right}")
          .sem_filter("the {abstract} is recent"))
    out = lz.collect()
    assert any(r.rule == "pushdown_filter" for r in lz.last_rewrites)
    assert out.records == eager.records              # gold set preserved
    assert _calls(llog) < _calls(elog)               # filtered-left pair space


def test_map_fusion_single_prompt_pass():
    records, world, *_ = synth.make_filter_world(30, seed=15)
    log = []
    lz = (_frame(records, world, log=log).lazy()
          .sem_map("a query for {claim}", out_column="q")
          .sem_map("a title for {claim}", out_column="t"))
    out = lz.collect()
    assert any(r.rule == "fuse_maps" for r in lz.last_rewrites)
    assert _calls(log, "generate_calls") == len(records)   # one pass, not two
    assert all("q" in t and "t" in t for t in out.records)


def test_map_fusion_skipped_on_dependency():
    records, world, *_ = synth.make_filter_world(10, seed=16)
    sess = _session(world)
    plan = Map(Map(Scan(records), "a query for {claim}", out_column="q"),
               "rewrite {q}", out_column="t")
    opt = PlanOptimizer(sess)
    optimized = opt.optimize(plan)
    assert isinstance(optimized, Map) and isinstance(optimized.child, Map)
    assert not any(r.rule == "fuse_maps" for r in opt.applied)


def test_sim_prefilter_injected_under_high_fanout_join():
    left, right, world, *_ = synth.make_join_world(30, 10, seed=17)
    log = []
    lz = (_frame(left, world, log=log).lazy()
          .sem_join(right, "the {abstract} reports the {reaction:right}"))
    out = lz.collect(prefilter_threshold=100)        # 300 pairs > threshold
    assert any(r.rule == "inject_sim_prefilter" for r in lz.last_rewrites)
    assert _calls(log) < len(left) * len(right)
    gold = (_frame(left, world)
            .sem_join(right, "the {abstract} reports the {reaction:right}"))
    gold_pairs = {(t["id"], t["right_id"]) for t in gold.records}
    got_pairs = {(t["id"], t["right_id"]) for t in out.records}
    assert got_pairs <= gold_pairs                   # prefilter never invents
    assert len(got_pairs & gold_pairs) >= 0.6 * len(gold_pairs)


# ---------------------------------------------------------------------------
# BatchedModelCache
# ---------------------------------------------------------------------------


def test_batched_cache_dedups_repeated_prompts():
    records, world, *_ = synth.make_filter_world(20, seed=18)
    cached = BatchedModelCache(CountedModel(synth.SimulatedModel(world, "oracle"),
                                            "oracle"))
    prompts = [f"the {t['claim']} holds" for t in records]
    with accounting.track("first") as st1:
        b1, s1 = cached.predicate(prompts + prompts[:5])  # in-batch dupes
    assert st1.oracle_calls == 20                     # dupes coalesced
    assert st1.cache_hits == 5
    with accounting.track("second") as st2:
        b2, s2 = cached.predicate(prompts)
    assert st2.oracle_calls == 0                      # served from the LRU
    assert st2.cache_hits == 20
    np.testing.assert_array_equal(b1[:20], b2)
    np.testing.assert_array_equal(s1[:20], s2)


def test_batched_cache_survives_batch_larger_than_capacity():
    """Self-eviction reassembly: inserting the tail of an over-capacity batch
    evicts its head from the LRU, but the per-prompt rows must still come
    back correct and in order (reassembly reads the batch-local map)."""
    records, world, *_ = synth.make_filter_world(8, seed=23)
    cached = BatchedModelCache(
        CountedModel(synth.SimulatedModel(world, "oracle"), "oracle"), capacity=3)
    prompts = [f"the {t['claim']} holds" for t in records]
    out = cached.generate(prompts)                    # batch (8) > capacity (3)
    assert len(out) == 8 and all(isinstance(x, str) for x in out)
    assert out == synth.SimulatedModel(world, "oracle").generate(prompts)
    passed, _ = cached.predicate(prompts)
    direct, _ = synth.SimulatedModel(world, "oracle").predicate(prompts)
    np.testing.assert_array_equal(passed, direct)


def test_batched_cache_lru_eviction_order():
    records, world, *_ = synth.make_filter_world(3, seed=26)
    cached = BatchedModelCache(
        CountedModel(synth.SimulatedModel(world, "oracle"), "oracle"), capacity=2)
    pa, pb, pc = [f"the {t['claim']} holds" for t in records]
    cached.predicate([pa])
    cached.predicate([pb])
    cached.predicate([pa])                            # refresh a; b is now LRU
    cached.predicate([pc])                            # evicts b, not a
    with accounting.track("probe") as st:
        cached.predicate([pa, pc])                    # both still cached
    assert st.oracle_calls == 0 and st.cache_hits == 2
    with accounting.track("probe2") as st2:
        cached.predicate([pb])                        # b was evicted
    assert st2.oracle_calls == 1 and st2.cache_hits == 0


def test_filter_reorder_uses_proxy_proposal_when_available():
    records, world, *_ = synth.make_filter_world(80, seed=24)
    synth.add_phrase_predicate(world, records, "is rare", 0.1, seed=24)
    synth.add_phrase_predicate(world, records, "is common", 0.9, seed=24)
    log = []
    lz = (SemFrame(records, _session(world, with_proxy=True, log=None), log).lazy()
          .sem_filter("the {claim} is common")
          .sem_filter("the {claim} is rare"))
    out = lz.collect()
    assert any(r.rule == "reorder_filters" for r in lz.last_rewrites)
    opt_stats = next(st for st in log if st["operator"] == "plan_optimize")
    assert opt_stats["proxy_calls"] >= len(records)   # proposal scored the base
    eager = (_frame(records, world)
             .sem_filter("the {claim} is common")
             .sem_filter("the {claim} is rare"))
    assert [t["id"] for t in out.records] == [t["id"] for t in eager.records]


def test_explain_then_collect_probes_once():
    records, world, *_ = synth.make_filter_world(60, seed=25)
    synth.add_phrase_predicate(world, records, "is rare", 0.1, seed=25)
    synth.add_phrase_predicate(world, records, "is common", 0.9, seed=25)
    log = []
    lz = (_frame(records, world, log=log)
          .lazy()
          .sem_filter("the {claim} is common")
          .sem_filter("the {claim} is rare"))
    lz.explain()
    lz.collect()
    explain_st = next(st for st in log if st["operator"] == "plan_explain")
    collect_st = next(st for st in log if st["operator"] == "plan_optimize")
    assert explain_st["oracle_calls"] > 0             # probes are visible
    # the shared optimizer memoizes selectivities: collect re-optimizes free
    assert collect_st["oracle_calls"] == 0 and collect_st["proxy_calls"] == 0


def test_batched_cache_choose_keyed_by_n_options():
    records, world, model, emb = synth.make_topic_world(6, 3, seed=19)
    cached = BatchedModelCache(CountedModel(model, "oracle"))
    prompts = [f"item {t['paper']}\n0. a\n1. b" for t in records]
    a = cached.choose(prompts, 2)
    b = cached.choose(prompts, 3)                     # different key space
    assert a.shape == b.shape == (6,)
    assert cached.misses == 12                        # no cross-n_options reuse


# ---------------------------------------------------------------------------
# IR / explain
# ---------------------------------------------------------------------------


def test_plan_columns_propagate_like_eager_schema():
    left, right, world, *_ = synth.make_join_world(5, 4, seed=20)
    plan = Join(Filter(Scan(left), "the {abstract} holds"), Scan(right),
                "the {abstract} reports the {reaction:right}")
    assert plan.columns() == {"id", "abstract", "right_id", "right_reaction"}


def test_explain_reports_costs_and_rewrites():
    left, right, world, *_ = synth.make_join_world(25, 8, seed=21)
    synth.add_phrase_predicate(world, left, "is recent", 0.3, seed=21)
    lz = (_frame(left, world).lazy()
          .sem_join(right, "the {abstract} reports the {reaction:right}")
          .sem_filter("the {abstract} is recent"))
    txt = lz.explain()
    assert "== logical plan (as written) ==" in txt
    assert "== optimized plan ==" in txt
    assert "estimated oracle calls" in txt
    assert "pushdown_filter" in txt


def test_lazy_validates_langex_against_plan_schema():
    records, world, *_ = synth.make_filter_world(5, seed=22)
    lz = _frame(records, world).lazy()
    with pytest.raises(KeyError):
        lz.sem_filter("the {nope} holds")
    assert isinstance(lz.sem_filter("the {claim} holds"), LazySemFrame)
