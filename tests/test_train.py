"""Training substrate: optimizer math, convergence, resume, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data.tokenizer import TOKENIZER
from repro.train import grad_compress, optimizer as opt
from repro.train.loop import LoopConfig, run


def test_adamw_matches_reference_math():
    cfg = opt.OptimizerConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                              weight_decay=0.0, clip_norm=1e9, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = opt.init_state(params, cfg)
    g = {"w": jnp.asarray([0.5, -0.1])}
    p2, s2, m = opt.apply_updates(cfg, params, state, g)
    # step1: m=0.1g*? m = (1-b1)g, v=(1-b2)g^2, mhat=g, vhat=g^2 -> delta=sign(g)
    want = params["w"] - 0.1 * jnp.sign(g["w"]) * (jnp.abs(g["w"]) / (jnp.abs(g["w"]) + cfg.eps))
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(want), rtol=1e-5)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    np.testing.assert_allclose(float(opt.global_norm(clipped)), 1.0, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = opt.OptimizerConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(opt.lr_at(cfg, s)) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] < lrs[2]
    assert lrs[2] >= lrs[3] >= lrs[4]
    assert lrs[4] >= cfg.learning_rate * cfg.min_lr_ratio * 0.99


def test_loss_decreases_and_resume():
    cfg = get_smoke("llama3.2-3b").with_(vocab_size=TOKENIZER.vocab_size)
    d = tempfile.mkdtemp()
    lc = LoopConfig(steps=8, batch=4, seq_len=64, ckpt_dir=d, ckpt_every=4,
                    log_every=100)
    ocfg = opt.OptimizerConfig(learning_rate=1e-3, total_steps=12, warmup_steps=1)
    m1 = run(cfg, ocfg, lc, log=lambda s: None)
    assert m1["last_step"] == 8
    # resume continues from the checkpoint, not from scratch
    lc2 = LoopConfig(steps=12, batch=4, seq_len=64, ckpt_dir=d, ckpt_every=4,
                     log_every=100)
    m2 = run(cfg, ocfg, lc2, log=lambda s: None)
    assert m2["last_step"] == 12
    assert m2["loss"] < 6.5  # byte-vocab CE starts ~ln(384)=5.95+margin; sane


def test_error_feedback_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    err = grad_compress.init_error_buffer(g)
    # telescoping: accumulated dequantized grads converge to accumulated true
    acc_true = np.zeros((64, 64))
    acc_deq = np.zeros((64, 64))
    for t in range(20):
        gt = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
        deq, err = grad_compress.compress_tree(gt, err)
        acc_true += np.asarray(gt["w"])
        acc_deq += np.asarray(deq["w"])
    resid = np.abs(acc_true - acc_deq).max()
    # residual stays bounded by one quantization step, does not accumulate
    assert resid < 0.25


def test_bf16_optimizer_state_variant():
    cfg = opt.OptimizerConfig(state_dtype="bfloat16", use_master=False)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init_state(params, cfg)
    assert "master" not in state
    assert state["m"]["w"].dtype == jnp.bfloat16
    p2, s2, _ = opt.apply_updates(cfg, params, state, {"w": jnp.ones(4, jnp.bfloat16)})
    assert p2["w"].dtype == jnp.bfloat16
