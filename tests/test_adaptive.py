"""Adaptive re-optimization + multi-query plan sharing (PR 8).

Three timescales of feedback are covered here:

  * cross-session — StatsStore EWMA decay / discounted load, feedback-
    informed initial costing (``sel_obs`` in explain output);
  * mid-query — the AdaptivePlanExecutor's greedy filter re-ranking,
    retrieval switching, and fragment resizing, each asserted *record-
    identical* to the static plan (the strict equivalence contract) while
    visibly cutting the oracle bill on drifting workloads;
  * multi-query — the MatViewRegistry materializing a shared subplan
    exactly once across concurrent gateway sessions.

The drifting workloads put filter chains above a ``sem_map`` on purpose:
a non-Scan base is unprobeable at plan time (rule 3 needs base records),
so the static plan keeps the as-written order and only the feedback loop —
warm store at plan time, live blending mid-query — can recover the cheap
order.
"""
import pytest

from repro.core.backends import synth
from repro.core.frame import SemFrame, Session
from repro.core.plan import AdaptivePlanExecutor, PartitionedExecutor
from repro.obs.analyze import explain_analyze
from repro.obs.stats_store import StatsStore
from repro.serve import Gateway, MatViewRegistry, plan_fingerprint


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _session(world, *, with_proxy=False, sample_size=60):
    return Session(
        oracle=synth.SimulatedModel(world, "oracle"),
        proxy=synth.SimulatedModel(world, "proxy") if with_proxy else None,
        embedder=synth.SimulatedEmbedder(world), sample_size=sample_size)


def _frame(records, world, *, log=None, **kw):
    return SemFrame(records, _session(world, **kw), log)


def _calls(log, kind="oracle_calls"):
    return sum(st.get(kind, 0) for st in log)


def _drift_world(n=80, seed=7):
    """Claims corpus with a broad (~0.9) and a narrow (~0.05) predicate:
    the as-written order (broad first) is the expensive one."""
    records, world, *_ = synth.make_filter_world(n, seed=seed)
    synth.add_phrase_predicate(world, records, "is broad", 0.9, seed=seed)
    synth.add_phrase_predicate(world, records, "is narrow", 0.05, seed=seed)
    return records, world


def _chain(frame):
    return (frame.lazy()
            .sem_map("a short note on {claim}", out_column="note")
            .sem_filter("the {claim} is broad")
            .sem_filter("the {claim} is narrow"))


# ---------------------------------------------------------------------------
# stats store: EWMA decay + discounted load
# ---------------------------------------------------------------------------


def test_stats_store_ewma_decay_weights_recent():
    s = StatsStore(decay=0.5)
    s.observe("filter", "fp", rows_in=100, rows_out=10)
    s.observe("filter", "fp", rows_in=100, rows_out=90)
    obs = s.get("filter", "fp")
    # runs is the EWMA weight mass, not a plain count
    assert obs.runs == pytest.approx(1.5)
    # additive semantics would average to 0.5; the EWMA leans recent
    assert obs.selectivity == pytest.approx(95 / 150)
    assert obs.selectivity > 0.6


def test_stats_store_load_discount_downweights_history(tmp_path):
    a = StatsStore()
    for _ in range(4):
        a.observe("filter", "fp", rows_in=50, rows_out=10,
                  stats={"oracle_calls": 50})
    path = a.save(str(tmp_path / "stats.json"))

    b = StatsStore()
    b.load(path, discount=0.5)
    obs = b.get("filter", "fp")
    assert obs.runs == pytest.approx(2.0)
    assert obs.oracle_calls == pytest.approx(100.0)
    # ratios survive the discount: it shrinks weight, not the estimate
    assert obs.selectivity == pytest.approx(0.2)

    c = StatsStore()
    c.load(path)                      # identity merge keeps additive ints
    assert c.get("filter", "fp").runs == 4


# ---------------------------------------------------------------------------
# equivalence: adaptive == static
# ---------------------------------------------------------------------------


def test_cold_adaptive_matches_static_records_and_bill():
    """With an empty store every live blend equals the plan-time prior, so
    the greedy chain replays the static order exactly: same records, same
    oracle bill."""
    records, world = _drift_world()
    slog, alog = [], []
    static = _chain(_frame(records, world, log=slog)).collect()
    adaptive = _chain(_frame(records, world, log=alog)).collect(adaptive=True)
    assert adaptive.records == static.records
    assert _calls(alog) == _calls(slog)


def test_adaptive_matches_static_across_operators():
    """Operator zoo: gold filter + join, cascade filter (tau calibration),
    topk + agg — adaptive runs must be record-identical, cascades also
    bill-identical (same tau thresholds imply same oracle region)."""
    left, right, world, *_ = synth.make_join_world(24, 8, seed=21)
    synth.add_phrase_predicate(world, left, "is checkable", 0.5, seed=21)

    def joined(f):
        return (f.lazy().sem_filter("the {abstract} is checkable")
                .sem_join(right, "the {abstract} reports the {reaction:right}"))
    s = joined(_frame(left, world)).collect()
    a = joined(_frame(left, world)).collect(adaptive=True)
    assert a.records == s.records

    def simjoined(f):
        return f.lazy().sem_sim_join(right, "abstract", "reaction", k=2)
    ss = simjoined(_frame(left, world)).collect()
    sa = simjoined(_frame(left, world)).collect(adaptive=True)
    assert sa.records == ss.records

    records, cworld, *_ = synth.make_filter_world(90, seed=22)
    synth.add_phrase_predicate(cworld, records, "is checkable", 0.4, seed=22)
    clog_s, clog_a = [], []
    cs = (_frame(records, cworld, with_proxy=True, log=clog_s).lazy()
          .sem_filter("the {claim} is checkable",
                      recall_target=0.9, precision_target=0.85).collect())
    ca = (_frame(records, cworld, with_proxy=True, log=clog_a).lazy()
          .sem_filter("the {claim} is checkable",
                      recall_target=0.9, precision_target=0.85)
          .collect(adaptive=True))
    assert ca.records == cs.records
    st_s = next(st for st in clog_s if st["operator"] == "sem_filter")
    st_a = next(st for st in clog_a if st["operator"] == "sem_filter")
    assert st_a["tau_plus"] == st_s["tau_plus"]
    assert st_a["tau_minus"] == st_s["tau_minus"]
    assert st_a["oracle_calls"] == st_s["oracle_calls"]
    assert st_a["proxy_calls"] == st_s["proxy_calls"]

    rrecords, rworld, *_ = synth.make_rank_world(32, compare_noise=0.0,
                                                 seed=23)

    def ranked(f):
        return (f.lazy().sem_topk("most accurate {abstract}", k=8)
                .sem_map("a group for {abstract}", out_column="bucket")
                .sem_agg("summarize: {abstract}", group_by="bucket",
                         fanout=4))
    rsess = Session(oracle=synth.SimulatedModel(rworld, "oracle"),
                    embedder=synth.SimulatedEmbedder(rworld), sample_size=30)
    rs = ranked(SemFrame(rrecords, rsess)).collect()
    ra = ranked(SemFrame(rrecords, Session(
        oracle=synth.SimulatedModel(rworld, "oracle"),
        embedder=synth.SimulatedEmbedder(rworld),
        sample_size=30))).collect(adaptive=True)
    assert ra.records == rs.records


def test_cascade_is_an_immovable_barrier():
    """A gold filter may never jump a cascade: the cascade's tau calibrates
    on its input set.  Even when a warm store makes the trailing narrow
    filter look cheapest, execution order — and therefore the cascade's
    input set, thresholds, and the full oracle+proxy bill — must match the
    static plan."""
    records, world = _drift_world(n=60, seed=9)
    synth.add_phrase_predicate(world, records, "is plausible", 0.5, seed=9)

    def chain(frame):
        return (frame.lazy()
                .sem_map("a short note on {claim}", out_column="note")
                .sem_filter("the {claim} is broad")
                .sem_filter("the {claim} is plausible",
                            recall_target=0.9, precision_target=0.85)
                .sem_filter("the {claim} is narrow"))

    store = StatsStore()
    chain(_frame(records, world, with_proxy=True)).collect(stats_store=store)

    slog, alog = [], []
    static = chain(_frame(records, world, with_proxy=True, log=slog)).collect()
    f = chain(_frame(records, world, with_proxy=True, log=alog))
    adaptive = f.collect(adaptive=True, stats_store=store)
    assert adaptive.records == static.records
    assert _calls(alog) == _calls(slog)
    assert _calls(alog, "proxy_calls") == _calls(slog, "proxy_calls")
    ex = f._exec_pair[2]
    assert not any(e.kind == "reorder_filters" for e in ex.replans)


# ---------------------------------------------------------------------------
# mid-query re-optimization: the three re-plan kinds
# ---------------------------------------------------------------------------


def test_warm_store_reorders_chain_and_cuts_bill():
    """The drift workload: broad(0.9) then narrow(0.05) as written.  After
    one observed run the adaptive executor promotes the narrow filter —
    record-identical, and the oracle bill drops from ~1.9N to ~1.05N."""
    records, world = _drift_world()
    store = StatsStore()
    warm = _chain(_frame(records, world)).collect(stats_store=store)

    slog, alog = [], []
    static = _chain(_frame(records, world, log=slog)).collect()
    f = _chain(_frame(records, world, log=alog))
    adaptive = f.collect(adaptive=True, stats_store=store)

    assert adaptive.records == static.records == warm.records
    assert _calls(alog) < 0.8 * _calls(slog)
    ex = f._exec_pair[2]
    assert isinstance(ex, AdaptivePlanExecutor)
    assert any(e.kind == "reorder_filters" for e in ex.replans)


def test_retrieval_switch_on_observed_corpus_is_record_identical():
    """Rule 5 prices the search corpus at the default filter selectivity
    (the chain sits above a map, so nothing is probeable) and plans IVF;
    the filter actually keeps ~4% of rows, so the adaptive executor
    re-chooses exact retrieval mid-query.  Records must match the static
    run (k >= surviving corpus puts IVF in its degenerate full-scan
    regime, so the planned backend is exact-equivalent here)."""
    records, world, *_ = synth.make_filter_world(400, seed=27)
    synth.add_phrase_predicate(world, records, "is narrow", 0.04, seed=27)

    def pipe(frame):
        return (frame.lazy()
                .sem_map("a short note on {claim}", out_column="note")
                .sem_filter("the {claim} is narrow")
                .sem_search("claim", "claim text 3", k=30))

    kw = dict(index_min_corpus=100, index_shared=True)
    f_s = pipe(_frame(records, world))
    static = f_s.collect(**kw)
    assert any(r.rule == "choose_retrieval" and "IVF" in r.detail
               for r in f_s.last_rewrites)

    f_a = pipe(_frame(records, world))
    adaptive = f_a.collect(adaptive=True, **kw)
    assert adaptive.records == static.records
    ex = f_a._exec_pair[2]
    switches = [e for e in ex.replans if e.kind == "switch_retrieval"]
    assert switches and "-> exact" in switches[0].reason


def test_fragment_resize_on_observed_rows():
    """Rule 6 plans 4 fragments for the second filter from the estimated
    ~100 input rows; the narrow filter actually leaves ~10, so the adaptive
    executor resizes to a single fragment — identical records (partitioned
    operators are output-identical by construction)."""
    records, world = _drift_world(n=200, seed=5)

    def pipe(frame):
        return (frame.lazy()
                .sem_map("a short note on {claim}", out_column="note")
                .sem_filter("the {claim} is narrow")
                .sem_filter("the {claim} is broad"))

    static = pipe(_frame(records, world)).collect(n_partitions=4)
    f = pipe(_frame(records, world))
    adaptive = f.collect(adaptive=True, n_partitions=4)
    assert adaptive.records == static.records
    ex = f._exec_pair[2]
    assert any(e.kind == "resize_fragments" for e in ex.replans)


# ---------------------------------------------------------------------------
# explain surfaces
# ---------------------------------------------------------------------------


def test_explain_plan_prints_observed_selectivity():
    records, world = _drift_world(n=40, seed=11)
    store = StatsStore()
    _chain(_frame(records, world)).collect(stats_store=store)
    cold = _chain(_frame(records, world)).explain()
    warm = _chain(_frame(records, world)).explain(stats_store=store)
    assert "sel_obs=" not in cold
    assert "sel_obs=" in warm


def test_explain_analyze_marks_replanned_nodes():
    """Live (executor-only) feedback: the store goes to explain_analyze's
    named parameter, so plan-time costing stays cold and the promotion
    happens mid-query — the promoted node carries the >> replanned marker."""
    records, world = _drift_world(n=60, seed=13)
    synth.add_phrase_predicate(world, records, "is typical", 0.5, seed=13)

    def chain3(frame):
        return (frame.lazy()
                .sem_map("a short note on {claim}", out_column="note")
                .sem_filter("the {claim} is broad")
                .sem_filter("the {claim} is narrow")
                .sem_filter("the {claim} is typical"))

    store = StatsStore()
    rep1 = explain_analyze(chain3(_frame(records, world)), stats_store=store)
    rep2 = explain_analyze(chain3(_frame(records, world)), stats_store=store,
                           adaptive=True)
    assert rep2.records == rep1.records
    text = rep2.render()
    assert ">> replanned:" in text
    assert "reorder_filters" in text


def test_repro_adaptive_env_flips_default(monkeypatch):
    records, world = _drift_world(n=8, seed=2)
    monkeypatch.setenv("REPRO_ADAPTIVE", "1")
    f = _frame(records, world).lazy().sem_filter("the {claim} is broad")
    f.collect()
    assert isinstance(f._exec_pair[2], AdaptivePlanExecutor)
    monkeypatch.setenv("REPRO_ADAPTIVE", "0")
    g = _frame(records, world).lazy().sem_filter("the {claim} is broad")
    g.collect()
    assert type(g._exec_pair[2]) is PartitionedExecutor


# ---------------------------------------------------------------------------
# multi-query: materialized subplan sharing
# ---------------------------------------------------------------------------


def test_plan_fingerprint_identity_and_registry_unit():
    records, world = _drift_world(n=10, seed=3)
    f1 = _frame(records, world).lazy().sem_filter("the {claim} is broad")
    f2 = _frame(records, world).lazy().sem_filter("the {claim} is broad")
    f3 = _frame(records, world).lazy().sem_filter("the {claim} is narrow")
    fp1, fp2, fp3 = (plan_fingerprint(f.plan) for f in (f1, f2, f3))
    assert fp1 == fp2
    assert fp1 != fp3

    reg = MatViewRegistry(capacity=4)
    # a bare scan is never worth materializing
    assert reg.key_for(f1.plan.child) is None
    assert reg.key_for(f1.plan) == fp1

    rows1, hit1 = reg.get_or_compute(fp1, lambda: [{"a": 1}])
    rows2, hit2 = reg.get_or_compute(
        fp1, lambda: (_ for _ in ()).throw(AssertionError("recomputed")))
    assert (hit1, hit2) == (False, True)
    assert rows1 == rows2 == [{"a": 1}]
    assert rows1 is not rows2          # callers never alias the stored view
    m = reg.metrics()
    assert m["matview_builds"] == 1
    assert m["matview_hits"] == 1


def test_gateway_matview_materializes_shared_subplan_once():
    """N concurrent sessions over the same fingerprinted subplan: exactly
    one computation, the rest served from the view."""
    records, world = _drift_world(n=40, seed=17)
    sess = _session(world, sample_size=30)
    frames = [SemFrame(records, sess).lazy()
              .sem_filter("the {claim} is broad") for _ in range(6)]
    with Gateway(sess, max_inflight=4, window_s=0.02, matview=True) as gw:
        handles = [gw.submit(f) for f in frames]
        results = [h.result(timeout=60) for h in handles]
        snap = gw.snapshot()
    assert snap["matview_builds"] == 1
    assert snap["matview_hits"] == 5
    assert all(r == results[0] for r in results)
    assert results[0] is not results[1]


def test_gateway_adaptive_counts_replans():
    records, world = _drift_world(n=60, seed=4)
    sess = _session(world, sample_size=30)

    def pipe():
        return (SemFrame(records, sess).lazy()
                .sem_map("a short note on {claim}", out_column="note")
                .sem_filter("the {claim} is broad")
                .sem_filter("the {claim} is narrow"))

    with Gateway(sess, max_inflight=2, window_s=0.02, adaptive=True) as gw:
        r1 = gw.submit(pipe()).result(timeout=60)
        r2 = gw.submit(pipe()).result(timeout=60)   # warm store: reorders
        snap = gw.snapshot()
    assert r1 == r2
    assert snap["replans"] >= 1
