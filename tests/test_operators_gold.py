"""Gold algorithms: exactness on noiseless worlds (Table 1 semantics)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests need the 'test' extra
    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    class _Stub:  # absorbs st.text(...) / @settings(...) at collection time
        def __getattr__(self, _name):
            return lambda *a, **k: None

        def __call__(self, *a, **k):
            return lambda f: f

    settings = st = _Stub()

from repro.core.backends import synth
from repro.core.backends.base import CountedModel
from repro.core.frame import SemFrame, Session
from repro.core.langex import Langex, as_langex
from repro.core.operators.agg import sem_agg_fold, sem_agg_hierarchical
from repro.core.operators.mapex import _snap_to_source
from repro.core.operators.topk import (sem_topk_heap, sem_topk_quadratic,
                                       sem_topk_quickselect)


def test_filter_gold_equals_truth():
    records, world, oracle, _, _ = synth.make_filter_world(300, seed=5)
    sess = Session(oracle=oracle)
    sf = SemFrame(records, sess)
    out = sf.sem_filter("{claim} holds")
    got = {t["id"] for t in out.records}
    want = {r for r, v in world.filter_truth.items() if v}
    assert got == want
    assert sf.last_stats()["oracle_calls"] == 300  # linear pass, one per tuple


def test_join_gold_equals_truth_and_is_quadratic():
    left, right, world, oracle, _, _ = synth.make_join_world(12, 9, seed=6)
    sess = Session(oracle=oracle)
    sf = SemFrame(left, sess)
    out = sf.sem_join(right, "the {abstract} reports the {reaction:right}")
    got = {(t["id"], t["right_id"]) for t in out.records}
    want = {p for p, v in world.join_truth.items() if v}
    assert got == want
    assert sf.last_stats()["oracle_calls"] == 12 * 9


@pytest.mark.parametrize("algo,fn", [
    ("quickselect", sem_topk_quickselect),
    ("quadratic", sem_topk_quadratic),
    ("heap", sem_topk_heap),
])
def test_topk_algorithms_exact_at_zero_noise(algo, fn):
    records, world, model, _, _ = synth.make_rank_world(60, compare_noise=1e-9, seed=7)
    model = CountedModel(model, "oracle")
    if algo == "quickselect":
        idx, stt = fn(records, "{abstract}", 8, model, seed=0)
    else:
        idx, stt = fn(records, "{abstract}", 8, model)
    want = sorted(range(60), key=lambda i: -world.rank_value[records[i]["id"]])[:8]
    assert list(idx) == want  # exact ordered top-k
    if algo == "quadratic":
        assert stt["compare_calls"] == 60 * 59 // 2


def test_topk_call_complexity_ordering():
    """Quadratic must cost ~an order of magnitude more comparisons (Table 7)."""
    records, world, model, _, piv = synth.make_rank_world(80, compare_noise=1e-9, seed=8)
    model = CountedModel(model, "oracle")
    _, st_q = sem_topk_quickselect(records, "{abstract}", 10, model, seed=0)
    _, st_quad = sem_topk_quadratic(records, "{abstract}", 10, model)
    assert st_quad["compare_calls"] > 5 * st_q["compare_calls"]


def test_topk_pivot_optimization_lossless():
    """§3.4: similarity-guided pivots change cost, never the answer."""
    records, world, model, _, piv = synth.make_rank_world(70, compare_noise=1e-9, seed=9)
    a, _ = sem_topk_quickselect(records, "{abstract}", 6, model, seed=1)
    b, _ = sem_topk_quickselect(records, "{abstract}", 6, model, seed=1,
                                pivot_scores=piv)
    assert list(a) == list(b)


def test_agg_hierarchical_covers_all_and_logarithmic_depth():
    records, world, model, _ = synth.make_topic_world(100, 3, seed=10)
    model = CountedModel(model, "oracle")
    out, stt = sem_agg_hierarchical(records, "summarize {paper}", model, fanout=8)
    assert isinstance(out, str) and out
    assert stt["generate_calls"] <= 100 / 8 + 5  # ~n/fanout + upper levels
    out2, st2 = sem_agg_fold(records[:10], "summarize {paper}", model)
    assert st2["generate_calls"] == 9  # sequential fold: n-1 calls


@given(st.text(min_size=1, max_size=80), st.integers(0, 79), st.integers(1, 30))
@settings(max_examples=40, deadline=None)
def test_extract_snap_always_substring(source, start, length):
    answer = source[start % len(source):][:length]
    got = _snap_to_source(answer, source)
    assert got in source


@given(st.text(alphabet=st.characters(blacklist_characters="{}"), max_size=40))
def test_langex_passthrough_without_fields(t):
    lx = Langex(t)
    assert lx.fields == []
    assert lx.render({}) == t


def test_langex_parsing_and_render():
    lx = as_langex("the {abstract:left} uses the {dataset:right}")
    assert [f.name for f in lx.fields] == ["abstract", "dataset"]
    assert lx.is_binary
    got = lx.render({"abstract": "A"}, {"dataset": "B"})
    assert got == "the A uses the B"
    with pytest.raises(KeyError):
        lx.validate({"abstract"}, {"nope"})


def test_sim_join_and_search_roundtrip():
    records, world, model, emb = synth.make_topic_world(50, 5, seed=11)
    sess = Session(oracle=model, embedder=emb)
    sf = SemFrame(records, sess)
    idx = sf.sem_index("paper")
    hits = sf.sem_search("paper", records[7]["paper"], k=1, index=idx)
    assert hits.records[0]["id"] == records[7]["id"]
    left5 = SemFrame(records[:5], sess)
    joined = left5.sem_sim_join(records, "paper", "paper", k=1)
    assert all(t["right_id"] == t["id"] for t in joined.records)  # self-match


def test_sem_map_and_extract():
    records, world, model, emb = synth.make_topic_world(10, 3, seed=12)
    sess = Session(oracle=model, embedder=emb)
    sf = SemFrame(records, sess)
    mapped = sf.sem_map("classify {paper}")
    assert all("mapped" in t for t in mapped.records)
    ex = sf.sem_extract("find the paper id in {paper}", source_field="paper")
    for t in ex.records:
        assert t["extracted"] in t["paper"]
