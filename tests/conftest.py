import os
import sys

# Tests run on the single real CPU device (the dry-run sets its own 512-device
# flag in its own process; see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
