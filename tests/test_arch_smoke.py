"""Per-architecture smoke tests (assignment requirement): reduced same-family
configs run a real forward + train step on CPU, asserting shapes and no NaNs;
prefill/decode consistency ties the serving path to the training path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config, get_smoke, input_specs
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.trainstep import make_train_step

ALL_ARCHS = sorted(ARCHS)


def _extra(cfg, key, b):
    extra = {}
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(key, (b, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        extra["audio_frames"] = jax.random.normal(key, (b, cfg.num_audio_frames, cfg.d_model), jnp.float32)
    return extra or None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_decode_consistency(arch):
    cfg = get_smoke(arch)
    if cfg.is_moe:
        cfg = cfg.with_(capacity_factor=8.0)  # no drops -> decode must match
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = _extra(cfg, key, B)

    logits, aux = registry.forward(cfg, params, tokens, extra=extra)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()

    cache = registry.init_cache(cfg, B, S + 4)
    lp, cache = registry.prefill(cfg, params, tokens[:, :S - 1], cache, extra=extra)
    assert np.allclose(np.asarray(lp), np.asarray(logits[:, :S - 1]), atol=1e-3)
    ld, _ = registry.decode_step(cfg, params, tokens[:, S - 1:S], cache,
                                 jnp.int32(S - 1), extra=extra)
    assert np.allclose(np.asarray(ld[:, 0]), np.asarray(logits[:, S - 1]), atol=1e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = registry.init_params(cfg, key)
    ocfg = opt.OptimizerConfig(total_steps=2, warmup_steps=1)
    state = opt.init_state(params, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, microbatches=2))
    B, S = 4, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    extra = _extra(cfg, key, B)
    if extra:
        batch.update(extra)
    params2, state2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for name, (L, d, h, hk, ff, v) in spec.items():
        cfg = get_config(name)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, hk, ff, v), name


def test_param_counts_near_nameplate():
    approx = {"qwen2-72b": 72e9, "mixtral-8x22b": 141e9,
              "llama4-maverick-400b-a17b": 400e9, "llama3.2-3b": 3.2e9,
              "deepseek-7b": 7e9, "xlstm-125m": 125e6}
    for name, n in approx.items():
        got = get_config(name).param_count()
        assert 0.65 * n < got < 1.35 * n, (name, got, n)


def test_shape_cells_and_skips():
    cells = 0
    skips = []
    for arch in ALL_ARCHS:
        for shape in SHAPES.values():
            ok, why = cell_applicable(get_config(arch), shape)
            cells += 1
            if not ok:
                skips.append((arch, shape.name))
    assert cells == 40
    assert all(s == "long_500k" for _, s in skips)
    assert {a for a, _ in skips} == set(ALL_ARCHS) - {"xlstm-125m", "zamba2-7b"}


def test_input_specs_cover_modalities():
    cfg = get_config("whisper-small")
    specs = input_specs(cfg, SHAPES["train_4k"])
    assert specs["audio_frames"].shape == (256, 1500, 768)
    cfg = get_config("llama-3.2-vision-11b")
    specs = input_specs(cfg, SHAPES["decode_32k"])
    assert specs["tokens"].shape == (128, 1)
    assert "cache_len" in specs
