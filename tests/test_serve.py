"""Serving-layer tests: dispatcher fusion, shared-cache TTL/eviction/
persistence, gateway admission/fairness/cancellation/deadlines, per-session
accounting, and the satellite fixes (CountedModel role attribution, scheduler
retry-state reset).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import accounting
from repro.core.backends import synth
from repro.core.backends.base import CountedModel
from repro.core.backends.testing import CountingBackend
from repro.core.frame import SemFrame, Session
from repro.core.plan.cache import BatchedModelCache
from repro.engine.scheduler import ContinuousBatchScheduler, Request
from repro.serve import (AdmissionError, DispatchError, Gateway,
                         MicroBatchDispatcher, SessionCancelled,
                         SessionDeadlineExceeded, SharedSemanticCache)
from repro.serve.dispatch import DispatchedModel


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _world(n=24, seed=3):
    left, right, world, *_ = synth.make_join_world(n, 8, seed=seed)
    synth.add_phrase_predicate(world, left, "is checkable", 0.4, seed=seed)
    synth.add_phrase_predicate(world, left, "is recent", 0.3, seed=seed)
    return left, right, world


def _session(world, *, oracle=None):
    return Session(oracle=oracle or synth.SimulatedModel(world, "oracle"),
                   embedder=synth.SimulatedEmbedder(world), sample_size=30)


def _pipeline(records, right, session):
    return (SemFrame(records, session).lazy()
            .sem_filter("the {abstract} is checkable")
            .sem_join(right, "the {abstract} reports the {reaction:right}"))


# ---------------------------------------------------------------------------
# dispatcher: cross-query micro-batch fusion
# ---------------------------------------------------------------------------


def test_dispatcher_fuses_concurrent_calls_into_one_backend_batch():
    left, _, world = _world()
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    d = MicroBatchDispatcher(oracle=backend, window_s=0.05, max_batch=1000)
    prompts_a = [f"the {t['abstract']} is checkable" for t in left[:10]]
    prompts_b = [f"the {t['abstract']} is checkable" for t in left[10:20]]
    out = {}

    def call(name, ps):
        out[name] = DispatchedModel(d, "oracle", tag=name).predicate(ps)

    threads = [threading.Thread(target=call, args=("a", prompts_a)),
               threading.Thread(target=call, args=("b", prompts_b))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    d.close()
    assert len(backend.batches) == 1            # one fused backend batch
    assert backend.n_prompts == 20
    # each caller got rows for exactly its own prompts, in its own order
    direct = synth.SimulatedModel(world, "oracle")
    np.testing.assert_array_equal(out["a"][0], direct.predicate(prompts_a)[0])
    np.testing.assert_array_equal(out["b"][0], direct.predicate(prompts_b)[0])
    assert d.stats()["fused_calls"] == 2 and d.stats()["fused_batches"] == 1


def test_dispatcher_dedups_shared_prompts_and_attributes_owners():
    left, _, world = _world()
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    d = MicroBatchDispatcher(oracle=backend, window_s=0.05, max_batch=1000)
    shared = [f"the {t['abstract']} is checkable" for t in left[:12]]
    results = {}

    def call(name):
        with accounting.track(name) as st:
            DispatchedModel(d, "oracle", tag=name).predicate(shared)
        results[name] = st

    threads = [threading.Thread(target=call, args=(n,)) for n in ("a", "b")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    d.close()
    assert backend.n_prompts == 12              # dedup across the two callers
    sts = [results["a"], results["b"]]
    assert sorted(st.oracle_calls for st in sts) == [0, 12]   # one owner pays
    assert sorted(st.cache_hits for st in sts) == [0, 12]     # one rides free
    assert d.stats()["cross_shared"] == 12


def test_dispatcher_size_trigger_flushes_before_window():
    left, _, world = _world()
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    d = MicroBatchDispatcher(oracle=backend, window_s=5.0, max_batch=8)
    prompts = [f"the {t['abstract']} is checkable" for t in left[:8]]
    t0 = time.monotonic()
    DispatchedModel(d, "oracle").predicate(prompts)
    elapsed = time.monotonic() - t0
    d.close()
    assert elapsed < 1.0                        # did not wait out the window
    assert backend.n_prompts == 8


def test_dispatcher_propagates_backend_errors_to_all_callers():
    class Exploding:
        def predicate(self, prompts):
            raise RuntimeError("backend down")

    d = MicroBatchDispatcher(oracle=Exploding(), window_s=0.02)
    errors = []

    def call():
        try:
            DispatchedModel(d, "oracle").predicate(["p1", "p2"])
        except DispatchError as exc:
            errors.append(exc)

    threads = [threading.Thread(target=call) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    d.close()
    assert len(errors) == 2


def test_dispatcher_buckets_choose_by_n_options():
    records, _world2, model, _emb = synth.make_topic_world(6, 3, seed=9)
    backend = CountingBackend(model)
    d = MicroBatchDispatcher(oracle=backend, window_s=0.02)
    h = DispatchedModel(d, "oracle")
    prompts = [f"item {t['paper']}\n0. a\n1. b" for t in records]
    a = h.choose(prompts, 2)
    b = h.choose(prompts, 3)
    d.close()
    assert a.shape == b.shape == (6,)
    assert len(backend.batches) == 2            # separate buckets per arity


# ---------------------------------------------------------------------------
# shared semantic cache: TTL, eviction, namespaces, persistence
# ---------------------------------------------------------------------------


def test_store_ttl_expiry_forces_reissue():
    clock = {"t": 0.0}
    store = SharedSemanticCache(ttl_s=10.0, clock=lambda: clock["t"])
    store.put(("oracle", "predicate", "p"), [True, 0.9], owner="s1")
    assert store.get(("oracle", "predicate", "p"))[0]
    clock["t"] = 9.9
    assert store.get(("oracle", "predicate", "p"))[0]   # still fresh
    clock["t"] = 20.0
    found, _ = store.get(("oracle", "predicate", "p"))
    assert not found and store.expirations == 1


def test_store_lru_eviction_order():
    store = SharedSemanticCache(capacity=2)
    store.put(("oracle", "g", "a"), 1)
    store.put(("oracle", "g", "b"), 2)
    store.get(("oracle", "g", "a"))             # refresh a; b is now LRU
    store.put(("oracle", "g", "c"), 3)
    assert ("oracle", "g", "a") in store
    assert ("oracle", "g", "b") not in store    # evicted
    assert ("oracle", "g", "c") in store
    assert store.evictions == 1


def test_store_namespaces_isolate_roles():
    store = SharedSemanticCache()
    store.put(("oracle", "predicate", "p"), [True, 0.99])
    assert not store.get(("proxy", "predicate", "p"))[0]


def test_store_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "semcache.jsonl")
    s1 = SharedSemanticCache(persist_path=path)
    s1.put(("oracle", "predicate", "p1"), [True, 0.9], owner="runA")
    s1.put(("oracle", "generate", "p2"), "answer", owner="runA")
    s1.put(("embed", "embed", "p3"), [0.1, 0.2])    # memory-only namespace
    s1.close()
    s2 = SharedSemanticCache(persist_path=path)
    assert s2.loaded == 2
    found, row = s2.get(("oracle", "predicate", "p1"), requester="runB")
    assert found and row == [True, 0.9]
    assert s2.cross_hits == 1                   # owner runA != requester runB
    assert not s2.get(("embed", "embed", "p3"))[0]
    s2.close()


def test_batched_cache_shared_store_two_executors(tmp_path):
    """Satellite: two executors over one store — the second pays nothing,
    and TTL expiry makes it pay again."""
    records, world, *_ = synth.make_filter_world(15, seed=31)
    clock = {"t": 0.0}
    store = SharedSemanticCache(ttl_s=100.0, clock=lambda: clock["t"])
    prompts = [f"the {t['claim']} holds" for t in records]

    def run(requester):
        cached = BatchedModelCache(
            CountedModel(synth.SimulatedModel(world, "oracle"), "oracle"),
            store=store, namespace="oracle", requester=requester)
        with accounting.track(requester) as st:
            passed, _ = cached.predicate(prompts)
        return passed, st

    b1, st1 = run("exec1")
    b2, st2 = run("exec2")
    assert st1.oracle_calls == 15 and st1.cache_hits == 0
    assert st2.oracle_calls == 0 and st2.cache_hits == 15   # shared hits
    assert store.cross_hits == 15
    np.testing.assert_array_equal(b1, b2)
    clock["t"] = 200.0                          # everything expires
    b3, st3 = run("exec3")
    assert st3.oracle_calls == 15 and st3.cache_hits == 0   # re-issued
    np.testing.assert_array_equal(b1, b3)


# ---------------------------------------------------------------------------
# gateway: concurrency, admission, fairness, cancellation, deadlines
# ---------------------------------------------------------------------------


def test_gateway_concurrent_sessions_match_serial_results():
    left, right, world = _world(n=30, seed=7)
    serial = []
    for _ in range(4):
        serial.append(_pipeline(left, right, _session(world)).collect().records)

    with Gateway(_session(world), max_inflight=4, window_s=0.02) as gw:
        handles = [gw.submit(_pipeline(left, right, gw.session))
                   for _ in range(4)]
        rows = [h.result(timeout=60) for h in handles]
        snap = gw.snapshot()
    assert rows == serial
    assert snap["completed"] == 4 and snap["failed"] == 0
    assert snap["p95_latency_s"] is not None


def test_gateway_cross_query_sharing_beats_serial_backend_cost():
    left, right, world = _world(n=30, seed=8)
    serial_backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    for _ in range(4):
        _pipeline(left, right, _session(world, oracle=serial_backend)).collect()
    serial_prompts = serial_backend.n_prompts

    shared_backend = CountingBackend(synth.SimulatedModel(world, "oracle"))
    with Gateway(_session(world, oracle=shared_backend), max_inflight=4,
                 window_s=0.02) as gw:
        handles = [gw.submit(_pipeline(left, right, gw.session))
                   for _ in range(4)]
        for h in handles:
            h.result(timeout=60)
        snap = gw.snapshot()
    assert shared_backend.n_prompts < serial_prompts
    assert shared_backend.n_prompts <= serial_prompts / 2   # ~4x sharing
    assert snap["cross_query_hit_rate"] > 0


def test_gateway_admission_rejects_when_queue_full():
    left, right, world = _world(n=12, seed=10)
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"),
                              slow_marker="<rec:", slow_s=0.4)
    gw = Gateway(_session(world, oracle=backend), max_inflight=1,
                 max_pending=1, window_s=0.005)
    try:
        first = gw.submit(_pipeline(left, right, gw.session))
        backend.first_prompt.wait(5.0)          # worker is now busy
        second = gw.submit(_pipeline(left, right, gw.session))  # fills queue
        with pytest.raises(AdmissionError):
            gw.submit(_pipeline(left, right, gw.session))
        assert gw.snapshot()["rejected"] == 1
        first.result(timeout=60)
        second.result(timeout=60)
    finally:
        gw.close()


def test_gateway_fairness_round_robin_across_tenants():
    left, right, world = _world(n=10, seed=11)
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"),
                              slow_marker="<rec:", slow_s=0.05)
    gw = Gateway(_session(world, oracle=backend), max_inflight=1,
                 window_s=0.002)
    try:
        plan = lambda: _pipeline(left, right, gw.session)  # noqa: E731
        blocker = gw.submit(plan(), tenant="A")
        backend.first_prompt.wait(5.0)
        a2 = gw.submit(plan(), tenant="A")
        a3 = gw.submit(plan(), tenant="A")
        b1 = gw.submit(plan(), tenant="B")      # submitted last, tenant B
        for h in (blocker, a2, a3, b1):
            h.result(timeout=60)
        # round-robin: B's first session starts before A's backlog drains
        assert b1.started_at < a3.started_at
    finally:
        gw.close()


def test_gateway_cancel_queued_session():
    left, right, world = _world(n=10, seed=12)
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"),
                              slow_marker="<rec:", slow_s=0.3)
    gw = Gateway(_session(world, oracle=backend), max_inflight=1,
                 window_s=0.005)
    try:
        blocker = gw.submit(_pipeline(left, right, gw.session))
        backend.first_prompt.wait(5.0)
        victim = gw.submit(_pipeline(left, right, gw.session))
        victim.cancel()
        with pytest.raises(SessionCancelled):
            victim.result(timeout=60)
        assert victim.status == "cancelled"
        blocker.result(timeout=60)
        assert gw.snapshot()["cancelled"] == 1
    finally:
        gw.close()


def test_gateway_cancel_running_session_between_stages():
    left, right, world = _world(n=10, seed=13)
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"),
                              slow_marker="is checkable", slow_s=0.3)
    gw = Gateway(_session(world, oracle=backend), max_inflight=1,
                 window_s=0.005)
    try:
        # filter (slow) then join: cancel lands at the stage boundary
        sess = gw.submit(_pipeline(left, right, gw.session), optimize=False)
        backend.first_prompt.wait(5.0)          # stage 1 model work started
        sess.cancel()
        with pytest.raises(SessionCancelled):
            sess.result(timeout=60)
        assert not backend.saw("reports the")   # join stage never issued
    finally:
        gw.close()


def test_gateway_deadline_expires_session():
    left, right, world = _world(n=10, seed=14)
    backend = CountingBackend(synth.SimulatedModel(world, "oracle"),
                              slow_marker="<rec:", slow_s=0.4)
    gw = Gateway(_session(world, oracle=backend), max_inflight=1,
                 window_s=0.005)
    try:
        blocker = gw.submit(_pipeline(left, right, gw.session))
        backend.first_prompt.wait(5.0)
        doomed = gw.submit(_pipeline(left, right, gw.session), deadline_s=0.05)
        with pytest.raises(SessionDeadlineExceeded):
            doomed.result(timeout=60)
        assert doomed.status == "expired"
        blocker.result(timeout=60)
        assert gw.snapshot()["expired"] == 1
    finally:
        gw.close()


def test_gateway_per_session_stats_rollup():
    left, right, world = _world(n=20, seed=15)
    with Gateway(_session(world), max_inflight=2, window_s=0.01) as gw:
        handles = [gw.submit(_pipeline(left, right, gw.session))
                   for _ in range(3)]
        for h in handles:
            h.result(timeout=60)
    for h in handles:
        assert h.stats is not None
        # every prompt a session asked for was either paid for or shared
        assert h.stats.oracle_calls + h.stats.cache_hits > 0
        assert h.stats.wall_s > 0
        assert h.summary()["stats"]["oracle_calls"] == h.stats.oracle_calls
    # sharing means the 3 sessions together paid for one session's prompts
    paid = sum(h.stats.oracle_calls for h in handles)
    asked = [h.stats.oracle_calls + h.stats.cache_hits for h in handles]
    assert paid <= min(asked) + 5               # probes/races tolerance


# ---------------------------------------------------------------------------
# satellites: CountedModel attribution, scheduler retry reset
# ---------------------------------------------------------------------------


def test_counted_model_attributes_all_kinds_to_role():
    records, world, *_ = synth.make_filter_world(6, seed=40)
    oracle = CountedModel(synth.SimulatedModel(world, "oracle"), "oracle")
    prompts = [f"the {t['claim']} holds" for t in records]
    with accounting.track("op") as st:
        oracle.predicate(prompts)
        oracle.generate(prompts)
        oracle.compare([f"{p} vs {p}" for p in prompts])
        oracle.choose([f"{p}\n0. a\n1. b" for p in prompts], 2)
    assert st.oracle_calls == 24                # all four kinds attributed
    assert st.generate_calls == 6               # per-kind columns preserved
    assert st.compare_calls == 6
    assert st.lm_calls == 24                    # no double counting


class _StubRunner:
    max_slots = 2
    max_seq = 64

    def prefill_into_slot(self, tokens, slot, extra=None):
        return np.eye(8)[3] * 5.0               # always argmax -> token 3

    def decode(self, slot_next, slot_len):
        return np.tile(np.eye(8)[4] * 5.0, (self.max_slots, 1))


def test_scheduler_prefill_failure_resets_retry_state():
    fail = {"n": 2}

    def flaky():
        if fail["n"] > 0:
            fail["n"] -= 1
            raise RuntimeError("injected prefill fault")

    sched = ContinuousBatchScheduler(_StubRunner(), fault_hook=flaky,
                                     max_retries=3)
    req = Request(rid=0, tokens=np.array([1, 2], np.int32), max_new_tokens=3)
    req.out_tokens = [9, 9]                     # stale state from a past life
    req.started_at = time.monotonic() - 999.0
    sched.submit(req)
    done = sched.run_to_completion()
    assert len(done) == 1 and done[0].done and not done[0].failed
    assert done[0].retries == 2
    # retry reset: no stale tokens leaked into the final output
    assert done[0].out_tokens == [3, 4, 4]


def test_scheduler_exhausted_retries_reports_failure_with_clean_state():
    def always_fail():
        raise RuntimeError("injected fault")

    sched = ContinuousBatchScheduler(_StubRunner(), fault_hook=always_fail,
                                     max_retries=1)
    req = Request(rid=0, tokens=np.array([1, 2], np.int32), max_new_tokens=3)
    sched.submit(req)
    done = sched.run_to_completion()
    assert len(done) == 1 and done[0].failed and not done[0].done
    assert done[0].out_tokens == [] and done[0].started_at is None
