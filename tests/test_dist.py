"""Distribution layer: sharding-rule resolution (pure logic, no devices) +
multi-device behaviors (context-parallel decode, pipeline parallelism,
elastic checkpoint resharding) exercised in subprocesses with a forced
8-device CPU topology — device count locks at first jax init, so they cannot
share this process.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env, timeout=600)


# ---------------------------------------------------------------------------
# rule resolution (no devices needed)
# ---------------------------------------------------------------------------


def test_resolve_pspec_divisibility_fallbacks():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import RULE_TABLES, abstract_mesh, resolve_pspec
    mesh = abstract_mesh((2, 4), ("data", "model"))
    rules = RULE_TABLES["serve_replicated"]
    # kv_heads=8 divisible by model=4 -> sharded; 6 not -> fallback None
    assert resolve_pspec((512, 8, 128), ("embed_in", "kv_heads", "qkv"), mesh, rules) \
        == P(None, "model", None)
    assert resolve_pspec((512, 6, 128), ("embed_in", "kv_heads", "qkv"), mesh, rules) \
        == P(None, None, None)


def test_resolve_pspec_axis_used_once():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import RULE_TABLES, abstract_mesh, resolve_pspec
    mesh = abstract_mesh((2, 4), ("data", "model"))
    rules = RULE_TABLES["default"]
    # batch takes data; kv_seq then takes model only (data already used)
    spec = resolve_pspec((8, 64, 8, 128), ("batch", "kv_seq", "kv_heads", "qkv"),
                         mesh, rules)
    assert spec == P("data", "model", None, None)
    # batch=1 not divisible -> kv_seq grabs (data, model)
    spec = resolve_pspec((1, 64, 8, 128), ("batch", "kv_seq", "kv_heads", "qkv"),
                         mesh, rules)
    assert spec == P(None, ("data", "model"), None, None)


# ---------------------------------------------------------------------------
# multi-device subprocess tests
# ---------------------------------------------------------------------------


def test_context_parallel_decode_matches_reference():
    r = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.configs import get_smoke
        from repro.models import attention as A
        from repro.dist import context_parallel as CP
        from repro.common import init_params
        cfg = get_smoke("llama3.2-3b")
        mesh = make_test_mesh((2, 4), ("data", "model"))
        params = init_params(A.attention_spec(cfg), jax.random.PRNGKey(0))
        B, S = 4, 64
        kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.num_kv_heads, cfg.hd))
        vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.num_kv_heads, cfg.hd))
        x = jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.d_model))
        lens = jnp.asarray([3, 33, 63, 0], jnp.int32)
        ref, krf, vrf = A.decode_self_attention(params, x, kc, vc, lens, cfg=cfg)
        from repro.dist.sharding import set_mesh
        with set_mesh(mesh):
            kcs = jax.device_put(kc, NamedSharding(mesh, P("data", "model", None, None)))
            vcs = jax.device_put(vc, NamedSharding(mesh, P("data", "model", None, None)))
            out, k2, v2 = jax.jit(lambda p, x, k, v, l: CP.cp_decode_self_attention(
                p, x, k, v, l, cfg=cfg, mesh=mesh))(params, x, kcs, vcs, lens)
        assert jnp.allclose(out, ref, atol=3e-5), float(jnp.max(jnp.abs(out - ref)))
        assert jnp.allclose(k2, krf, atol=1e-5)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_pipeline_parallel_matches_reference():
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.configs import get_smoke
        from repro.models import registry
        from repro.dist.pipeline_parallel import make_pp_loss, pp_forward
        from repro.train.trainstep import loss_fn as ref_loss
        from repro.data.tokenizer import TOKENIZER
        cfg = get_smoke("llama3.2-3b").with_(vocab_size=TOKENIZER.vocab_size, num_layers=4)
        mesh = make_test_mesh((2, 4), ("pod", "data"))
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (16, 32), 0, 200)
        labels = jax.random.randint(jax.random.PRNGKey(2), (16, 32), 0, 200)
        ref, _ = registry.forward(cfg, params, tokens)
        from repro.dist.sharding import set_mesh
        with set_mesh(mesh):
            got = jax.jit(lambda p, t: pp_forward(cfg, mesh, p, t, n_micro=4))(params, tokens)
            assert jnp.allclose(got, ref, atol=1e-4)
            loss = make_pp_loss(cfg, mesh, n_micro=4)
            l, g = jax.jit(jax.value_and_grad(loss))(params, tokens, labels)
            (rl, _), rg = jax.jit(jax.value_and_grad(
                lambda p, t, y: ref_loss(cfg, p, t, y), has_aux=True))(params, tokens, labels)
            assert abs(float(l) - float(rl)) < 1e-4
            gerr = max(float(jnp.max(jnp.abs(a - b)))
                       for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(rg)))
            assert gerr < 5e-4, gerr
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_elastic_checkpoint_reshard():
    r = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt
        from repro.launch.mesh import make_test_mesh
        d = tempfile.mkdtemp()
        mesh1 = make_test_mesh((8,), ("data",))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh1, P("data", None)))
        ckpt.save(d, 1, {"params": {"w": w}})
        # restart on a DIFFERENT topology
        mesh2 = make_test_mesh((2, 4), ("data", "model"))
        sh = {"params": {"w": NamedSharding(mesh2, P("data", "model"))}}
        step, out = ckpt.restore_sharded(d, sh)
        got = out["params"]["w"]
        assert got.sharding.spec == P("data", "model")
        np.testing.assert_array_equal(np.asarray(got), np.arange(64.0).reshape(8, 8))
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_gspmd_train_step_with_rules():
    """A sharded train step on an 8-device mesh produces finite metrics and
    params identical to the unsharded step."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.configs import get_smoke
        from repro.models import registry
        from repro.dist import sharding as shd
        from repro.train import optimizer as opt
        from repro.train.trainstep import make_train_step
        from repro.data.tokenizer import TOKENIZER
        cfg = get_smoke("llama3.2-3b").with_(vocab_size=384, d_model=64, d_ff=128)
        mesh = make_test_mesh((2, 4), ("data", "model"))
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        ocfg = opt.OptimizerConfig(total_steps=2, warmup_steps=0)
        state = opt.init_state(params, ocfg)
        step = make_train_step(cfg, ocfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 384),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 384)}
        p_ref, _, m_ref = jax.jit(step)(params, state, batch)
        pspecs = registry.param_specs(cfg)
        ospecs = opt.state_specs(pspecs, ocfg)
        with shd.set_mesh(mesh), shd.activation_rules(mesh, "default"):
            sh = (shd.spec_shardings(pspecs, mesh), shd.spec_shardings(ospecs, mesh), None)
            p2, s2, m2 = jax.jit(step, in_shardings=sh, out_shardings=(sh[0], sh[1], None))(
                params, state, batch)
        assert np.isfinite(float(m2["loss"]))
        assert abs(float(m2["loss"]) - float(m_ref["loss"])) < 1e-3
        err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                  for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)))
        assert err < 5e-3, err
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr
