"""Common infrastructure: parameter specs, pytree path utilities, dtypes.

The central abstraction is the ParamSpec table: every model exposes
``param_specs(cfg) -> dict[path, ParamSpec]`` — a *shape-level* description of
its parameters (shape, dtype, logical axis names, initializer).  From one spec
table we derive:

  * materialized parameters (``init_params``) for smoke tests / real runs,
  * ``jax.ShapeDtypeStruct`` stand-ins for the multi-pod dry-run (a 400B-param
    model never has to be allocated on the CPU host),
  * ``NamedSharding``s via the logical-axis rule tables in ``repro.dist``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

Path = tuple[str, ...]

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape-level description of a single parameter tensor.

    ``axes`` names each dimension with a *logical* axis ("embed", "mlp",
    "heads", "vocab", "layers", ...).  Physical sharding is resolved later by
    rule tables (see ``repro.dist.sharding``); the model code never mentions
    mesh axes.
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":
            scale = self.init_scale * 0.02
        elif self.init == "scaled":  # fan-in scaled
            fan_in = self.shape[0] if len(self.shape) == 1 else int(np.prod(self.shape[:-1]))
            scale = self.init_scale / math.sqrt(max(fan_in, 1))
        else:  # pragma: no cover - guarded by tests
            raise ValueError(f"unknown init {self.init}")
        return (scale * jax.random.normal(key, self.shape, jnp.float32)).astype(self.dtype)


SpecTree = dict[Path, ParamSpec]


def unflatten(flat: Mapping[Path, Any]) -> dict:
    """{(a,b,c): v} -> {a: {b: {c: v}}}."""
    out: dict = {}
    for path, value in flat.items():
        node = out
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = value
    return out


def flatten(tree: Mapping, prefix: Path = ()) -> dict[Path, Any]:
    out: dict[Path, Any] = {}
    for k, v in tree.items():
        p = prefix + (k,)
        if isinstance(v, Mapping):
            out.update(flatten(v, p))
        else:
            out[p] = v
    return out


def init_params(specs: SpecTree, key: jax.Array) -> dict:
    """Materialize a spec table into a nested param dict (deterministic)."""
    paths = sorted(specs.keys())
    keys = jax.random.split(key, max(len(paths), 1))
    flat = {p: specs[p].materialize(keys[i]) for i, p in enumerate(paths)}
    return unflatten(flat)


def param_structs(specs: SpecTree) -> dict:
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return unflatten({p: s.struct() for p, s in specs.items()})


def param_count(specs: SpecTree) -> int:
    return sum(int(np.prod(s.shape)) for s in specs.values())


def param_bytes(specs: SpecTree) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in specs.values())


def tree_size_bytes(tree: Any) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize for l in leaves)


def cast_tree(tree: Any, dtype: Any) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


# ---------------------------------------------------------------------------
# Misc numeric helpers
# ---------------------------------------------------------------------------


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pytree_allclose(a: Any, b: Any, **kw) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(np.allclose(x, y, **kw) for x, y in zip(la, lb))
