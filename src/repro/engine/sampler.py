"""Token samplers over final-position logits (numpy-side, per-slot)."""
from __future__ import annotations

import numpy as np


class Sampler:
    def __init__(self, temperature: float = 0.0, top_k: int = 0, seed: int = 0):
        self.temperature = temperature
        self.top_k = top_k
        self.rng = np.random.default_rng(seed)

    def __call__(self, logits: np.ndarray) -> np.ndarray:
        """logits: [B, V] -> token ids [B]."""
        if self.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.temperature
        if self.top_k:
            kth = np.partition(z, -self.top_k, axis=-1)[:, -self.top_k][:, None]
            z = np.where(z < kth, -np.inf, z)
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array([self.rng.choice(len(row), p=row) for row in p], np.int32)


def logprobs_of(logits: np.ndarray, token_ids) -> np.ndarray:
    """Log-softmax of ``logits`` ([..., V]) gathered at ``token_ids``."""
    z = logits - logits.max(axis=-1, keepdims=True)
    lse = np.log(np.exp(z).sum(axis=-1, keepdims=True))
    logp = z - lse
    return logp[..., token_ids]
