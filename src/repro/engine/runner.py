"""Jitted model-step closures for the inference engine.

One ``ModelRunner`` owns params + jitted prefill/decode functions.  Prefill is
bucketed by prompt length (power-of-two padding) so the number of distinct
compilations stays logarithmic; decode is a single compilation over the full
slot batch with per-slot cache lengths.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


class ModelRunner:
    """Owns params and compiled steps for one model."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.cache = registry.init_cache(cfg, max_slots, max_seq)

        @jax.jit
        def _decode(params, tokens, cache, lens):
            logits, cache = registry.decode_step(cfg, params, tokens, cache, lens)
            return logits[:, 0].astype(jnp.float32), cache

        self._decode = _decode

        @functools.partial(jax.jit, static_argnames=("bucket",))
        def _prefill(params, tokens, cache1, true_len, extra, bucket):
            logits, cache1 = registry.prefill(cfg, params, tokens, cache1, extra=extra or None)
            last = logits[0, true_len - 1].astype(jnp.float32)
            return last, cache1

        self._prefill = _prefill

        @jax.jit
        def _write_slot(cache, cache1, slot):
            return jax.tree.map(lambda g, p: g.at[:, slot].set(p[:, 0].astype(g.dtype)), cache, cache1)

        self._write_slot = _write_slot

    # -- prefill one request into a slot --------------------------------
    def prefill_into_slot(self, tokens: np.ndarray, slot: int, extra: dict | None = None):
        """tokens: [T] int32. Returns last-token logits [V]."""
        t = int(tokens.shape[0])
        assert t <= self.max_seq, f"prompt {t} > max_seq {self.max_seq}"
        bucket = min(_bucket(t), self.max_seq)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :t] = tokens
        cache1 = registry.init_cache(self.cfg, 1, self.max_seq)
        logits, cache1 = self._prefill(self.params, jnp.asarray(padded), cache1,
                                       jnp.int32(t), extra, bucket)
        self.cache = self._write_slot(self.cache, cache1, jnp.int32(slot))
        return np.asarray(logits)

    # -- one decode step over all slots ----------------------------------
    def decode(self, tokens: np.ndarray, lens: np.ndarray):
        """tokens: [slots] int32 (next input per slot); lens: [slots] int32."""
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens[:, None]),
                                          self.cache, jnp.asarray(lens))
        return np.asarray(logits)

    # -- whole-sequence scoring (no cache) -------------------------------
    @functools.cached_property
    def _score(self):
        @functools.partial(jax.jit, static_argnames=())
        def f(params, tokens, extra):
            logits, _ = registry.forward(self.cfg, params, tokens, extra=extra or None)
            return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)

        return f

    def logprobs(self, tokens: np.ndarray, extra: dict | None = None) -> np.ndarray:
        """tokens: [B,T] -> log-probs [B,T,V] (teacher-forced)."""
        return np.asarray(self._score(self.params, jnp.asarray(tokens), extra))
