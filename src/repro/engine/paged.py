"""Paged KV cache (vLLM's PagedAttention, TPU-adapted).

GPU paged attention exists to fight fragmentation with warp-level gathers.
On TPU we keep the *allocator* (page table, per-slot page lists — memory is
still allocated in fixed pages, so no fragmentation across variable-length
requests) but lay pages out as statically-shaped arrays [L, pages, page_size,
kv_heads, head_dim]; the per-step gather of a slot's pages lowers to XLA
dynamic-slices feeding the same dense attention einsums (MXU-friendly),
rather than a scalar-indexed kernel.

Implemented for the dense/moe ('self'-cache) transformer families — the
scheduler demo + tests; contiguous caches remain the default elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.moe import moe_ffn
from repro.models.transformer import layer_layout


class PageAllocator:
    """Host-side free-list page allocator + per-slot page tables.

    ``table`` is copy-on-write: callers hand it to async-dispatched jitted
    steps (``jnp.asarray(alloc.table)``), and JAX CPU may read the host
    buffer *after* the call returns — mutating it in place between steps
    races that deferred read and produces nondeterministically corrupt page
    tables (observed as run-to-run divergent decode logits under load).
    Every mutation therefore replaces ``table`` with a fresh array.
    """

    def __init__(self, num_pages: int, page_size: int, max_slots: int, max_pages_per_slot: int):
        self.page_size = page_size
        self.free = list(range(num_pages - 1, -1, -1))
        self.table = np.zeros((max_slots, max_pages_per_slot), np.int32)
        self.pages_used: list[list[int]] = [[] for _ in range(max_slots)]

    def ensure(self, slot: int, n_tokens: int) -> None:
        need = (n_tokens + self.page_size - 1) // self.page_size
        used = self.pages_used[slot]
        if len(used) >= need:
            return
        if len(self.free) < need - len(used):  # check upfront: the update
            raise MemoryError("out of KV pages")  # below must be atomic
        table = self.table.copy()
        while len(used) < need:
            p = self.free.pop()
            table[slot, len(used)] = p
            used.append(p)
        self.table = table

    def release(self, slot: int) -> None:
        self.free.extend(reversed(self.pages_used[slot]))
        self.pages_used[slot] = []
        table = self.table.copy()
        table[slot] = 0
        self.table = table


def init_pages(cfg: ModelConfig, num_pages: int, page_size: int):
    lay = layer_layout(cfg)
    n = lay.get("dense") or lay.get("moe")
    shape = (n, num_pages, page_size, cfg.num_kv_heads, cfg.hd)
    z = jnp.zeros(shape, jnp.dtype(cfg.dtype))
    return {"k": z, "v": z}


def _gather_pages(pages_l, table):
    """pages_l: [P, ps, hk, hd]; table: [B, maxp] -> [B, maxp*ps, hk, hd]."""
    g = pages_l[table]  # [B, maxp, ps, hk, hd]
    b, mp, ps = g.shape[0], g.shape[1], g.shape[2]
    return g.reshape(b, mp * ps, g.shape[3], g.shape[4])


def paged_decode_step(cfg: ModelConfig, params, tokens, pages, table, lens):
    """One decode step with paged KV. tokens [B,1]; table [B,maxp]; lens [B].

    Returns (logits [B,1,V], updated pages).
    """
    lay = layer_layout(cfg)
    use_moe = lay["kind"] == "moe"
    assert lay["kind"] in ("dense", "moe"), "paged decode: dense/moe families"
    b = tokens.shape[0]
    ps = pages["k"].shape[2]
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    bidx = jnp.arange(b)
    page_of = table[bidx, lens // ps]   # physical page holding position `lens`
    off = lens % ps

    def body(x, inp):
        lp, kp, vp = inp                 # page slices [P, ps, hk, hd]
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        q, k_new, v_new = attn.project_qkv(lp["attn"], h, cfg=cfg, positions=lens[:, None])
        kp = kp.at[page_of, off].set(k_new[:, 0].astype(kp.dtype), mode="drop")
        vp = vp.at[page_of, off].set(v_new[:, 0].astype(vp.dtype), mode="drop")
        k = _gather_pages(kp, table)
        v = _gather_pages(vp, table)
        k_pos = jnp.arange(k.shape[1])
        mask = (k_pos[None, :] <= lens[:, None])[:, None, None, :]
        o = attn.gqa_attend(q, k, v, mask)
        x = x + attn.out_proj(lp["attn"], o)
        h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        if use_moe:
            f, _ = moe_ffn(lp["moe"], h, cfg=cfg)
            if cfg.moe_shared_expert:
                f = f + L.swiglu(lp["shared"], h)
        else:
            f = L.swiglu(lp["ffn"], h)
        return x + f, (kp, vp)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], pages["k"], pages["v"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)
    return logits, {"k": ks, "v": vs}
