"""InferenceEngine: the vLLM-analogue facade the semantic operators consume.

Four primitives (mirroring the paper's model-access patterns):
  generate(prompts)          -> free-text generations            (sem_map/agg)
  predicate(prompts)         -> bool + True-token log-prob       (sem_filter/join;
                                the log-prob is the cascade proxy score)
  compare(prompts)           -> A/B choice + log-prob            (sem_topk)
  classify(prompt, n_opts)   -> argmax over first n option ids   (sem_group_by)

Predicate/compare/classify need exactly one output token, so they are served
by a single teacher-forced forward pass over a padded batch (cheap decoding —
the effect the paper credits for sem_filter's 3.6x win over generic AI UDF
maps); generate() runs through the continuous-batching scheduler.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import TOKENIZER
from repro.engine.runner import ModelRunner
from repro.engine.sampler import Sampler
from repro.engine.scheduler import ContinuousBatchScheduler, Request
from repro.models import registry


@dataclasses.dataclass
class EngineStats:
    lm_calls: int = 0
    generated_tokens: int = 0
    prompt_tokens: int = 0

    def add(self, calls: int, prompt: int, gen: int) -> None:
        self.lm_calls += calls
        self.prompt_tokens += prompt
        self.generated_tokens += gen


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, seed: int = 0,
                 max_slots: int = 8, max_seq: int = 512, temperature: float = 0.0):
        self.cfg = cfg
        if params is None:
            params = registry.init_params(cfg, jax.random.PRNGKey(seed))
        self.runner = ModelRunner(cfg, params, max_slots=max_slots, max_seq=max_seq)
        self.sampler = Sampler(temperature=temperature, seed=seed)
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    def generate(self, prompts: list[str], *, max_new_tokens: int = 48,
                 fault_hook=None) -> list[str]:
        sched = ContinuousBatchScheduler(self.runner, sampler=self.sampler,
                                         fault_hook=fault_hook)
        for i, p in enumerate(prompts):
            toks = np.asarray(TOKENIZER.encode(p)[: self.runner.max_seq - max_new_tokens - 1],
                              np.int32)
            sched.submit(Request(rid=i, tokens=toks, max_new_tokens=max_new_tokens,
                                 stop_id=TOKENIZER.eos_id))
        done = sched.run_to_completion()
        self.stats.add(len(prompts), sum(len(r.tokens) for r in done),
                       sum(len(r.out_tokens) for r in done))
        by_id = {r.rid: r for r in done}
        return [TOKENIZER.decode([t for t in by_id[i].out_tokens if t != TOKENIZER.eos_id])
                if i in by_id and not by_id[i].failed else ""
                for i in range(len(prompts))]

    # ------------------------------------------------------------------
    def _last_logits(self, prompts: list[str]) -> np.ndarray:
        """One forward pass; per-row logits at the last real token. [B, V]."""
        seqs = [TOKENIZER.encode(p)[: self.runner.max_seq] for p in prompts]
        out = []
        bs = 32
        for i in range(0, len(seqs), bs):
            chunk = seqs[i:i + bs]
            width = max(16, max(len(s) for s in chunk))
            toks = TOKENIZER.pad_batch(chunk, width)
            lp = self.runner.logprobs(toks)  # [b, T, V] log-softmax
            idx = np.asarray([min(len(s), width) - 1 for s in chunk])
            out.append(lp[np.arange(len(chunk)), idx])
            self.stats.add(len(chunk), sum(len(s) for s in chunk), len(chunk))
        return np.concatenate(out, axis=0)

    def predicate(self, prompts: list[str]) -> tuple[np.ndarray, np.ndarray]:
        """Returns (passes [B] bool, score [B]: p(True | {True,False}))."""
        if not prompts:
            return np.zeros(0, bool), np.zeros(0, np.float32)
        logp = self._last_logits(prompts)
        lt, lf = logp[:, TOKENIZER.true_id], logp[:, TOKENIZER.false_id]
        score = 1.0 / (1.0 + np.exp(-(lt - lf)))  # calibrated True-vs-False prob
        return lt > lf, score.astype(np.float32)

    def compare(self, prompts: list[str]) -> np.ndarray:
        """Returns [B] bool: True if option A preferred over option B."""
        if not prompts:
            return np.zeros(0, bool)
        logp = self._last_logits(prompts)
        return logp[:, TOKENIZER.a_id] > logp[:, TOKENIZER.b_id]

    def choose(self, prompts: list[str], n_options: int) -> np.ndarray:
        """Returns [B] int in [0, n_options): argmax over the option labels.

        Matches the ``GenerativeModel`` protocol (operators pass the option
        *count*; sem_group_by prompts number the categories "0.", "1.", ...):
        options map to their single-token digit ids internally.  Beyond 10
        options the leading digit is shared, so ties collapse to the first
        option of each decade — callers wanting exact >10-way classification
        should bucket (sem_group_by keeps C small).
        """
        logp = self._last_logits(prompts)
        option_token_ids = [TOKENIZER.encode(str(min(i, 9)), bos=False)[0]
                            for i in range(n_options)]
        return np.argmax(logp[:, option_token_ids], axis=-1)
