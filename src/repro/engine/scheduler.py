"""Continuous-batching scheduler (vLLM-style iteration-level scheduling).

Requests are admitted into fixed decode *slots*; every engine step either
prefills one waiting request into a free slot or runs one batched decode step
across all active slots.  Finished sequences free their slot immediately
(iteration-level, not request-level, batching).

Fault tolerance / straggler mitigation:
  * per-request wall-clock deadline -> the request is cancelled and
    re-queued (fresh slot, bounded retries) — the cluster-level analogue of
    re-dispatching work from a straggling / failed worker,
  * a ``fault_hook`` is invoked around model steps so tests can inject
    worker failures (exceptions) and verify the scheduler recovers.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.engine.runner import ModelRunner
from repro.engine.sampler import Sampler


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # prompt token ids [T]
    max_new_tokens: int = 32
    stop_id: int | None = None
    extra: dict | None = None
    deadline_s: float | None = None     # wall-clock budget (straggler guard)
    # runtime state
    out_tokens: list = dataclasses.field(default_factory=list)
    first_logits: np.ndarray | None = None
    done: bool = False
    failed: bool = False
    retries: int = 0
    started_at: float | None = None


class ContinuousBatchScheduler:
    def __init__(self, runner: ModelRunner, *, sampler: Sampler | None = None,
                 max_retries: int = 2, fault_hook: Callable[[], None] | None = None):
        self.runner = runner
        self.sampler = sampler or Sampler()
        self.max_retries = max_retries
        self.fault_hook = fault_hook or (lambda: None)
        n = runner.max_slots
        self.slot_req: list[Request | None] = [None] * n
        self.slot_len = np.zeros(n, np.int32)
        self.slot_next = np.zeros(n, np.int32)
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.steps = 0
        self.prefill_steps = 0
        self.decode_steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _requeue_or_fail(self, req: Request) -> None:
        """Single failure path for prefill faults, decode faults, and blown
        deadlines: reset all runtime state (stale out_tokens would corrupt a
        retried sequence, a stale started_at its deadline clock) and re-queue
        within the retry budget, else surface the request as failed."""
        req.retries += 1
        req.out_tokens = []
        req.first_logits = None
        req.started_at = None
        if req.retries <= self.max_retries:
            req.failed = req.done = False
            self.queue.append(req)       # re-dispatch (straggler mitigation)
        else:
            req.failed, req.done = True, False
            self.finished.append(req)

    def _finish(self, slot: int, *, failed: bool = False) -> None:
        req = self.slot_req[slot]
        assert req is not None
        self.slot_req[slot] = None
        if failed:
            self._requeue_or_fail(req)
        else:
            req.done, req.failed = True, False
            self.finished.append(req)

    def _check_deadlines(self) -> None:
        now = time.monotonic()
        for i, req in enumerate(self.slot_req):
            if req and req.deadline_s and req.started_at and now - req.started_at > req.deadline_s:
                self._finish(i, failed=True)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration. Returns False when idle (nothing to do)."""
        self.steps += 1
        self._check_deadlines()

        slot = self._free_slot()
        if self.queue and slot is not None:
            req = self.queue.popleft()
            req.started_at = time.monotonic()
            try:
                self.fault_hook()
                logits = self.runner.prefill_into_slot(req.tokens, slot, extra=req.extra)
            except RuntimeError:
                self._requeue_or_fail(req)
                return True
            self.prefill_steps += 1
            req.first_logits = logits
            tok = int(self.sampler(logits[None])[0])
            req.out_tokens.append(tok)
            self.slot_req[slot] = req
            self.slot_len[slot] = len(req.tokens)
            self.slot_next[slot] = tok
            if self._req_finished(req):
                self._finish(slot)
            return True

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return bool(self.queue)

        try:
            self.fault_hook()
            logits = self.runner.decode(self.slot_next, self.slot_len)
        except RuntimeError:
            # worker fault mid-decode: re-queue everything in flight
            for i in list(active):
                self._finish(i, failed=True)
            return True
        self.decode_steps += 1
        toks = self.sampler(logits)
        for i in active:
            req = self.slot_req[i]
            self.slot_len[i] += 1
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self.slot_next[i] = tok
            if self._req_finished(req) or self.slot_len[i] + 1 >= self.runner.max_seq:
                self._finish(i)
        return True

    @staticmethod
    def _req_finished(req: Request) -> bool:
        if req.stop_id is not None and req.out_tokens and req.out_tokens[-1] == req.stop_id:
            return True
        return len(req.out_tokens) >= req.max_new_tokens

    def run_to_completion(self, max_steps: int = 100_000) -> list[Request]:
        for _ in range(max_steps):
            busy_slots = any(r is not None for r in self.slot_req)
            if not self.queue and not busy_slots:
                break
            self.step()
        return self.finished
