"""CorpusTable: the versioned streaming corpus (delta-table analogue).

The paper's batch model — and PRs 1-3 — treat a corpus as frozen: any
appended row changes the registry fingerprint, forcing a full re-embed +
index rebuild and a from-scratch pipeline run.  A ``CorpusTable`` instead
gives rows stable ids and gives the *table* a monotonically increasing
version: every commit (an append batch, an update, a delete) bumps the
version by one and logs the change, so downstream consumers can ask two
delta-aware questions the frozen model cannot answer:

  * ``snapshot(v)``  — the exact row set at any past version (commits are
    replayable), which is what lets a continuous query pin a version and
    stay record-identical to a from-scratch run even while writers race;
  * ``delta(v0, v1)`` — the *net* row changes between two versions
    (add-then-delete inside the window cancels out), which is what lets the
    ``IndexRegistry`` append only new vectors to a cached index and the
    serving cache cover every already-judged row.

Snapshot order is row-id order (= insertion order; updates keep their
position), so an appends-only delta satisfies
``snapshot(v1) == snapshot(v0) + [r for _, r in delta.added]`` — the
alignment contract the incremental index path relies on (index position i
is snapshot row i at every version).

Listeners (``add_listener``) are the change feed: ``Gateway.subscribe``
registers one per table to re-execute continuous queries on new versions.
Thread-safe; listeners fire outside the lock.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import uuid
from typing import Any, Callable, Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class DeltaSet:
    """Net row changes between two table versions ``(since, to]``."""

    since: int
    to: int
    added: tuple[tuple[int, dict], ...]    # (row id, record at `to`)
    updated: tuple[tuple[int, dict], ...]  # existed at `since`, changed
    deleted: tuple[int, ...]               # existed at `since`, gone at `to`

    @property
    def appends_only(self) -> bool:
        """True when a base index/result can be extended instead of rebuilt."""
        return not self.updated and not self.deleted

    def __bool__(self) -> bool:
        return bool(self.added or self.updated or self.deleted)


class CorpusTable:
    _SNAPSHOT_CACHE = 8   # materialized historical versions kept around

    def __init__(self, records: Sequence[dict] = (), *, name: str | None = None):
        self.table_id = name or f"tbl-{uuid.uuid4().hex[:10]}"
        self._lock = threading.RLock()
        # (version, op, rid, record-or-None); records are copied on commit.
        # _log_versions mirrors the (sorted) version column so delta() and
        # _state_at() bisect to their window instead of scanning the log
        self._log: list[tuple[int, str, int, dict | None]] = []
        self._log_versions: list[int] = []
        self._live: dict[int, dict] = {}     # rid -> record, insertion order
        self._next_rid = 0
        self._version = 0
        self._schema: set[str] = set()
        self._listeners: list[Callable[[int], None]] = []
        self._snap_cache: dict[int, list[dict]] = {}
        if records:
            self.append(records)

    # -- write path --------------------------------------------------------
    def _commit(self, entries: list[tuple[str, int, dict | None]]) -> int:
        """One atomic version bump for a batch of ops (lock held by caller)."""
        self._version += 1
        v = self._version
        for op, rid, rec in entries:
            self._log.append((v, op, rid, rec))
            self._log_versions.append(v)
            if op == "delete":
                self._live.pop(rid, None)
            else:
                self._live[rid] = rec
        self._snap_cache.pop(v, None)
        return v

    def append(self, records: Iterable[dict]) -> int:
        """Append a batch of rows as ONE new version; returns it."""
        with self._lock:
            entries = []
            for rec in records:
                rec = dict(rec)
                entries.append(("append", self._next_rid, rec))
                self._next_rid += 1
                if not self._schema:
                    self._schema = set(rec.keys())
            if not entries:
                return self._version
            v = self._commit(entries)
        self._notify(v)
        return v

    def update(self, rid: int, fields: dict) -> int:
        """Merge ``fields`` into row ``rid``; returns the new version."""
        with self._lock:
            if rid not in self._live:
                raise KeyError(f"row {rid} not live in {self.table_id}")
            rec = {**self._live[rid], **fields}
            v = self._commit([("update", rid, rec)])
        self._notify(v)
        return v

    def delete(self, rid: int) -> int:
        with self._lock:
            if rid not in self._live:
                raise KeyError(f"row {rid} not live in {self.table_id}")
            v = self._commit([("delete", rid, None)])
        self._notify(v)
        return v

    # -- read path -----------------------------------------------------------
    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def __len__(self) -> int:
        with self._lock:
            return len(self._live)

    def schema(self) -> set[str]:
        with self._lock:
            return set(self._schema)

    def _state_at(self, version: int) -> dict[int, dict]:
        """rid -> record at ``version`` (lock held). Replays the log for
        historical versions; rids come out in insertion order."""
        if version == self._version:
            return self._live
        if not 0 <= version <= self._version:
            raise ValueError(f"version {version} out of range "
                             f"[0, {self._version}] for {self.table_id}")
        state: dict[int, dict] = {}
        hi = bisect.bisect_right(self._log_versions, version)
        for _, op, rid, rec in self._log[:hi]:
            if op == "delete":
                state.pop(rid, None)
            else:
                state[rid] = rec
        return state

    def snapshot(self, version: int | None = None) -> list[dict]:
        """The row set at ``version`` (default: current), in row-id order.
        Record dicts are shared (treated immutable, like ``Scan.records``);
        the list is fresh per call."""
        with self._lock:
            v = self._version if version is None else version
            cached = self._snap_cache.get(v)
            if cached is None:
                cached = list(self._state_at(v).values())
                self._snap_cache[v] = cached
                while len(self._snap_cache) > self._SNAPSHOT_CACHE:
                    self._snap_cache.pop(next(iter(self._snap_cache)))
            return list(cached)

    def row_ids(self, version: int | None = None) -> list[int]:
        with self._lock:
            v = self._version if version is None else version
            return list(self._state_at(v).keys())

    def count(self, version: int | None = None) -> int:
        return len(self.snapshot(version))

    def delta(self, since: int, to: int | None = None) -> DeltaSet:
        """Net changes over ``(since, to]`` (see class docstring)."""
        with self._lock:
            to_v = self._version if to is None else to
            if not 0 <= since <= to_v <= self._version:
                raise ValueError(f"bad delta range ({since}, {to_v}] for "
                                 f"{self.table_id}@v{self._version}")
            added: set[int] = set()
            updated: set[int] = set()
            deleted: set[int] = set()
            lo = bisect.bisect_right(self._log_versions, since)
            hi = bisect.bisect_right(self._log_versions, to_v)
            for _, op, rid, _rec in self._log[lo:hi]:
                if op == "append":
                    added.add(rid)
                elif op == "update":
                    if rid not in added:
                        updated.add(rid)
                else:  # delete
                    if rid in added:          # born and died inside the window
                        added.discard(rid)
                    else:
                        updated.discard(rid)
                        deleted.add(rid)
            state = self._state_at(to_v)
            return DeltaSet(
                since=since, to=to_v,
                added=tuple((rid, state[rid]) for rid in sorted(added)),
                updated=tuple((rid, state[rid]) for rid in sorted(updated)),
                deleted=tuple(sorted(deleted)))

    # -- change feed ---------------------------------------------------------
    def add_listener(self, fn: Callable[[int], None]) -> Callable[[int], None]:
        with self._lock:
            self._listeners.append(fn)
        return fn

    def remove_listener(self, fn: Callable[[int], None]) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def _notify(self, version: int) -> None:
        with self._lock:
            listeners = list(self._listeners)
        for fn in listeners:
            fn(version)

    # -- frame integration ---------------------------------------------------
    def frame(self, session) -> Any:
        """Eager SemFrame over the current snapshot (a frozen copy)."""
        from repro.core.frame import SemFrame
        return SemFrame(self.snapshot(), session)

    def lazy(self, session) -> Any:
        """LazySemFrame whose plan leaf is a StreamScan over this table —
        the handle ``Gateway.subscribe`` re-executes on every new version."""
        from repro.core.frame import LazySemFrame
        from repro.core.plan import nodes as N
        return LazySemFrame(N.StreamScan(self), session)

    def describe(self) -> dict:
        with self._lock:
            return {"table_id": self.table_id, "version": self._version,
                    "rows": len(self._live), "log_entries": len(self._log),
                    "columns": sorted(self._schema)}
