"""Streaming corpus subsystem: versioned tables + continuous queries.

PRs 1-3 made one query cheap (plan IR), many concurrent queries cheap
(gateway), and retrieval sub-linear (IVF) — all over a *frozen* corpus.
This package makes the corpus itself a first-class, changing object:

  * ``table``      — :class:`CorpusTable`, rows with stable ids, a
                     monotonically versioned append/update/delete delta log,
                     replayable snapshots, and a commit change feed;
  * ``continuous`` — :class:`Subscription` / :class:`Emission`, the
                     continuous-query machinery behind
                     ``Gateway.subscribe(pipeline)``: re-execute on new
                     versions, delta-only model traffic via the shared
                     semantic cache, record-identical to a from-scratch run.

Incremental *index* maintenance lives with the indexes themselves
(``repro.index``: ``RetrievalBackend.add``, the IVF delta side buffer +
drift-triggered retrain) and the version-aware sharing in
``repro.serve.index_registry.IndexRegistry.get_or_update``.

    table = CorpusTable(records)
    with Gateway(session) as gw:
        sub = gw.subscribe(table.lazy(session).sem_filter("the {claim} holds"))
        first = sub.poll(timeout=30)          # full result at v1
        table.append(new_rows)                # -> only new rows hit the oracle
        delta = sub.poll(timeout=30)          # delta.added == new matches
"""
from repro.stream.continuous import (Emission, Subscription,
                                     find_stream_tables, pin_stream_scans)
from repro.stream.table import CorpusTable, DeltaSet

__all__ = [
    "CorpusTable", "DeltaSet", "Emission", "Subscription",
    "find_stream_tables", "pin_stream_scans",
]
