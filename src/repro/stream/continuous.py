"""Continuous queries: a subscribed pipeline re-executed on new table versions.

``Gateway.subscribe(pipeline)`` wraps a lazy plan whose leaves include at
least one :class:`~repro.stream.table.CorpusTable` ``StreamScan``.  The
subscription listens to every table's change feed and, on each commit,
re-submits the plan *pinned* to the new versions through the normal gateway
admission path (tenant fairness, micro-batch fusion, and the shared
semantic cache all apply).  Delta-awareness is split by operator class:

  * **monotone** ops (sem_filter / sem_map / sem_extract / sem_search /
    sem_sim_join) issue oracle/proxy/embed prompts per row, so the
    re-execution's old-row prompts hit the :class:`SharedSemanticCache`
    and only the delta rows reach a model;
  * **non-monotone** ops (sem_topk / sem_agg / sem_group_by) recompute
    their result from cached per-row judgments (pairwise comparisons,
    per-row labels) plus fresh calls only where new rows create new
    comparisons.

Because each emission executes the pinned plan from scratch through the
same executor, its records are *identical* to a from-scratch run of the
pipeline at that version — the correctness contract ``tests/test_stream.py``
and ``benchmarks/stream_bench.py`` check.

Rapid commits coalesce: the subscription always re-runs at the *latest*
versions, so k commits during one in-flight run produce one catch-up
emission, not k.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from collections import Counter
from typing import Any

from repro.core.plan import nodes as N
from repro.obs import trace as _trace


def find_stream_tables(plan: N.LogicalNode) -> list:
    """All distinct CorpusTables under ``plan``'s StreamScan leaves."""
    out: dict[str, Any] = {}

    def walk(node: N.LogicalNode) -> None:
        if isinstance(node, N.StreamScan):
            out.setdefault(node.table.table_id, node.table)
        for c in node.children():
            walk(c)

    walk(plan)
    return list(out.values())


def pin_stream_scans(plan: N.LogicalNode,
                     versions: dict[str, int] | None = None) -> N.LogicalNode:
    """New plan with every floating StreamScan pinned: to ``versions`` (by
    table id) when given, else to each table's current version.  Pinning
    freezes the row set the whole run sees, so a commit landing mid-query
    cannot make two stages of one pipeline disagree about the corpus."""
    # only rebuild nodes whose subtree actually changed: a plan with no
    # floating StreamScan comes back untouched (every gateway run pins, so
    # pure batch plans must not pay a per-submit deep copy)
    mapping = {}
    for c in plan.children():
        pinned = pin_stream_scans(c, versions)
        if pinned is not c:
            mapping[id(c)] = pinned
    if mapping:
        plan = plan.replace_children(mapping)
    if isinstance(plan, N.StreamScan):
        v = (versions or {}).get(plan.table.table_id, plan.version)
        if v is None:
            v = plan.table.version
        if v != plan.version:
            plan = dataclasses.replace(plan, version=v)
    return plan


@dataclasses.dataclass
class Emission:
    """One continuous-query result: the full record set at ``versions`` plus
    the delta against the subscription's previous emission."""

    versions: dict[str, int]
    records: list | None
    added: list
    removed: list
    sid: str | None = None
    error: BaseException | None = None

    @property
    def version(self) -> int:
        """Single-table convenience: the (max) pinned version."""
        return max(self.versions.values()) if self.versions else 0

    def summary(self) -> dict:
        return {"versions": dict(self.versions), "sid": self.sid,
                "rows": len(self.records) if self.records is not None else None,
                "added": len(self.added), "removed": len(self.removed),
                "error": repr(self.error) if self.error is not None else None}


def _rec_key(rec: dict) -> tuple:
    return tuple(sorted((k, repr(v)) for k, v in rec.items()))


def _diff(prev: list | None, cur: list) -> tuple[list, list]:
    """(added, removed) by record content, multiset semantics."""
    if prev is None:
        return list(cur), []
    have = Counter(_rec_key(r) for r in prev)
    added = []
    for r in cur:
        k = _rec_key(r)
        if have[k] > 0:
            have[k] -= 1
        else:
            added.append(r)
    want = Counter(_rec_key(r) for r in cur)
    removed = []
    for r in prev:
        k = _rec_key(r)
        if want[k] > 0:
            want[k] -= 1
        else:
            removed.append(r)
    return added, removed


class Subscription:
    """A continuous query's handle: an emission queue plus cancellation.

    Created by ``Gateway.subscribe``; one daemon thread serializes this
    subscription's runs (per-version results arrive in version order)."""

    def __init__(self, gateway, plan: N.LogicalNode, *, tenant: str = "default",
                 optimize: bool = True, emit_initial: bool = True):
        if not isinstance(plan, N.LogicalNode):
            raise TypeError("subscribe() takes a LazySemFrame or a plan node, "
                            f"got {type(plan).__name__}")
        self.gateway = gateway
        self.plan = plan
        self.tenant = tenant
        self.optimize = optimize
        self.tables = find_stream_tables(plan)
        if not self.tables:
            raise ValueError("subscribe() needs a pipeline over a CorpusTable "
                             "(no StreamScan leaf in the plan); use submit() "
                             "for one-shot queries")
        self._cv = threading.Condition()
        self._dirty = emit_initial
        self._cancelled = False
        self._emissions: queue.Queue[Emission] = queue.Queue()
        self.last_records: list | None = None
        self._last_versions: dict[str, int] | None = None
        self.emitted = 0
        self.runs = 0
        for t in self.tables:
            t.add_listener(self._on_commit)
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"subscription-{tenant}")

    def start(self) -> "Subscription":
        self._thread.start()
        return self

    # -- change feed ---------------------------------------------------------
    def _on_commit(self, version: int) -> None:
        with self._cv:
            self._dirty = True
            self._cv.notify_all()

    # -- the run loop --------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._dirty and not self._cancelled:
                    self._cv.wait()
                if self._cancelled:
                    return
                self._dirty = False
            versions = {t.table_id: t.version for t in self.tables}
            if versions == self._last_versions:
                continue  # version-aware memo: nothing new to compute
            self._run_once(versions)

    def _run_once(self, versions: dict[str, int]) -> None:
        # subscription threads run outside any session's trace context:
        # emission spans root on the gateway's tracer handle directly
        with _trace.span_in(getattr(self.gateway, "tracer", None),
                            f"emission/{self.tenant}", "emission",
                            tenant=self.tenant,
                            version=max(versions.values())) as sp:
            self._run_pinned(versions, sp)

    def _run_pinned(self, versions: dict[str, int], sp) -> None:
        from repro.serve.gateway import AdmissionError
        pinned = pin_stream_scans(self.plan, versions)
        sess = None
        try:
            while True:
                try:
                    sess = self.gateway.submit(pinned, tenant=self.tenant,
                                               optimize=self.optimize)
                    break
                except AdmissionError:          # shed-load backpressure
                    with self._cv:
                        if self._cancelled:
                            return
                        self._cv.wait(timeout=0.02)
            while not sess.wait(0.05):
                with self._cv:
                    if self._cancelled:
                        sess.cancel()
            self.runs += 1
            records = sess.result(timeout=10.0)
        except BaseException as exc:
            with self._cv:
                if self._cancelled:
                    return                      # cancellation is not an error
            sp.set(sid=getattr(sess, "sid", None), error=repr(exc))
            self._push(Emission(versions=versions, records=None, added=[],
                                removed=[], sid=getattr(sess, "sid", None),
                                error=exc))
            return
        added, removed = _diff(self.last_records, records)
        self.last_records = records
        self._last_versions = versions
        sp.set(sid=sess.sid, rows_out=len(records), added=len(added),
               removed=len(removed))
        self._push(Emission(versions=versions, records=records, added=added,
                            removed=removed, sid=sess.sid))

    def _push(self, em: Emission) -> None:
        self._emissions.put(em)
        self.emitted += 1
        self.gateway.metrics.on_emit(error=em.error is not None)
        aud = getattr(self.gateway, "auditor", None)
        if aud is not None:
            aud.observe_emission(
                tenant=self.tenant,
                rows=len(em.records) if em.records is not None else 0,
                added=len(em.added), error=em.error is not None)

    # -- consumer side -------------------------------------------------------
    def poll(self, timeout: float | None = None) -> Emission | None:
        """Next emission, or None when ``timeout`` elapses."""
        try:
            return self._emissions.get(timeout=timeout)
        except queue.Empty:
            return None

    @property
    def pending(self) -> int:
        return self._emissions.qsize()

    @property
    def cancelled(self) -> bool:
        with self._cv:
            return self._cancelled

    def cancel(self, wait: bool = True) -> None:
        with self._cv:
            if self._cancelled:
                wait_thread = wait and self._thread.is_alive()
            else:
                self._cancelled = True
                wait_thread = wait and self._thread.is_alive()
            self._cv.notify_all()
        for t in self.tables:
            t.remove_listener(self._on_commit)
        discard = getattr(self.gateway, "_discard_subscription", None)
        if discard is not None:
            discard(self)
        if wait_thread and threading.current_thread() is not self._thread:
            self._thread.join(timeout=30.0)

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc) -> None:
        self.cancel()

    def summary(self) -> dict:
        return {"tenant": self.tenant, "tables": [t.table_id for t in self.tables],
                "runs": self.runs, "emitted": self.emitted,
                "pending": self.pending, "cancelled": self.cancelled}
