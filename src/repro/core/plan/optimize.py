"""Rule-based plan optimizer over the logical IR.

Five rewrites, applied in a fixed order (each is semantics-preserving wrt
the gold algorithms except 4-5, which trade a bounded recall tail for a
smaller bill and only fire when the cost model says so):

  1. ``fuse_maps``            — consecutive independent sem_maps collapse
                                into one FusedMap prompt pass (N calls, not
                                K*N).
  2. ``pushdown_filter``      — a filter over a join whose langex touches
                                only one side's columns moves below the join,
                                shrinking the pair space before the O(n1*n2)
                                operator runs.
  3. ``reorder_filters``      — a chain of filters over a Scan is re-ordered
                                by estimated cost x selectivity: each
                                predicate's selectivity comes from ONE shared
                                importance sample (optimizer/stats.py) probed
                                through the executor's BatchedModelCache, so
                                probe labels are re-used by the execution
                                itself.  Classic ordering: ascending
                                cost / (1 - selectivity).
  4. ``inject_sim_prefilter`` — a gold join whose estimated pair count
                                exceeds ``prefilter_threshold`` gets a
                                sem_sim_join candidate prefilter (top
                                ``prefilter_frac`` of right rows per left
                                row) when the session has an embedder.
  4b. ``choose_join_strategy`` — a ``strategy="auto"`` join is priced both
                                ways — IVF blocking + B-pair block prompts
                                + transitivity inference vs the pairwise
                                cascade — and the winner is installed on the
                                node (``strategy_auto`` marks it
                                re-choosable by the adaptive executor).
  5. ``choose_retrieval``     — every Search/SimJoin node with
                                ``index_kind="auto"`` gets an exact or IVF
                                retrieval backend by byte-aware cost (build
                                cost amortized over expected probes vs exact
                                scan, scan cost priced in HBM bytes per
                                stored dtype;
                                ``repro.index.backend.choose_retrieval_config``)
                                at the optimizer's ``recall_target``; the
                                choice — kind, IVF ``nprobe``, and tile
                                precision (int8 tiles + exact rerank when the
                                byte/recall trade wins and the corpus clears
                                ``quant_min_corpus``) — is installed on the
                                node and shows up in ``explain_plan``.
  6. ``plan_partitions``      — with ``n_partitions`` set, operators over
                                enough rows are cut into Exchange-bounded
                                fragments (``nodes.Partition`` below,
                                ``nodes.Exchange`` above) with a per-operator
                                strategy: Filter/Map/FusedMap/Extract are
                                row-parallel (contiguous partitions, gather
                                concat), TopK runs per-partition select +
                                lossless merge, Agg reduces subtree-aligned
                                partitions (hash partitions on the group key
                                for group-bys), and a gold Join either
                                broadcasts a small right side to left
                                fragments or repartitions both sides into a
                                fragment grid (cost: right-side cardinality
                                vs ``broadcast_max_rows``).  Cascades keep
                                their one *global* importance sample, so
                                thresholds — and therefore guarantees — are
                                unchanged (see ``plan.parallel``).  The same
                                rule installs the device-shard layout on
                                Search/SimJoin corpora (``shards``; exact and
                                IVF scans run shard_map-distributed when the
                                process has devices and the corpus clears
                                ``shard_min_corpus``).

Feedback: constructed with a ``stats_store`` (``repro.obs.stats_store``),
the optimizer runs a zeroth pass — ``feedback_costing`` — that installs
observed selectivities on Filter/Join nodes whose semantic fingerprint the
store has seen before, shrinkage-blended with the model prior by evidence
mass, and rules 3/5/6 price from the blended numbers.  A recurring
predicate is thus costed from what it actually did last time, not from the
static default.

``explain_plan`` renders a plan tree with per-node cardinality and
oracle-call estimates (plus, on Exchange boundaries, the partition count and
per-fragment cost share, and — given a ``stats_store`` — the observed
selectivity next to the model's guess); ``LazySemFrame.explain()`` shows
before/after plus the applied rewrite list.
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

from repro.core.operators.filter import predicate_prompt
from repro.core.optimizer import stats
from repro.core.plan import nodes as N
from repro.index.backend import (IVF_MIN_CORPUS, QUANT_MIN_CORPUS,
                                 SHARD_MIN_CORPUS, choose_retrieval_config,
                                 choose_shards)

# per-tuple oracle-equivalent unit costs (cascades mostly pay the proxy)
GOLD_FILTER_COST = 1.0
CASCADE_FILTER_COST = 0.45
GENERATE_COST = 1.0
DEFAULT_FILTER_SEL = 0.5
DEFAULT_JOIN_SEL = 0.05

_RIGHT_FIELD_RE = re.compile(r"\{right_([^{}:]+)\}")


def _device_count() -> int:
    """Device probe via the kernels dispatch helper (one definition of
    device resolution), imported lazily — plan logic must not force jax
    init on import."""
    from repro.kernels.ops import _n_devices
    return _n_devices()


@dataclasses.dataclass(frozen=True)
class AppliedRewrite:
    rule: str
    detail: str

    def __str__(self) -> str:
        return f"{self.rule}: {self.detail}"


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def estimate_cardinality(node: N.LogicalNode) -> float:
    if isinstance(node, (N.Scan, N.StreamScan)):
        return float(len(node.records))
    if isinstance(node, N.Filter):
        sel = node.selectivity if node.selectivity is not None else DEFAULT_FILTER_SEL
        return sel * estimate_cardinality(node.child)
    if isinstance(node, N.Join):
        sel = node.selectivity if node.selectivity is not None else DEFAULT_JOIN_SEL
        return (sel * estimate_cardinality(node.left)
                * estimate_cardinality(node.right))
    if isinstance(node, N.SimJoin):
        return node.k * estimate_cardinality(node.left)
    if isinstance(node, N.TopK):
        n = estimate_cardinality(node.child)
        if node.group_by is not None:
            return n  # k rows per group, group count unknown: upper bound
        return float(min(node.k, n))
    if isinstance(node, N.Search):
        return float(min(node.k, estimate_cardinality(node.child)))
    if isinstance(node, N.Agg):
        return 1.0
    # Map / FusedMap / Extract / GroupBy keep cardinality
    return estimate_cardinality(node.children()[0]) if node.children() else 0.0


def block_join_cost(n1: float, n2: float) -> float:
    """Oracle-equivalent cost of the block-join path: the mid region of an
    O(n1*k) candidate set amortized over B-pair block prompts, plus the
    pairwise coverage probes / agreement checks and the calibration bill."""
    from repro.core.optimizer.blocks import DEFAULT_BLOCK_SIZE, blocking_k
    k = min(blocking_k(int(n2)), max(int(n2), 1))
    n_cand = n1 * k
    return 0.1 * n_cand / DEFAULT_BLOCK_SIZE + 0.02 * n1 + 48.0


def cascade_join_cost(n1: float, n2: float) -> float:
    return 0.1 * n1 * n2 + n1  # sample + mid region + projection


def resolve_join_strategy(n1: float, n2: float) -> str:
    """The cost model's pick for ``strategy="auto"`` joins: blocking +
    block prompts when they beat the pairwise cascade on the pair grid."""
    return "block" if block_join_cost(n1, n2) < cascade_join_cost(n1, n2) \
        else "cascade"


def estimate_cost(node: N.LogicalNode) -> float:
    """Estimated oracle-equivalent LM calls for this node alone."""
    if isinstance(node, N.Scan) or isinstance(node, N.SimJoin):
        return 0.0
    if isinstance(node, N.Filter):
        unit = CASCADE_FILTER_COST if node.is_cascade else GOLD_FILTER_COST
        return unit * estimate_cardinality(node.child)
    if isinstance(node, N.Join):
        n1 = estimate_cardinality(node.left)
        n2 = estimate_cardinality(node.right)
        strat = node.strategy
        if strat == "auto":
            strat = resolve_join_strategy(n1, n2)
        if strat == "block":
            return block_join_cost(n1, n2)
        if strat == "cascade" or (strat is None and node.is_cascade):
            return cascade_join_cost(n1, n2)
        if node.prefilter_k:
            return n1 * min(node.prefilter_k, n2)
        return n1 * n2
    if isinstance(node, (N.Map, N.Extract, N.FusedMap)):
        return GENERATE_COST * estimate_cardinality(node.child)
    if isinstance(node, N.TopK):
        return 2.0 * estimate_cardinality(node.child)
    if isinstance(node, N.GroupBy):
        n = estimate_cardinality(node.child)
        return 2.0 * n if node.accuracy_target is None else 1.2 * n
    if isinstance(node, N.Agg):
        n = estimate_cardinality(node.child)
        return n / max(node.fanout - 1, 1) + 1
    if isinstance(node, N.Search):
        return float(node.n_rerank or 0)
    return 0.0


def total_cost(node: N.LogicalNode) -> float:
    return estimate_cost(node) + sum(total_cost(c) for c in node.children())


def predicted_selectivity(node: N.LogicalNode) -> float | None:
    """Predicted output/input-fraction for selective nodes; None where the
    notion doesn't apply (scans, maps).  The join candidate space is the
    pair grid, matching the executor's observed convention."""
    if isinstance(node, N.Filter):
        return (node.selectivity if node.selectivity is not None
                else DEFAULT_FILTER_SEL)
    if isinstance(node, N.Join):
        return (node.selectivity if node.selectivity is not None
                else DEFAULT_JOIN_SEL)
    if isinstance(node, (N.TopK, N.Search)):
        n = estimate_cardinality(node.children()[0])
        return min(float(node.k) / n, 1.0) if n else None
    if isinstance(node, N.Exchange):
        return predicted_selectivity(node.child)
    if isinstance(node, N.Partition):
        return None
    return None


def predicted_node_metrics(node: N.LogicalNode) -> dict:
    """The cost model's per-node predictions in one place — the single
    source of truth behind both ``explain_plan`` (planning time) and
    ``explain_analyze``'s predicted column (after a traced run)."""
    target = node.child if isinstance(node, (N.Exchange, N.Partition)) else node
    return {
        "rows": estimate_cardinality(node),
        "selectivity": predicted_selectivity(node),
        "oracle_calls": estimate_cost(target),
    }


def shrinkage_blend(prior: float, observed: float, weight: float,
                    prior_strength: float) -> float:
    """Observed statistic blended with its model prior, shrunk by evidence
    mass: ``weight`` is the (possibly decayed) run count behind the
    observation, ``prior_strength`` the pseudo-run weight of the prior.  A
    once-seen predicate moves the estimate a little; a recurring one
    dominates it."""
    w = max(float(weight), 0.0)
    return (prior_strength * prior + w * observed) / (prior_strength + w)


def explain_plan(node: N.LogicalNode, *, indent: str = "",
                 stats_store=None) -> str:
    pred = predicted_node_metrics(node)
    extra = ""
    if pred["selectivity"] is not None:
        extra += f", sel~{pred['selectivity']:.2f}"
    if stats_store is not None:
        # observed reality next to the model's guess, when the store has
        # seen this predicate before (keyed by semantic fingerprint)
        obs = stats_store.stats_for_node(node)
        if obs is not None and obs.selectivity is not None:
            extra += f", sel_obs={obs.selectivity:.2f} (w={obs.runs:.1f})"
    if isinstance(node, N.Exchange) and node.n_partitions > 1:
        # cost share of one fragment at this boundary (the merged operator's
        # own bill split across partitions)
        extra += f", frag_oracle~{pred['oracle_calls'] / node.n_partitions:.0f}"
    out = [f"{indent}{node.label()}  "
           f"(rows~{pred['rows']:.0f}, "
           f"oracle~{estimate_cost(node):.0f}{extra})"]
    for c in node.children():
        out.append(explain_plan(c, indent=indent + "  ",
                                stats_store=stats_store))
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


class PlanOptimizer:
    def __init__(self, session, *, oracle=None, proxy=None, sample_size: int = 32,
                 seed: int = 0, prefilter_threshold: int = 20_000,
                 prefilter_frac: float = 0.25, recall_target: float = 0.95,
                 index_min_corpus: int = IVF_MIN_CORPUS,
                 index_shared: bool = False,
                 n_partitions: int | None = None,
                 partition_min_rows: int = 32,
                 broadcast_max_rows: int = 2048,
                 shards: int | str | None = "auto",
                 shard_min_corpus: int = SHARD_MIN_CORPUS,
                 quantize: str = "auto",
                 quant_min_corpus: int = QUANT_MIN_CORPUS,
                 stats_store=None,
                 prior_strength: float = 4.0):
        self.session = session
        # probe through the executor's cache so sample labels are reused
        self.oracle = oracle if oracle is not None else session.oracle
        self.proxy = proxy if proxy is not None else session.proxy
        self.sample_size = sample_size
        self.seed = seed
        self.prefilter_threshold = prefilter_threshold
        self.prefilter_frac = prefilter_frac
        self.recall_target = recall_target          # ANN retrieval knob
        self.index_min_corpus = index_min_corpus
        # True when an IndexRegistry shares builds across sessions (the
        # serving gateway sets it): the cost model then amortizes the IVF
        # build over serving traffic instead of charging it to one plan
        self.index_shared = index_shared
        # fragment parallelism: None/1 leaves plans single-partition (the
        # pre-partition behavior); the serving gateway and collect() opt in
        self.n_partitions = n_partitions
        self.partition_min_rows = partition_min_rows
        self.broadcast_max_rows = broadcast_max_rows
        # device-shard layout for similarity corpora: "auto" = every device
        # once the corpus clears shard_min_corpus (a single-device process
        # never annotates, so plain CPU runs are untouched); an int pins it
        self.shards = shards
        self.shard_min_corpus = shard_min_corpus
        # IVF tile precision: "auto" lets the byte-aware cost model pick int8
        # tiles (+ exact rerank) once the corpus clears quant_min_corpus;
        # "int8"/"none" pin it
        self.quantize = quantize
        self.quant_min_corpus = quant_min_corpus
        # runtime feedback: observed (operator, fingerprint) statistics from
        # prior executions, blended with the model prior at prior_strength
        # pseudo-runs (see shrinkage_blend)
        self.stats_store = stats_store
        self.prior_strength = prior_strength
        self.applied: list[AppliedRewrite] = []
        self._sel_memo: dict[tuple, float] = {}

    # -- generic bottom-up transform --------------------------------------
    def _transform(self, node: N.LogicalNode, fn) -> N.LogicalNode:
        mapping = {id(c): self._transform(c, fn) for c in node.children()}
        node = node.replace_children(mapping)
        out = fn(node)
        return node if out is None else out

    def optimize(self, plan: N.LogicalNode) -> N.LogicalNode:
        self.applied = []  # per-run; the selectivity memo persists across runs
        plan = self._feedback_costing(plan)
        plan = self._transform(plan, self._fuse_maps)
        for _ in range(8):  # pushdown to fixpoint (filters sink through join stacks)
            before = len(self.applied)
            plan = self._transform(plan, self._pushdown_filter)
            if len(self.applied) == before:
                break
        plan = self._reorder_filters(plan)
        plan = self._transform(plan, self._inject_sim_prefilter)
        plan = self._transform(plan, self._choose_join_strategy)
        plan = self._transform(plan, self._choose_retrieval)
        plan = self._transform(plan, self._plan_partitions)
        return plan

    # -- rule 0: feedback-informed initial costing -------------------------
    def _blend_with_store(self, node, prior: float) -> tuple[float, float] | None:
        """(blended selectivity, evidence weight) from the stats store for a
        node's fingerprint, or None when the store has never seen it."""
        if self.stats_store is None:
            return None
        obs = self.stats_store.stats_for_node(node)
        if obs is None or obs.selectivity is None:
            return None
        return (shrinkage_blend(prior, obs.selectivity, obs.runs,
                                self.prior_strength), obs.runs)

    def _feedback_costing(self, plan):
        """Zeroth pass: install observed selectivities (shrinkage-blended
        with the default prior) on Filter/Join nodes the stats store has
        seen before, so every later rule prices from history."""
        if self.stats_store is None:
            return plan
        installed: list[str] = []

        def fn(node):
            if isinstance(node, N.Filter) and node.selectivity is None:
                prior = DEFAULT_FILTER_SEL
            elif isinstance(node, N.Join) and node.selectivity is None:
                prior = DEFAULT_JOIN_SEL
            else:
                return None
            blended = self._blend_with_store(node, prior)
            if blended is None:
                return None
            sel, weight = blended
            installed.append(f"{node.langex.template!r} sel~{sel:.2f} "
                             f"(w={weight:.1f})")
            return dataclasses.replace(node, selectivity=sel)

        plan = self._transform(plan, fn)
        if installed:
            self.applied.append(AppliedRewrite(
                "feedback_costing",
                f"{len(installed)} node(s) costed from observed history: "
                + "; ".join(installed)))
        return plan

    # -- rule 1: map fusion ------------------------------------------------
    def _fuse_maps(self, node):
        if not isinstance(node, N.Map):
            return None
        child = node.child
        if isinstance(child, N.Map):
            langexes, cols = (child.langex,), (child.out_column,)
            base = child.child
        elif isinstance(child, N.FusedMap):
            langexes, cols = child.langexes, child.out_columns
            base = child.child
        else:
            return None
        deps = {f.name for f in node.langex.fields}
        if deps & set(cols) or node.out_column in cols:
            return None  # second map reads/overwrites the first's output
        fused = N.FusedMap(base, langexes + (node.langex,), cols + (node.out_column,))
        self.applied.append(AppliedRewrite(
            "fuse_maps", f"{len(fused.langexes)} sem_maps -> one prompt pass "
                         f"(columns {', '.join(fused.out_columns)})"))
        return fused

    # -- rule 2: filter pushdown below join --------------------------------
    def _pushdown_filter(self, node):
        if not (isinstance(node, N.Filter) and isinstance(node.child, N.Join)):
            return None
        join = node.child
        fields = {f.name for f in node.langex.fields}
        if not fields:
            return None
        left_cols = join.left.columns()
        right_cols = join.right.columns()
        if fields <= left_cols:
            pushed = dataclasses.replace(node, child=join.left)
            self.applied.append(AppliedRewrite(
                "pushdown_filter",
                f"filter {node.langex.template!r} pushed below join (left side)"))
            return dataclasses.replace(join, left=pushed)
        stripped = {m.group(1) for m in _RIGHT_FIELD_RE.finditer(node.langex.template)}
        if stripped and fields == {f"right_{s}" for s in stripped} \
                and stripped <= right_cols:
            template = _RIGHT_FIELD_RE.sub(r"{\1}", node.langex.template)
            pushed = dataclasses.replace(node, child=join.right, langex=template)
            self.applied.append(AppliedRewrite(
                "pushdown_filter",
                f"filter {node.langex.template!r} pushed below join (right side)"))
            return dataclasses.replace(join, right=pushed)
        return None

    # -- rule 3: filter chain reordering -----------------------------------
    def _filter_unit_cost(self, f: N.Filter) -> float:
        unit = CASCADE_FILTER_COST if f.is_cascade else GOLD_FILTER_COST
        if self.stats_store is not None:
            # observed oracle calls per input row refine the static unit
            # cost (a well-cached or proxy-heavy predicate bills far less)
            obs = self.stats_store.stats_for_node(f)
            if obs is not None and obs.rows_in > 0:
                unit = shrinkage_blend(unit, obs.oracle_calls_per_row,
                                       obs.runs, self.prior_strength)
        return unit

    def _probe_selectivity(self, f: N.Filter, base: N.LogicalNode,
                           base_records: list, idx: np.ndarray,
                           probs: np.ndarray) -> float:
        memo_key = (f.langex.template, id(base))
        if memo_key not in self._sel_memo:
            sampled = [base_records[i] for i in idx]
            prompts = [predicate_prompt(f.langex, t) for t in sampled]
            labels, _ = self.oracle.predicate(prompts)
            self._sel_memo[memo_key] = stats.estimate_selectivity(idx, probs, labels)
        return self._sel_memo[memo_key]

    def _reorder_filters(self, node):
        if not isinstance(node, N.Filter):
            mapping = {id(c): self._reorder_filters(c) for c in node.children()}
            return node.replace_children(mapping)

        # collect the maximal chain below this (top-most) filter; the loop
        # consumes inner filters, so recursion only re-enters below the chain
        chain: list[N.Filter] = []
        cur: N.LogicalNode = node
        while isinstance(cur, N.Filter):
            chain.append(cur)
            cur = cur.child
        base = self._reorder_filters(cur)
        chain_bottom_up = list(reversed(chain))  # application order

        # a StreamScan base reorders too: its pinned snapshot is the sample
        # population (probe labels land in the shared cache, so execution —
        # and the next version's re-run — reuse them)
        base_records = base.records \
            if isinstance(base, (N.Scan, N.StreamScan)) else []
        if len(chain) < 2 or len(base_records) < 2:
            rebuilt = base
            for f in chain_bottom_up:
                rebuilt = dataclasses.replace(f, child=rebuilt)
            return rebuilt

        base_cols = base.columns()
        if any({fl.name for fl in f.langex.fields} - base_cols for f in chain):
            rebuilt = base
            for f in chain_bottom_up:
                rebuilt = dataclasses.replace(f, child=rebuilt)
            return rebuilt

        # with a proxy in the session, draw the shared sample from the SUPG
        # defensive proposal over the chain's first predicate (cheap scores);
        # without one, uniform — Hajek weighting absorbs either proposal
        scores = None
        if self.proxy is not None:
            prompts = [predicate_prompt(chain_bottom_up[0].langex, t)
                       for t in base_records]
            _, scores = self.proxy.predicate(prompts)
        idx, probs = stats.shared_sample_indices(
            len(base_records), self.sample_size, self.seed, scores=scores)
        sels = [self._probe_selectivity(f, base, base_records, idx, probs)
                for f in chain_bottom_up]
        # fold in observed history: the importance-sample probe is the prior,
        # the store's EWMA selectivity the evidence
        sels = [b[0] if (b := self._blend_with_store(f, s)) is not None else s
                for f, s in zip(chain_bottom_up, sels)]
        # optimal chain order: ascending cost / (1 - selectivity)
        rank = [self._filter_unit_cost(f) / max(1.0 - s, 1e-6)
                for f, s in zip(chain_bottom_up, sels)]
        order = sorted(range(len(chain)), key=lambda i: rank[i])
        rebuilt = base
        for i in order:
            rebuilt = dataclasses.replace(chain_bottom_up[i], child=rebuilt,
                                          selectivity=sels[i])
        if order != list(range(len(chain))):
            self.applied.append(AppliedRewrite(
                "reorder_filters",
                f"{len(chain)}-filter chain reordered by cost x selectivity "
                f"(sel={', '.join(f'{s:.2f}' for s in sels)})"))
        return rebuilt

    # -- rule 4b: block-join vs pairwise-cascade strategy ------------------
    def _choose_join_strategy(self, node):
        """Price IVF blocking + block prompts against the pairwise cascade
        for ``strategy="auto"`` joins and install the winner (visible in
        ``explain_plan`` via the node label and the rewrite list)."""
        if not isinstance(node, N.Join) or node.strategy != "auto":
            return None
        n1 = estimate_cardinality(node.left)
        n2 = estimate_cardinality(node.right)
        chosen = resolve_join_strategy(n1, n2)
        self.applied.append(AppliedRewrite(
            "choose_join_strategy",
            f"join over ~{n1 * n2:.0f} pairs -> {chosen} (block "
            f"~{block_join_cost(n1, n2):.0f} oracle units vs pairwise "
            f"cascade ~{cascade_join_cost(n1, n2):.0f})"))
        return dataclasses.replace(node, strategy=chosen, strategy_auto=True)

    # -- rule 5: cost-based exact vs IVF retrieval -------------------------
    def _choose_retrieval(self, node):
        if isinstance(node, N.Search):
            if node.index is not None or node.index_kind != "auto":
                return None  # user pinned an index or a kind
            n_corpus = estimate_cardinality(node.child)
            n_queries = 1.0
        elif isinstance(node, N.SimJoin):
            if node.index_kind != "auto":
                return None
            n_corpus = estimate_cardinality(node.right)
            n_queries = estimate_cardinality(node.left)
        else:
            return None
        corpus_child = node.child if isinstance(node, N.Search) else node.right
        k = node.k if isinstance(node, (N.Search, N.SimJoin)) else 10
        cfg = choose_retrieval_config(
            int(n_corpus), max(int(n_queries), 1),
            recall_target=self.recall_target, min_corpus=self.index_min_corpus,
            shared=self.index_shared,
            quantize=node.quantize or self.quantize,  # node pin wins
            min_quant_corpus=self.quant_min_corpus, k=max(int(k), 1))
        kind, nprobe, quantize = cfg["kind"], cfg["nprobe"], cfg["quantize"]
        if isinstance(corpus_child, N.StreamScan):
            # don't pin the size-derived nprobe on a stream corpus: it would
            # land in the versioned registry key and churn it as the table
            # grows (sqrt(n) shifts), forcing full rebuilds; the executor
            # keys by recall_target and the index derives nprobe itself
            nprobe = None
        if kind == "ivf":
            c = cfg["costs"]
            tag = "IVF-int8 (+exact rerank)" if quantize == "int8" else "IVF"
            detail = (f"{type(node).__name__.lower()} over ~{n_corpus:.0f} "
                      f"rows -> {tag} (nprobe={nprobe}/{c['n_clusters']} "
                      f"clusters, recall_target={self.recall_target}; est. "
                      f"scan units {c['ivf']:.0f} vs exact {c['exact']:.0f}")
            if quantize == "int8":
                detail += (f"; int8 {c['ivf_q']:.0f} units, "
                           f"~{c['ivf_bytes_per_query'] / max(c['ivf_q_bytes_per_query'], 1):.1f}x "
                           f"fewer scan bytes/query)")
            else:
                detail += ")"
            self.applied.append(AppliedRewrite("choose_retrieval", detail))
        # index_auto marks the choice as estimate-derived: the adaptive
        # executor may re-choose at run time when the real corpus size
        # drifts from n_corpus (user pins returned above, so stay fixed)
        return dataclasses.replace(node, index_kind=kind, nprobe=nprobe,
                                   quantize=quantize, index_auto=True)

    # -- rule 6: partition planning ----------------------------------------
    def _partition_count(self, n_rows: float) -> int:
        """Fragments for an operator over ``n_rows`` input rows: the
        configured count, capped so no fragment is empty.  Shared with the
        adaptive executor (``parallel.partition_count``) so a mid-query
        resize recomputes exactly the planner's sizing rule on observed
        rows."""
        from repro.core.plan.parallel import partition_count
        return partition_count(n_rows, self.n_partitions,
                               self.partition_min_rows)

    def _shard_count(self, n_corpus: float) -> int:
        if self.shards in (None, 0, 1) or n_corpus < 1:
            return 1
        requested = None if self.shards == "auto" else int(self.shards)
        return choose_shards(int(n_corpus), _device_count(),
                             requested=requested,
                             min_corpus=self.shard_min_corpus)

    def _wrap_row_parallel(self, node, what: str):
        P = self._partition_count(estimate_cardinality(node.child))
        if P < 2:
            return None
        wrapped = dataclasses.replace(node, child=N.Partition(node.child, P))
        self.applied.append(AppliedRewrite(
            "plan_partitions", f"{what} row-parallel over {P} partitions "
                               f"(gather concat)"))
        return N.Exchange(wrapped, "gather", P)

    def _plan_partitions(self, node):
        """Cut operators into Exchange-bounded fragments and install the
        device-shard layout on similarity corpora.  Every wrap is
        guarantee-preserving: the partitioned execution (``plan.parallel``)
        reproduces the single-partition output, and cascades keep one
        global importance sample."""
        if isinstance(node, N.Search):
            s = 1 if node.index is not None else \
                self._shard_count(estimate_cardinality(node.child))
            if s < 2:
                return None
            self.applied.append(AppliedRewrite(
                "plan_partitions",
                f"search corpus sharded across {s} devices"))
            return dataclasses.replace(node, shards=s)

        if isinstance(node, N.SimJoin):
            out = node
            s = self._shard_count(estimate_cardinality(node.right))
            if s >= 2:
                self.applied.append(AppliedRewrite(
                    "plan_partitions",
                    f"sim-join right corpus sharded across {s} devices"))
                out = dataclasses.replace(out, shards=s)
            P = self._partition_count(estimate_cardinality(node.left))
            if P >= 2:
                out = dataclasses.replace(
                    out, left=N.Partition(out.left, P),
                    right=N.Exchange(out.right, "broadcast", P))
                self.applied.append(AppliedRewrite(
                    "plan_partitions",
                    f"sim-join probe side over {P} partitions "
                    f"(right index broadcast)"))
                out = N.Exchange(out, "gather", P)
            return out if out is not node else None

        if isinstance(node, (N.Map, N.FusedMap, N.Extract)):
            return self._wrap_row_parallel(node, type(node).__name__.lower())

        if isinstance(node, N.Filter):
            mode = "cascade (global sample)" if node.is_cascade else "gold"
            return self._wrap_row_parallel(node, f"{mode} filter")

        if isinstance(node, N.TopK):
            # only the quickselect algorithm has a partitioned form (the
            # Table-7 baselines exist for measurement, not scale)
            if node.group_by is not None or node.algorithm != "quickselect":
                return None
            P = self._partition_count(estimate_cardinality(node.child))
            if P < 2:
                return None
            wrapped = dataclasses.replace(node,
                                          child=N.Partition(node.child, P))
            self.applied.append(AppliedRewrite(
                "plan_partitions",
                f"top-k over {P} partitions (per-partition quickselect + "
                f"lossless merge)"))
            return N.Exchange(wrapped, "gather", P)

        if isinstance(node, N.Agg):
            if node.partitioner is not None:  # user controls grouping/order
                return None
            P = self._partition_count(estimate_cardinality(node.child))
            if P < 2:
                return None
            if node.group_by is not None:
                part = N.Partition(node.child, P, strategy="hash",
                                   key=node.group_by)
                detail = (f"group-by agg hash-partitioned on "
                          f"{node.group_by!r} over {P} fragments")
            else:
                # fragment boundaries align to the reduction tree's root
                # subtrees -> record-identical merge; the aligned count is
                # fixed by (n, fanout), NOT by the configured n_partitions,
                # so estimate it the same way the executor derives it
                from repro.core.plan.parallel import subtree_partitions
                n_est = estimate_cardinality(node.child)
                P = len(subtree_partitions(int(n_est), node.fanout, P))
                if P < 2:
                    return None
                part = N.Partition(node.child, P, strategy="subtree")
                detail = (f"hierarchical agg over {P} subtree partitions "
                          f"+ one root reduce")
            self.applied.append(AppliedRewrite("plan_partitions", detail))
            return N.Exchange(dataclasses.replace(node, child=part),
                              "gather", P)

        if isinstance(node, N.Join):
            if node.is_cascade or node.strategy:
                # cascade joins calibrate on a global pair sample, and the
                # block path owns its own O(n1*k) candidate layout: both
                # stay single-fragment
                return None
            P = self._partition_count(estimate_cardinality(node.left))
            if P < 2:
                return None
            n2 = estimate_cardinality(node.right)
            if n2 <= self.broadcast_max_rows:
                join = dataclasses.replace(
                    node, left=N.Partition(node.left, P),
                    right=N.Exchange(node.right, "broadcast", P))
                self.applied.append(AppliedRewrite(
                    "plan_partitions",
                    f"join left over {P} partitions, right (~{n2:.0f} rows) "
                    f"broadcast"))
                return N.Exchange(join, "gather", P)
            # near-square grid capped at P fragments; the oversized right
            # side always splits (gr >= 2), the left only when P allows
            # (P=2 -> a 1x2 grid, not an inflated 2x2)
            gl = max(1, int(math.floor(math.sqrt(P))))
            gr = max(2, P // gl)
            join = dataclasses.replace(
                node, left=N.Partition(node.left, gl),
                right=N.Partition(node.right, gr))
            self.applied.append(AppliedRewrite(
                "plan_partitions",
                f"join repartitioned into a {gl}x{gr} fragment grid "
                f"(right ~{n2:.0f} rows too large to broadcast)"))
            return N.Exchange(join, "gather", gl * gr)

        return None

    # -- rule 4: sim-join prefilter ----------------------------------------
    def _inject_sim_prefilter(self, node):
        if not isinstance(node, N.Join) or node.is_cascade \
                or node.prefilter_k or node.strategy:
            return None
        if self.session.embedder is None or not node.langex.is_binary:
            return None
        n1 = estimate_cardinality(node.left)
        n2 = estimate_cardinality(node.right)
        if n1 * n2 < self.prefilter_threshold or n2 < 4:
            return None
        k = max(1, math.ceil(self.prefilter_frac * n2))
        self.applied.append(AppliedRewrite(
            "inject_sim_prefilter",
            f"gold join over ~{n1 * n2:.0f} pairs narrowed to top-{k} "
            f"similar right rows per left row"))
        return dataclasses.replace(node, prefilter_k=k)
