"""Logical plan IR: one dataclass per semantic operator.

Nodes are cheap immutable-ish descriptions (langex + knobs + child nodes);
they carry *no* execution state.  Rewrites produce new nodes with
``dataclasses.replace``.  ``columns()`` propagates the static schema the same
way the eager ``SemFrame`` does (joins prefix right columns with ``right_``),
which is what the pushdown rule reasons over.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from repro.core.langex import Langex, as_langex


@dataclasses.dataclass
class LogicalNode:
    """Base: children are the dataclass fields holding LogicalNodes."""

    def children(self) -> list["LogicalNode"]:
        return [v for f in dataclasses.fields(self)
                if isinstance(v := getattr(self, f.name), LogicalNode)]

    def replace_children(self, mapping: dict[int, "LogicalNode"]) -> "LogicalNode":
        """New node with children swapped (keyed by id of the old child)."""
        kw = {f.name: mapping[id(v)]
              for f in dataclasses.fields(self)
              if isinstance(v := getattr(self, f.name), LogicalNode) and id(v) in mapping}
        return dataclasses.replace(self, **kw) if kw else self

    def columns(self) -> set[str]:
        raise NotImplementedError

    def label(self) -> str:
        return type(self).__name__


def _lx(langex) -> Langex:
    return as_langex(langex)


@dataclasses.dataclass
class Scan(LogicalNode):
    records: Sequence[dict]

    def columns(self) -> set[str]:
        return set(self.records[0].keys()) if self.records else set()

    def label(self) -> str:
        return f"Scan[n={len(self.records)}]"


@dataclasses.dataclass
class StreamScan(LogicalNode):
    """Leaf over a versioned ``repro.stream.table.CorpusTable``.

    ``version=None`` floats with the table (resolved at each access);
    a pinned version is a reproducible snapshot — the serving gateway pins
    every StreamScan at run start so one pipeline never sees two versions,
    and subscriptions pin each re-execution to the commit that triggered it.
    """

    table: Any
    version: int | None = None

    @property
    def records(self) -> list[dict]:
        return self.table.snapshot(self.version)

    def columns(self) -> set[str]:
        return self.table.schema()

    def label(self) -> str:
        v = self.version if self.version is not None else self.table.version
        return (f"StreamScan[{self.table.table_id}@v{v}, "
                f"n={self.table.count(self.version)}]")


@dataclasses.dataclass
class Partition(LogicalNode):
    """Fragment source: split the child's rows into ``n_partitions``.

    Strategies:
      * ``contiguous`` — near-equal contiguous row ranges (the row-parallel
        default; order-preserving, so a gather is a plain concat);
      * ``subtree``    — contiguous ranges aligned to the consuming Agg's
        reduction-tree boundaries (``fanout ** (depth-1)`` leaves per
        partition), which makes the partition-local reduce subtrees exactly
        the root's child subtrees — record-identical by construction;
      * ``hash``       — rows keyed by ``key`` hash to a partition, so every
        group of a group-by lands whole in one fragment;
      * ``range``      — rows sorted by ``key`` then cut into contiguous
        runs (order statistics stay partition-local).

    Semantically transparent: an executor that ignores partitioning may run
    the child unsplit and produce identical results.
    """

    child: LogicalNode
    n_partitions: int
    strategy: str = "contiguous"
    key: str | None = None

    def columns(self) -> set[str]:
        return self.child.columns()

    def label(self) -> str:
        key = f", key={self.key}" if self.key else ""
        return f"Partition[{self.strategy}, P={self.n_partitions}{key}]"


@dataclasses.dataclass
class Exchange(LogicalNode):
    """Data-movement boundary between plan fragments.

    ``kind`` is the exchange the boundary performs:
      * ``gather``    — merge fragment outputs back into one stream (concat
        for row-parallel operators; operator-specific lossless merges for
        top-k / hierarchical aggregation);
      * ``broadcast`` — replicate the child to every fragment of the
        consuming operator (the small side of a join, a shared right-side
        retrieval index);
      * ``hash`` / ``range`` — repartition rows by key between fragments.

    Like :class:`Partition`, a partition-unaware executor may treat it as a
    no-op wrapper — the plan's results do not depend on fragmentation.
    """

    child: LogicalNode
    kind: str = "gather"
    n_partitions: int = 1

    def columns(self) -> set[str]:
        return self.child.columns()

    def label(self) -> str:
        return f"Exchange[{self.kind}, P={self.n_partitions}]"


def plain(node: LogicalNode) -> LogicalNode:
    """Strip Partition/Exchange wrappers (the underlying data-defining node:
    what corpus identity, stream-scan checks, and schema logic care about)."""
    while isinstance(node, (Partition, Exchange)):
        node = node.child
    return node


@dataclasses.dataclass
class Filter(LogicalNode):
    child: LogicalNode
    langex: Langex
    recall_target: float | None = None
    precision_target: float | None = None
    delta: float | None = None
    selectivity: float | None = None  # estimate installed by the optimizer

    def __post_init__(self):
        self.langex = _lx(self.langex)

    @property
    def is_cascade(self) -> bool:
        return self.recall_target is not None or self.precision_target is not None

    def columns(self) -> set[str]:
        return self.child.columns()

    def label(self) -> str:
        sel = f", sel~{self.selectivity:.2f}" if self.selectivity is not None else ""
        mode = "cascade" if self.is_cascade else "gold"
        return f"Filter[{mode}{sel}] {self.langex.template!r}"


@dataclasses.dataclass
class Join(LogicalNode):
    left: LogicalNode
    right: LogicalNode
    langex: Langex
    recall_target: float | None = None
    precision_target: float | None = None
    delta: float | None = None
    project_fn: Callable | None = None
    force_plan: str | None = None
    prefilter_k: int | None = None  # sim-join candidate prefilter (optimizer)
    selectivity: float | None = None  # pair-grid match rate (stats feedback)
    # fast-join strategy: None = today's dispatch (cascade iff targets set),
    # "cascade" = force the pairwise cascade, "block" = IVF blocking +
    # block prompts + transitivity inference, "auto" = let the optimizer's
    # cost model pick ("block" vs "cascade")
    strategy: str | None = None
    strategy_auto: bool = False  # strategy chosen by the optimizer, so the
                                 # adaptive executor may re-choose at run time

    def __post_init__(self):
        self.langex = _lx(self.langex)

    @property
    def is_cascade(self) -> bool:
        return self.recall_target is not None or self.precision_target is not None

    def columns(self) -> set[str]:
        return self.left.columns() | {f"right_{c}" for c in self.right.columns()}

    def label(self) -> str:
        mode = self.strategy or ("cascade" if self.is_cascade else "gold")
        pf = f", prefilter_k={self.prefilter_k}" if self.prefilter_k else ""
        sel = f", sel~{self.selectivity:.3f}" if self.selectivity is not None else ""
        return f"Join[{mode}{pf}{sel}] {self.langex.template!r}"


@dataclasses.dataclass
class TopK(LogicalNode):
    child: LogicalNode
    langex: Langex
    k: int
    algorithm: str = "quickselect"
    pivot_query: str | None = None
    group_by: str | None = None

    def __post_init__(self):
        self.langex = _lx(self.langex)

    def columns(self) -> set[str]:
        return self.child.columns()

    def label(self) -> str:
        return f"TopK[k={self.k}, {self.algorithm}] {self.langex.template!r}"


@dataclasses.dataclass
class Agg(LogicalNode):
    child: LogicalNode
    langex: Langex
    fanout: int = 8
    group_by: str | None = None
    partitioner: Callable | None = None
    out_column: str = "aggregate"

    def __post_init__(self):
        self.langex = _lx(self.langex)

    def columns(self) -> set[str]:
        cols = {self.out_column}
        if self.group_by is not None:
            cols.add(self.group_by)
        return cols

    def label(self) -> str:
        return f"Agg[fanout={self.fanout}] {self.langex.template!r}"


@dataclasses.dataclass
class GroupBy(LogicalNode):
    child: LogicalNode
    langex: Langex
    C: int
    accuracy_target: float | None = None
    delta: float | None = None

    def __post_init__(self):
        self.langex = _lx(self.langex)

    def columns(self) -> set[str]:
        return self.child.columns() | {"group", "group_label"}

    def label(self) -> str:
        return f"GroupBy[C={self.C}] {self.langex.template!r}"


@dataclasses.dataclass
class Map(LogicalNode):
    child: LogicalNode
    langex: Langex
    out_column: str = "mapped"

    def __post_init__(self):
        self.langex = _lx(self.langex)

    def columns(self) -> set[str]:
        return self.child.columns() | {self.out_column}

    def label(self) -> str:
        return f"Map[->{self.out_column}] {self.langex.template!r}"


@dataclasses.dataclass
class FusedMap(LogicalNode):
    """N sem_maps over the same input collapsed into one prompt pass."""

    child: LogicalNode
    langexes: tuple[Langex, ...]
    out_columns: tuple[str, ...]

    def __post_init__(self):
        self.langexes = tuple(_lx(l) for l in self.langexes)
        assert len(self.langexes) == len(self.out_columns)

    def columns(self) -> set[str]:
        return self.child.columns() | set(self.out_columns)

    def label(self) -> str:
        return f"FusedMap[->{','.join(self.out_columns)}] x{len(self.langexes)}"


@dataclasses.dataclass
class Extract(LogicalNode):
    child: LogicalNode
    langex: Langex
    source_field: str
    out_column: str = "extracted"

    def __post_init__(self):
        self.langex = _lx(self.langex)

    def columns(self) -> set[str]:
        return self.child.columns() | {self.out_column}

    def label(self) -> str:
        return f"Extract[{self.source_field}->{self.out_column}] {self.langex.template!r}"


def _index_tag(index_kind: str, nprobe, shards=None, quantize=None) -> str:
    out = ""
    if index_kind == "ivf":
        tag = "ivf-int8" if quantize == "int8" else "ivf"
        out = f", {tag}(nprobe={nprobe})" if nprobe else f", {tag}"
    elif index_kind != "auto":
        out = f", {index_kind}"
    if shards:
        out += f", shards={shards}"
    return out


@dataclasses.dataclass
class Search(LogicalNode):
    child: LogicalNode
    column: str
    query: str
    k: int = 10
    n_rerank: int = 0
    rerank_langex: Any = None
    index: Any = None
    index_kind: str = "auto"   # "exact" | "ivf" | "auto" (optimizer decides)
    nprobe: int | None = None  # IVF recall knob, installed by the optimizer
    shards: int | None = None  # device-shard layout, installed by the optimizer
    quantize: str | None = None  # IVF tile precision ("none"|"int8"), rule 5
    # True when rule 5 chose index_kind from a cardinality *estimate* (vs a
    # user pin): only then may the adaptive executor re-choose at run time
    index_auto: bool = False

    def columns(self) -> set[str]:
        return self.child.columns()

    def label(self) -> str:
        return (f"Search[k={self.k}"
                f"{_index_tag(self.index_kind, self.nprobe, self.shards, self.quantize)}] "
                f"{self.column}~{self.query!r}")


@dataclasses.dataclass
class SimJoin(LogicalNode):
    left: LogicalNode
    right: LogicalNode
    left_col: str
    right_col: str
    k: int = 1
    index_kind: str = "auto"
    nprobe: int | None = None
    shards: int | None = None
    quantize: str | None = None
    index_auto: bool = False   # kind chosen from an estimate (see Search)

    def columns(self) -> set[str]:
        return (self.left.columns()
                | {f"right_{c}" for c in self.right.columns()} | {"sim_score"})

    def label(self) -> str:
        return (f"SimJoin[k={self.k}"
                f"{_index_tag(self.index_kind, self.nprobe, self.shards, self.quantize)}] "
                f"{self.left_col}~{self.right_col}")
