"""PlanExecutor: the batched physical layer under the plan IR.

Walks a (possibly optimized) logical DAG bottom-up and dispatches each node
to the gold/cascade operator implementations in ``repro.core.operators``.
All model traffic goes through the executor's oracle/proxy handles; when the
executor is built with ``use_cache=True`` (the ``LazySemFrame.collect()``
path) those handles are ``BatchedModelCache`` wrappers, so a prompt answered
anywhere in the pipeline — including by the optimizer's selectivity probes —
is never re-issued to the backend.  The eager ``SemFrame`` path builds the
executor without the cache, which makes it call-for-call identical to the
pre-plan-layer behavior.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import accounting
from repro.core.operators import agg as _agg
from repro.core.operators import filter as _filter
from repro.core.operators import groupby as _groupby
from repro.core.operators import join as _join
from repro.core.operators import mapex as _mapex
from repro.core.operators import search as _search
from repro.core.operators import topk as _topk
from repro.core.plan import nodes as N
from repro.core.plan.cache import BatchedModelCache
from repro.index.backend import MASKED_SCORE


class PlanExecutor:
    def __init__(self, session, *, stats_log: list | None = None,
                 use_cache: bool = False, oracle=None, proxy=None,
                 embedder=None, stage_hook=None, index_registry=None,
                 recall_target: float = 0.95,
                 index_min_corpus: int | None = None):
        self.session = session
        self.stats_log = stats_log if stats_log is not None else []
        if oracle is None:
            oracle = BatchedModelCache(session.oracle) if use_cache else session.oracle
        if proxy is None and session.proxy is not None:
            proxy = BatchedModelCache(session.proxy) if use_cache else session.proxy
        self.oracle = oracle
        self.proxy = proxy
        self.embedder = embedder if embedder is not None else session.embedder
        # called before every node dispatch — the serving gateway's yield
        # point for cancellation / deadline checks between pipeline stages
        self.stage_hook = stage_hook
        # process-wide index sharing (the serving gateway passes one
        # IndexRegistry so concurrent sessions over the same corpus build
        # and embed once); None -> build per call (eager/lazy single-query)
        self.index_registry = index_registry
        # retrieval knobs for "auto" builds the optimizer didn't annotate
        # (e.g. the join sim-prefilter): recall_target=1.0 must force exact
        # everywhere for the record-identical contract to hold
        self.recall_target = recall_target
        self.index_min_corpus = index_min_corpus

    # -- retrieval plumbing ------------------------------------------------
    def _build_index(self, texts: list[str], *, kind: str = "auto",
                     nprobe: int | None = None, n_queries: int = 1):
        """Embed + index ``texts`` through the RetrievalBackend layer,
        consulting the shared IndexRegistry when one is installed."""
        from repro.index.backend import IVF_MIN_CORPUS, choose_backend
        if kind == "auto":
            # a registry amortizes the IVF build across sessions; without
            # one the index dies with this call, so the build must pay for
            # itself against a single exact scan
            kind, auto_probe = choose_backend(
                len(texts), max(n_queries, 1),
                recall_target=self.recall_target,
                min_corpus=self.index_min_corpus or IVF_MIN_CORPUS,
                shared=self.index_registry is not None)
            nprobe = nprobe if nprobe is not None else auto_probe
        kw = {"nprobe": nprobe} if (kind == "ivf" and nprobe) else {}
        if self.index_registry is None:
            return _search.sem_index(texts, self.embedder, index=kind, **kw)
        return self.index_registry.get_or_build(
            texts, self.embedder, kind=kind, params=kw,
            builder=lambda: _search.sem_index(texts, self.embedder,
                                              index=kind, **kw))

    def _build_stream_index(self, scan: N.StreamScan, column: str,
                            n_corpus: int, *, kind: str = "auto",
                            nprobe: int | None = None, n_queries: int = 1):
        """Version-aware index for a StreamScan corpus: the registry keys on
        (table id, embedder, config) instead of a content fingerprint, so an
        appends-only commit reuses the cached base index and embeds/indexes
        only the delta rows (``IndexRegistry.get_or_update``)."""
        from repro.index.backend import IVF_MIN_CORPUS, choose_backend
        table = scan.table
        version = scan.version if scan.version is not None else table.version
        if kind == "auto":
            kind, _ = choose_backend(
                n_corpus, max(n_queries, 1),
                recall_target=self.recall_target,
                min_corpus=self.index_min_corpus or IVF_MIN_CORPUS,
                shared=True)
        # key by the recall target, NOT a size-derived nprobe: the derived
        # probe count shifts as the table grows, and a shifting key would
        # turn every append into a full rebuild; the index derives (and on
        # retrain re-derives) nprobe from the target itself.  A user-pinned
        # nprobe stays in the key — it is corpus-size-independent.
        if kind != "ivf":
            kw = {}
        elif nprobe is not None:
            kw = {"nprobe": nprobe}
        else:
            kw = {"recall_target": self.recall_target}

        def builder(records):
            return _search.sem_index([str(t[column]) for t in records],
                                     self.embedder, index=kind, **kw)

        def updater(index, added):
            with accounting.track("sem_index_delta") as st:
                texts = [str(t[column]) for t in added]
                index.add(self.embedder.embed(texts))
                st.details.update(index=index.kind, delta_rows=len(texts),
                                  table=table.table_id, version=version)
            self.stats_log.append(st.as_dict())

        return self.index_registry.get_or_update(
            table, self.embedder, version=version, kind=kind, params=kw,
            builder=builder, updater=updater)

    def _corpus_index(self, child: N.LogicalNode, texts: list[str], column: str,
                      *, kind: str = "auto", nprobe: int | None = None,
                      n_queries: int = 1):
        """Executor delta routing: a StreamScan corpus under a registry goes
        through the versioned reuse path; everything else builds (or fetches
        by content fingerprint) as before."""
        if self.index_registry is not None and isinstance(child, N.StreamScan):
            return self._build_stream_index(child, column, len(texts), kind=kind,
                                            nprobe=nprobe, n_queries=n_queries)
        return self._build_index(texts, kind=kind, nprobe=nprobe,
                                 n_queries=n_queries)

    # -- plumbing ---------------------------------------------------------
    def _log(self, stats: dict) -> dict:
        self.stats_log.append(stats)
        # every operator logs right after its model work: together with the
        # descent-time check in run() this yields between pipeline stages,
        # so a cancellation lands before the *next* stage's model calls
        if self.stage_hook is not None:
            self.stage_hook(None)
        return stats

    def _targets(self, node) -> dict:
        s = self.session
        return dict(
            recall_target=node.recall_target or 0.9,
            precision_target=node.precision_target or 0.9,
            delta=node.delta if node.delta is not None else s.default_delta,
            sample_size=s.sample_size, seed=s.seed)

    def run(self, node: N.LogicalNode) -> list[dict]:
        if self.stage_hook is not None:
            self.stage_hook(node)
        fn = getattr(self, f"_run_{type(node).__name__.lower()}")
        return fn(node)

    # -- leaves ------------------------------------------------------------
    def _run_scan(self, node: N.Scan) -> list[dict]:
        return list(node.records)

    def _run_streamscan(self, node: N.StreamScan) -> list[dict]:
        # pinned version -> reproducible snapshot; floating -> current rows
        return node.records

    # -- filter ------------------------------------------------------------
    def _run_filter(self, node: N.Filter) -> list[dict]:
        recs = self.run(node.child)
        if not node.is_cascade:
            mask, stats = _filter.sem_filter_gold(recs, node.langex, self.oracle)
        else:
            if self.proxy is None:
                raise ValueError("optimized sem_filter needs a proxy model in the Session")
            mask, stats = _filter.sem_filter_cascade(
                recs, node.langex, self.oracle, self.proxy, **self._targets(node))
        self._log(stats)
        return [t for t, m in zip(recs, mask) if m]

    # -- join --------------------------------------------------------------
    def _run_join(self, node: N.Join) -> list[dict]:
        left = self.run(node.left)
        right = self.run(node.right)
        if node.is_cascade:
            if self.embedder is None:
                raise ValueError("optimized sem_join needs an embedder in the Session")
            mask, stats = _join.sem_join_cascade(
                left, right, node.langex, self.oracle, self.embedder,
                project_fn=node.project_fn, force_plan=node.force_plan,
                **self._targets(node))
        elif node.prefilter_k:
            mask, stats = self._join_prefiltered(node, left, right)
        else:
            mask, stats = _join.sem_join_gold(left, right, node.langex, self.oracle)
        self._log(stats)
        out = []
        n1, n2 = mask.shape
        for i in range(n1):
            for j in range(n2):
                if mask[i, j]:
                    out.append({**left[i],
                                **{f"right_{k}": v for k, v in right[j].items()}})
        return out

    def _join_prefiltered(self, node: N.Join, left, right):
        """Gold join narrowed to each left row's top-k most-similar right rows
        (the optimizer-injected sem_sim_join prefilter; trades a recall tail
        for an n1*k instead of n1*n2 oracle bill)."""
        lx = node.langex
        with accounting.track("sem_join_prefiltered") as st:
            n1, n2 = len(left), len(right)
            k = min(node.prefilter_k, n2)
            lfields = [f for f in lx.fields if f.side != "right"]
            rfields = [f for f in lx.fields if f.side == "right"]
            # candidate retrieval rides the RetrievalBackend layer (shared
            # with sem_sim_join: exact or IVF by the cost model / registry)
            right_index = self._build_index(
                _join._render_side(right, rfields), n_queries=n1)
            emb_l = self.embedder.embed(_join._render_side(left, lfields))
            _, cand = right_index.search(emb_l, k)
            pairs = [(i, int(j)) for i in range(n1) for j in cand[i]]
            passed, _ = self.oracle.predicate(_join._pair_prompts(lx, left, right, pairs))
            mask = np.zeros((n1, n2), bool)
            for (i, j), p in zip(pairs, passed):
                mask[i, j] = p
            st.details.update(prefilter_k=k, candidate_pairs=len(pairs),
                              pruned_pairs=n1 * n2 - len(pairs),
                              index=right_index.kind,
                              **{f"index_{kk}": v for kk, v in
                                 right_index.last_stats.items()
                                 if kk in ("scored_vectors", "probed_clusters")})
            return mask, st.as_dict()

    # -- topk --------------------------------------------------------------
    def _run_topk(self, node: N.TopK) -> list[dict]:
        recs = self.run(node.child)
        if node.group_by is not None:
            groups: dict = {}
            for t in recs:
                groups.setdefault(t[node.group_by], []).append(t)
            out = []
            for _, sub in sorted(groups.items(), key=lambda kv: str(kv[0])):
                child = dataclasses.replace(node, child=N.Scan(sub), group_by=None)
                out.extend(self.run(child))
            return out

        s = self.session
        pivot_scores = None
        if node.pivot_query is not None and self.embedder is not None:
            # pivot selection rides the retrieval layer: the corpus index is
            # registry-shared, so concurrent sessions embed the texts once
            index = self._build_index([node.langex.render(t) for t in recs],
                                      kind="exact")
            qv = self.embedder.embed([node.pivot_query])
            pivot_scores = index.pairwise(qv)[0]
        fn = {"quickselect": _topk.sem_topk_quickselect,
              "quadratic": _topk.sem_topk_quadratic,
              "heap": _topk.sem_topk_heap}[node.algorithm]
        if node.algorithm == "quickselect":
            idx, stats = fn(recs, node.langex, node.k, self.oracle,
                            pivot_scores=pivot_scores, seed=s.seed)
        else:
            idx, stats = fn(recs, node.langex, node.k, self.oracle)
        self._log(stats)
        return [recs[i] for i in idx]

    # -- agg ---------------------------------------------------------------
    def _run_agg(self, node: N.Agg) -> list[dict]:
        recs = self.run(node.child)
        if node.group_by is not None:
            groups: dict = {}
            for t in recs:
                groups.setdefault(t[node.group_by], []).append(t)
            out = []
            for g, sub in groups.items():
                answer, stats = _agg.sem_agg_hierarchical(
                    sub, node.langex, self.oracle,
                    fanout=node.fanout, partitioner=node.partitioner)
                self._log(stats)
                out.append({node.group_by: g, node.out_column: answer})
            return out
        answer, stats = _agg.sem_agg_hierarchical(
            recs, node.langex, self.oracle,
            fanout=node.fanout, partitioner=node.partitioner)
        self._log(stats)
        return [{node.out_column: answer}]

    # -- group_by ----------------------------------------------------------
    def _run_groupby(self, node: N.GroupBy) -> list[dict]:
        recs = self.run(node.child)
        s = self.session
        if self.embedder is None:
            raise ValueError("sem_group_by needs an embedder in the Session")
        if node.accuracy_target is None:
            res = _groupby.sem_group_by_gold(recs, node.langex, node.C,
                                             self.oracle, self.embedder, seed=s.seed)
        else:
            res = _groupby.sem_group_by_cascade(
                recs, node.langex, node.C, self.oracle, self.embedder,
                accuracy_target=node.accuracy_target,
                delta=node.delta if node.delta is not None else s.default_delta,
                sample_size=s.sample_size, seed=s.seed)
        self._log(res.stats)
        return [{**t, "group": int(g), "group_label": res.labels[int(g)]}
                for t, g in zip(recs, res.assignment)]

    # -- map family --------------------------------------------------------
    def _run_map(self, node: N.Map) -> list[dict]:
        recs = self.run(node.child)
        texts, stats = _mapex.sem_map(recs, node.langex, self.oracle)
        self._log(stats)
        return [{**t, node.out_column: x} for t, x in zip(recs, texts)]

    def _run_fusedmap(self, node: N.FusedMap) -> list[dict]:
        recs = self.run(node.child)
        columns, stats = _mapex.sem_map_fused(recs, node.langexes, self.oracle)
        self._log(stats)
        return [{**t, **{c: col[i] for c, col in zip(node.out_columns, columns)}}
                for i, t in enumerate(recs)]

    def _run_extract(self, node: N.Extract) -> list[dict]:
        recs = self.run(node.child)
        texts, stats = _mapex.sem_extract(recs, node.langex, self.oracle,
                                          source_field=node.source_field)
        self._log(stats)
        return [{**t, node.out_column: x} for t, x in zip(recs, texts)]

    # -- similarity family -------------------------------------------------
    def _run_search(self, node: N.Search) -> list[dict]:
        recs = self.run(node.child)
        index = node.index or self._corpus_index(
            node.child, [str(t[node.column]) for t in recs], node.column,
            kind=node.index_kind, nprobe=node.nprobe)
        # a shared stream index can be ahead of this run's pinned snapshot
        # (a commit landed mid-query): bound hits to the snapshot's rows
        cutoff = len(recs) if isinstance(node.child, N.StreamScan) else None
        hits, stats = _search.sem_search(
            index, node.query, self.embedder, k=node.k, n_rerank=node.n_rerank,
            rerank_model=self.oracle if node.n_rerank else None,
            records=recs, rerank_langex=node.rerank_langex, max_pos=cutoff)
        self._log(stats)
        return [recs[i] for i in hits if i < len(recs)]

    def _run_simjoin(self, node: N.SimJoin) -> list[dict]:
        left = self.run(node.left)
        right = self.run(node.right)
        index = self._corpus_index(node.right,
                                   [str(t[node.right_col]) for t in right],
                                   node.right_col, kind=node.index_kind,
                                   nprobe=node.nprobe, n_queries=len(left))
        cutoff = len(right) if isinstance(node.right, N.StreamScan) else None
        scores, idx, stats = _search.sem_sim_join(
            [str(t[node.left_col]) for t in left], index, self.embedder,
            k=node.k, max_pos=cutoff)
        self._log(stats)
        out = []
        for i, t in enumerate(left):
            for rank in range(idx.shape[1]):
                j = int(idx[i, rank])
                if j >= len(right) or scores[i, rank] <= MASKED_SCORE / 2:
                    continue  # beyond the pinned snapshot / unfilled slot
                out.append({**t, **{f"right_{kk}": v for kk, v in right[j].items()},
                            "sim_score": float(scores[i, rank])})
        return out
