"""PlanExecutor: the batched physical layer under the plan IR.

Walks a (possibly optimized) logical DAG bottom-up and dispatches each node
to the gold/cascade operator implementations in ``repro.core.operators``.
All model traffic goes through the executor's oracle/proxy handles; when the
executor is built with ``use_cache=True`` (the ``LazySemFrame.collect()``
path) those handles are ``BatchedModelCache`` wrappers, so a prompt answered
anywhere in the pipeline — including by the optimizer's selectivity probes —
is never re-issued to the backend.  The eager ``SemFrame`` path builds the
executor without the cache, which makes it call-for-call identical to the
pre-plan-layer behavior.

Partitioning: the base executor treats ``Partition``/``Exchange`` nodes as
transparent wrappers (single-partition semantics — by the IR contract that
fragmentation never changes results).  :class:`PartitionedExecutor` instead
executes each Exchange-bounded region as fragments over row partitions with
the guarantee-preserving merges of ``repro.core.plan.parallel`` — serially
without a pool, concurrently on a fragment thread pool (its own, or one the
serving gateway shares across sessions).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import accounting
from repro.core.operators import agg as _agg
from repro.obs import trace as _trace
from repro.core.operators import filter as _filter
from repro.core.operators import groupby as _groupby
from repro.core.operators import join as _join
from repro.core.operators import mapex as _mapex
from repro.core.operators import search as _search
from repro.core.operators import topk as _topk
from repro.core.plan import nodes as N
from repro.core.plan import parallel
from repro.core.plan.cache import BatchedModelCache
from repro.index.backend import MASKED_SCORE


class PlanExecutor:
    def __init__(self, session, *, stats_log: list | None = None,
                 use_cache: bool = False, oracle=None, proxy=None,
                 embedder=None, stage_hook=None, index_registry=None,
                 recall_target: float = 0.95,
                 index_min_corpus: int | None = None, stats_store=None,
                 matviews=None):
        self.session = session
        # cross-session observed-statistics feed (repro.obs.StatsStore);
        # None -> no observation overhead
        self.stats_store = stats_store
        # semantic materialized-view registry (repro.serve.matview): when
        # installed, every materializable subplan consults it by plan
        # fingerprint before executing, so concurrent sessions sharing a
        # subplan compute it once
        self.matviews = matviews
        self._matview_fp: dict[int, str | None] = {}
        self._matview_active: set[str] = set()
        self.stats_log = stats_log if stats_log is not None else []
        if oracle is None:
            oracle = BatchedModelCache(session.oracle) if use_cache else session.oracle
        if proxy is None and session.proxy is not None:
            proxy = BatchedModelCache(session.proxy) if use_cache else session.proxy
        self.oracle = oracle
        self.proxy = proxy
        self.embedder = embedder if embedder is not None else session.embedder
        # called before every node dispatch — the serving gateway's yield
        # point for cancellation / deadline checks between pipeline stages
        self.stage_hook = stage_hook
        # process-wide index sharing (the serving gateway passes one
        # IndexRegistry so concurrent sessions over the same corpus build
        # and embed once); None -> build per call (eager/lazy single-query)
        self.index_registry = index_registry
        # retrieval knobs for "auto" builds the optimizer didn't annotate
        # (e.g. the join sim-prefilter): recall_target=1.0 must force exact
        # everywhere for the record-identical contract to hold
        self.recall_target = recall_target
        self.index_min_corpus = index_min_corpus

    # -- retrieval plumbing ------------------------------------------------
    def _build_index(self, texts: list[str], *, kind: str = "auto",
                     nprobe: int | None = None, n_queries: int = 1,
                     shards: int | None = None, quantize: str | None = None):
        """Embed + index ``texts`` through the RetrievalBackend layer,
        consulting the shared IndexRegistry when one is installed.
        ``shards`` (optimizer-installed device layout) and ``quantize``
        (IVF tile precision) become build params, so the registry keys
        sharded/unsharded and int8/fp32 builds of the same corpus
        separately — a cached build never aliases across precisions."""
        from repro.index.backend import (IVF_MIN_CORPUS,
                                         choose_retrieval_config)
        if kind == "auto":
            # a registry amortizes the IVF build across sessions; without
            # one the index dies with this call, so the build must pay for
            # itself against a single exact scan
            cfg = choose_retrieval_config(
                len(texts), max(n_queries, 1),
                recall_target=self.recall_target,
                min_corpus=self.index_min_corpus or IVF_MIN_CORPUS,
                shared=self.index_registry is not None)
            kind = cfg["kind"]
            nprobe = nprobe if nprobe is not None else cfg["nprobe"]
            quantize = quantize if quantize is not None else cfg["quantize"]
        kw = {"nprobe": nprobe} if (kind == "ivf" and nprobe) else {}
        if kind == "ivf" and quantize and quantize != "none":
            kw["quantize"] = quantize
        if shards and shards > 1:
            kw["shards"] = int(shards)
        if self.index_registry is None:
            return _search.sem_index(texts, self.embedder, index=kind, **kw)
        return self.index_registry.get_or_build(
            texts, self.embedder, kind=kind, params=kw,
            builder=lambda: _search.sem_index(texts, self.embedder,
                                              index=kind, **kw))

    def _build_stream_index(self, scan: N.StreamScan, column: str,
                            n_corpus: int, *, kind: str = "auto",
                            nprobe: int | None = None, n_queries: int = 1,
                            shards: int | None = None,
                            quantize: str | None = None):
        """Version-aware index for a StreamScan corpus: the registry keys on
        (table id, embedder, config) instead of a content fingerprint, so an
        appends-only commit reuses the cached base index and embeds/indexes
        only the delta rows (``IndexRegistry.get_or_update``)."""
        from repro.index.backend import (IVF_MIN_CORPUS,
                                         choose_retrieval_config)
        table = scan.table
        version = scan.version if scan.version is not None else table.version
        if kind == "auto":
            cfg = choose_retrieval_config(
                n_corpus, max(n_queries, 1),
                recall_target=self.recall_target,
                min_corpus=self.index_min_corpus or IVF_MIN_CORPUS,
                shared=True)
            kind = cfg["kind"]
            quantize = quantize if quantize is not None else cfg["quantize"]
        # key by the recall target, NOT a size-derived nprobe: the derived
        # probe count shifts as the table grows, and a shifting key would
        # turn every append into a full rebuild; the index derives (and on
        # retrain re-derives) nprobe from the target itself.  A user-pinned
        # nprobe stays in the key — it is corpus-size-independent.
        if kind != "ivf":
            kw = {}
        elif nprobe is not None:
            kw = {"nprobe": nprobe}
        else:
            kw = {"recall_target": self.recall_target}
        if kind == "ivf" and quantize and quantize != "none":
            # tile precision is corpus-size-independent and changes stored
            # bytes + scores: it must live in the versioned key so int8 and
            # fp32 builds of the same table never alias
            kw["quantize"] = quantize
        if shards and shards > 1:
            # shard layout is corpus-size-independent (device count), so it
            # is safe in the versioned key — appends keep reusing the entry
            kw["shards"] = int(shards)

        def builder(records):
            return _search.sem_index([str(t[column]) for t in records],
                                     self.embedder, index=kind, **kw)

        def updater(index, added):
            with accounting.track("sem_index_delta") as st:
                texts = [str(t[column]) for t in added]
                index.add(self.embedder.embed(texts))
                st.details.update(index=index.kind, delta_rows=len(texts),
                                  table=table.table_id, version=version)
            self.stats_log.append(st.as_dict())

        return self.index_registry.get_or_update(
            table, self.embedder, version=version, kind=kind, params=kw,
            builder=builder, updater=updater)

    def _corpus_index(self, child: N.LogicalNode, texts: list[str], column: str,
                      *, kind: str = "auto", nprobe: int | None = None,
                      n_queries: int = 1, shards: int | None = None,
                      quantize: str | None = None, index_auto: bool = False):
        """Executor delta routing: a StreamScan corpus under a registry goes
        through the versioned reuse path; everything else builds (or fetches
        by content fingerprint) as before.  ``child`` is unwrapped through
        Partition/Exchange markers — fragmentation never changes what corpus
        an index covers.  ``index_auto`` flags an optimizer-estimated (not
        user-pinned) kind; the base executor honors the plan as written and
        the adaptive subclass may re-choose on observed corpus size."""
        child = N.plain(child)
        if self.index_registry is not None and isinstance(child, N.StreamScan):
            return self._build_stream_index(child, column, len(texts), kind=kind,
                                            nprobe=nprobe, n_queries=n_queries,
                                            shards=shards, quantize=quantize)
        return self._build_index(texts, kind=kind, nprobe=nprobe,
                                 n_queries=n_queries, shards=shards,
                                 quantize=quantize)

    # -- plumbing ---------------------------------------------------------
    def _log(self, stats: dict, node=None, *, n_in: int | None = None,
             n_out: int | None = None) -> dict:
        self.stats_log.append(stats)
        # observed cardinalities: annotate the active plan-stage span (for
        # explain_analyze) and feed the cross-session StatsStore
        if n_in is not None:
            sp = _trace.current_span()
            if sp is not None and sp.kind == "plan_stage":
                sp.set(rows_in=n_in, rows_out=n_out)
            if self.stats_store is not None and node is not None:
                self.stats_store.observe_node(node, stats, rows_in=n_in,
                                              rows_out=n_out or 0)
        # every operator logs right after its model work: together with the
        # descent-time check in run() this yields between pipeline stages,
        # so a cancellation lands before the *next* stage's model calls
        if self.stage_hook is not None:
            self.stage_hook(None)
        return stats

    def _targets(self, node) -> dict:
        s = self.session
        return dict(
            recall_target=node.recall_target or 0.9,
            precision_target=node.precision_target or 0.9,
            delta=node.delta if node.delta is not None else s.default_delta,
            sample_size=s.sample_size, seed=s.seed)

    def run(self, node: N.LogicalNode) -> list[dict]:
        if self.stage_hook is not None:
            self.stage_hook(node)
        fn = getattr(self, f"_run_{type(node).__name__.lower()}")
        if self.matviews is not None:
            inner = fn
            fn = lambda n: self._matview_dispatch(n, inner)
        if _trace.current_tracer() is None:
            return fn(node)
        # one span per plan node; node_id keys the explain_analyze join
        # between the executed span tree and the optimized plan tree
        with _trace.span(type(node).__name__, kind="plan_stage",
                         label=node.label(), node_id=id(node)) as sp:
            out = fn(node)
            sp.set(rows_out=len(out))
            return out

    def _matview_dispatch(self, node: N.LogicalNode, inner) -> list[dict]:
        """Consult the materialized-view registry before executing a
        materializable subplan.  Exchange/Partition wrappers fingerprint as
        their wrapped operator, so the consult happens at the outermost
        wrapper; ``_matview_active`` keeps the in-progress key from being
        re-consulted by the nested run() of the same subplan (the compute
        path descends through the very nodes that produced the key)."""
        key = self.matviews.key_for(node, memo=self._matview_fp)
        if key is None or key in self._matview_active:
            return inner(node)
        self._matview_active.add(key)
        try:
            records, hit = self.matviews.get_or_compute(
                key, lambda: inner(node), wait_hook=self.stage_hook)
        finally:
            self._matview_active.discard(key)
        if hit:
            self.stats_log.append({"operator": "matview_hit",
                                   "rows_out": len(records),
                                   "key": key[:16]})
            sp = _trace.current_span()
            if sp is not None and sp.kind == "plan_stage":
                sp.set(matview=True, rows_out=len(records))
        return records

    # -- leaves ------------------------------------------------------------
    def _run_scan(self, node: N.Scan) -> list[dict]:
        return list(node.records)

    def _run_streamscan(self, node: N.StreamScan) -> list[dict]:
        # pinned version -> reproducible snapshot; floating -> current rows
        return node.records

    # -- partition boundaries ----------------------------------------------
    # Partition/Exchange are semantically transparent by IR contract, so the
    # base executor runs them single-partition (identical results); the
    # PartitionedExecutor subclass overrides _run_exchange with real
    # fragment-parallel execution.
    def _run_partition(self, node: N.Partition) -> list[dict]:
        return self.run(node.child)

    def _run_exchange(self, node: N.Exchange) -> list[dict]:
        return self.run(node.child)

    # -- filter ------------------------------------------------------------
    def _run_filter(self, node: N.Filter) -> list[dict]:
        recs = self.run(node.child)
        if not node.is_cascade:
            mask, stats = _filter.sem_filter_gold(recs, node.langex, self.oracle)
        else:
            if self.proxy is None:
                raise ValueError("optimized sem_filter needs a proxy model in the Session")
            mask, stats = _filter.sem_filter_cascade(
                recs, node.langex, self.oracle, self.proxy, **self._targets(node))
        out = [t for t, m in zip(recs, mask) if m]
        self._log(stats, node, n_in=len(recs), n_out=len(out))
        return out

    # -- join --------------------------------------------------------------
    def _join_dispatch(self, node: N.Join, left, right):
        """Strategy dispatch shared by this executor and the adaptive
        subclass: ``strategy=None`` reproduces the historical dispatch
        bit-identically (cascade iff targets are set, else prefilter/gold);
        ``"cascade"`` forces the pairwise cascade; ``"block"`` runs the
        three-stage fast path; ``"auto"`` resolves through the optimizer's
        cost model at observed cardinalities."""
        strategy = node.strategy
        if strategy == "auto":
            from repro.core.plan.optimize import resolve_join_strategy
            strategy = resolve_join_strategy(len(left), len(right))
        if strategy == "block":
            if self.embedder is None:
                raise ValueError("block sem_join needs an embedder in the Session")
            return _join.sem_join_block(
                left, right, node.langex, self.oracle, self.embedder,
                equivalence=node.langex.equivalence or None,
                index_builder=lambda texts, nq: self._build_index(
                    texts, n_queries=nq),
                **self._targets(node))
        if strategy == "cascade" or (strategy is None and node.is_cascade):
            if self.embedder is None:
                raise ValueError("optimized sem_join needs an embedder in the Session")
            return _join.sem_join_cascade(
                left, right, node.langex, self.oracle, self.embedder,
                project_fn=node.project_fn, force_plan=node.force_plan,
                **self._targets(node))
        if node.prefilter_k:
            return self._join_prefiltered(node, left, right)
        return _join.sem_join_gold(left, right, node.langex, self.oracle)

    def _run_join(self, node: N.Join) -> list[dict]:
        left = self.run(node.left)
        right = self.run(node.right)
        mask, stats = self._join_dispatch(node, left, right)
        out = []
        n1, n2 = mask.shape
        for i in range(n1):
            for j in range(n2):
                if mask[i, j]:
                    out.append({**left[i],
                                **{f"right_{k}": v for k, v in right[j].items()}})
        # candidate space for a join is the pair grid, so selectivity is
        # matches / (n1*n2) — the quantity the optimizer's join estimate uses
        self._log(stats, node, n_in=n1 * n2, n_out=len(out))
        return out

    def _join_prefiltered(self, node: N.Join, left, right):
        """Gold join narrowed to each left row's top-k most-similar right rows
        (the optimizer-injected sem_sim_join prefilter; trades a recall tail
        for an n1*k instead of n1*n2 oracle bill)."""
        lx = node.langex
        with accounting.track("sem_join_prefiltered") as st:
            n1, n2 = len(left), len(right)
            k = min(node.prefilter_k, n2)
            lfields = [f for f in lx.fields if f.side != "right"]
            rfields = [f for f in lx.fields if f.side == "right"]
            # candidate retrieval rides the RetrievalBackend layer (shared
            # with sem_sim_join: exact or IVF by the cost model / registry)
            right_index = self._build_index(
                _join._render_side(right, rfields), n_queries=n1)
            emb_l = self.embedder.embed(_join._render_side(left, lfields))
            _, cand = right_index.search(emb_l, k)
            pairs = [(i, int(j)) for i in range(n1) for j in cand[i]]
            passed, _ = self.oracle.predicate(_join._pair_prompts(lx, left, right, pairs))
            mask = np.zeros((n1, n2), bool)
            for (i, j), p in zip(pairs, passed):
                mask[i, j] = p
            st.details.update(prefilter_k=k, candidate_pairs=len(pairs),
                              pruned_pairs=n1 * n2 - len(pairs),
                              index=right_index.kind,
                              **{f"index_{kk}": v for kk, v in
                                 right_index.last_stats.items()
                                 if kk in ("scored_vectors", "probed_clusters")})
            return mask, st.as_dict()

    # -- topk --------------------------------------------------------------
    def _run_topk(self, node: N.TopK) -> list[dict]:
        recs = self.run(node.child)
        if node.group_by is not None:
            groups: dict = {}
            for t in recs:
                groups.setdefault(t[node.group_by], []).append(t)
            out = []
            for _, sub in sorted(groups.items(), key=lambda kv: str(kv[0])):
                child = dataclasses.replace(node, child=N.Scan(sub), group_by=None)
                out.extend(self.run(child))
            return out

        s = self.session
        pivot_scores = None
        if node.pivot_query is not None and self.embedder is not None:
            # pivot selection rides the retrieval layer: the corpus index is
            # registry-shared, so concurrent sessions embed the texts once
            index = self._build_index([node.langex.render(t) for t in recs],
                                      kind="exact")
            qv = self.embedder.embed([node.pivot_query])
            pivot_scores = index.pairwise(qv)[0]
        fn = {"quickselect": _topk.sem_topk_quickselect,
              "quadratic": _topk.sem_topk_quadratic,
              "heap": _topk.sem_topk_heap}[node.algorithm]
        if node.algorithm == "quickselect":
            idx, stats = fn(recs, node.langex, node.k, self.oracle,
                            pivot_scores=pivot_scores, seed=s.seed)
        else:
            idx, stats = fn(recs, node.langex, node.k, self.oracle)
        self._log(stats, node, n_in=len(recs), n_out=len(idx))
        return [recs[i] for i in idx]

    # -- agg ---------------------------------------------------------------
    def _run_agg(self, node: N.Agg) -> list[dict]:
        recs = self.run(node.child)
        if node.group_by is not None:
            groups: dict = {}
            for t in recs:
                groups.setdefault(t[node.group_by], []).append(t)
            out = []
            for g, sub in groups.items():
                answer, stats = _agg.sem_agg_hierarchical(
                    sub, node.langex, self.oracle,
                    fanout=node.fanout, partitioner=node.partitioner)
                self._log(stats, node, n_in=len(sub), n_out=1)
                out.append({node.group_by: g, node.out_column: answer})
            return out
        answer, stats = _agg.sem_agg_hierarchical(
            recs, node.langex, self.oracle,
            fanout=node.fanout, partitioner=node.partitioner)
        self._log(stats, node, n_in=len(recs), n_out=1)
        return [{node.out_column: answer}]

    # -- group_by ----------------------------------------------------------
    def _run_groupby(self, node: N.GroupBy) -> list[dict]:
        recs = self.run(node.child)
        s = self.session
        if self.embedder is None:
            raise ValueError("sem_group_by needs an embedder in the Session")
        if node.accuracy_target is None:
            res = _groupby.sem_group_by_gold(recs, node.langex, node.C,
                                             self.oracle, self.embedder, seed=s.seed)
        else:
            res = _groupby.sem_group_by_cascade(
                recs, node.langex, node.C, self.oracle, self.embedder,
                accuracy_target=node.accuracy_target,
                delta=node.delta if node.delta is not None else s.default_delta,
                sample_size=s.sample_size, seed=s.seed)
        self._log(res.stats, node, n_in=len(recs), n_out=len(recs))
        return [{**t, "group": int(g), "group_label": res.labels[int(g)]}
                for t, g in zip(recs, res.assignment)]

    # -- map family --------------------------------------------------------
    def _run_map(self, node: N.Map) -> list[dict]:
        recs = self.run(node.child)
        texts, stats = _mapex.sem_map(recs, node.langex, self.oracle)
        self._log(stats, node, n_in=len(recs), n_out=len(recs))
        return [{**t, node.out_column: x} for t, x in zip(recs, texts)]

    def _run_fusedmap(self, node: N.FusedMap) -> list[dict]:
        recs = self.run(node.child)
        columns, stats = _mapex.sem_map_fused(recs, node.langexes, self.oracle)
        self._log(stats, node, n_in=len(recs), n_out=len(recs))
        return [{**t, **{c: col[i] for c, col in zip(node.out_columns, columns)}}
                for i, t in enumerate(recs)]

    def _run_extract(self, node: N.Extract) -> list[dict]:
        recs = self.run(node.child)
        texts, stats = _mapex.sem_extract(recs, node.langex, self.oracle,
                                          source_field=node.source_field)
        self._log(stats, node, n_in=len(recs), n_out=len(recs))
        return [{**t, node.out_column: x} for t, x in zip(recs, texts)]

    # -- similarity family -------------------------------------------------
    def _run_search(self, node: N.Search) -> list[dict]:
        recs = self.run(node.child)
        index = node.index or self._corpus_index(
            node.child, [str(t[node.column]) for t in recs], node.column,
            kind=node.index_kind, nprobe=node.nprobe, shards=node.shards,
            quantize=node.quantize, index_auto=node.index_auto)
        # a shared stream index can be ahead of this run's pinned snapshot
        # (a commit landed mid-query): bound hits to the snapshot's rows
        cutoff = len(recs) \
            if isinstance(N.plain(node.child), N.StreamScan) else None
        hits, stats = _search.sem_search(
            index, node.query, self.embedder, k=node.k, n_rerank=node.n_rerank,
            rerank_model=self.oracle if node.n_rerank else None,
            records=recs, rerank_langex=node.rerank_langex, max_pos=cutoff)
        out = [recs[i] for i in hits if i < len(recs)]
        self._log(stats, node, n_in=len(recs), n_out=len(out))
        return out

    def _run_simjoin(self, node: N.SimJoin) -> list[dict]:
        left = self.run(node.left)
        right = self.run(node.right)
        index = self._corpus_index(node.right,
                                   [str(t[node.right_col]) for t in right],
                                   node.right_col, kind=node.index_kind,
                                   nprobe=node.nprobe, n_queries=len(left),
                                   shards=node.shards, quantize=node.quantize,
                                   index_auto=node.index_auto)
        cutoff = len(right) \
            if isinstance(N.plain(node.right), N.StreamScan) else None
        scores, idx, stats = _search.sem_sim_join(
            [str(t[node.left_col]) for t in left], index, self.embedder,
            k=node.k, max_pos=cutoff)
        out = self._simjoin_rows(left, right, scores, idx)
        self._log(stats, node, n_in=len(left), n_out=len(out))
        return out

    def _simjoin_rows(self, left, right, scores, idx) -> list[dict]:
        out = []
        for i, t in enumerate(left):
            for rank in range(idx.shape[1]):
                j = int(idx[i, rank])
                if j >= len(right) or scores[i, rank] <= MASKED_SCORE / 2:
                    continue  # beyond the pinned snapshot / unfilled slot
                out.append({**t, **{f"right_{kk}": v for kk, v in right[j].items()},
                            "sim_score": float(scores[i, rank])})
        return out


class PartitionedExecutor(PlanExecutor):
    """PlanExecutor that actually runs Exchange-bounded plan fragments.

    ``_run_exchange`` dispatches the merged operator to its partitioned
    implementation (``repro.core.plan.parallel`` / ``sem_topk_partitioned``)
    over the row partitions declared by the Partition node below it.  Every
    merge preserves the single-partition output — gold ops are row- or
    pair-tiled with unchanged prompts, cascades calibrate on one global
    importance sample, agg fragments align to reduction-tree subtrees, and
    top-k merges partition winners losslessly through a shared comparator —
    so a partitioned plan returns exactly what the base executor would.

    Fragments run serially without a pool, or concurrently on
    ``fragment_pool`` (the serving gateway shares one across sessions;
    ``fragment_workers`` > 1 instead creates a private pool — ``close()``
    releases it).  ``fragments_run`` / ``partitioned_ops`` feed the
    gateway's per-session metrics.
    """

    def __init__(self, session, *, fragment_pool=None,
                 fragment_workers: int = 0, **kw):
        super().__init__(session, **kw)
        self._own_pool = None
        if fragment_pool is None and fragment_workers > 1:
            fragment_pool = self._own_pool = ThreadPoolExecutor(
                max_workers=fragment_workers, thread_name_prefix="plan-frag")
        self._pool = fragment_pool
        self.fragments_run = 0
        self.partitioned_ops = 0

    def close(self, *, wait: bool = True) -> None:
        if self._own_pool is not None:
            self._own_pool.shutdown(wait=wait)
            self._own_pool = None
            self._pool = None

    def __del__(self):  # GC backstop for private pools; close() is the API
        try:
            self.close(wait=False)
        except Exception:  # pragma: no cover - interpreter shutdown
            pass

    def _count(self, n_fragments: int) -> None:
        self.fragments_run += n_fragments
        self.partitioned_ops += 1

    # -- dispatch ----------------------------------------------------------
    def _run_exchange(self, node: N.Exchange) -> list[dict]:
        if node.kind == "broadcast":
            # replication marker: rows are unchanged, distribution is the
            # consuming operator's business
            return self.run(node.child)
        child = node.child
        handler = {
            N.Filter: self._part_filter, N.Map: self._part_map,
            N.FusedMap: self._part_fusedmap, N.Extract: self._part_extract,
            N.TopK: self._part_topk, N.Agg: self._part_agg,
            N.Join: self._part_join, N.SimJoin: self._part_simjoin,
        }.get(type(child))
        if handler is None or not isinstance(self._part_source(child),
                                             N.Partition):
            return self.run(child)  # nothing partitioned below: fall through
        return handler(child)

    @staticmethod
    def _part_source(node) -> N.LogicalNode:
        """The child slot the optimizer partitions for this operator."""
        return node.left if isinstance(node, (N.Join, N.SimJoin)) else node.child

    def _split(self, records, part: N.Partition, *, fanout: int = 8):
        return parallel.split_partitions(records, part, fanout=fanout)

    # -- row-parallel family -----------------------------------------------
    def _part_filter(self, node: N.Filter) -> list[dict]:
        part = node.child
        recs = self.run(part.child)
        parts = self._split(recs, part)
        if not node.is_cascade:
            mask, stats = parallel.sem_filter_gold_partitioned(
                recs, node.langex, self.oracle, parts, self._pool)
        else:
            if self.proxy is None:
                raise ValueError(
                    "optimized sem_filter needs a proxy model in the Session")
            mask, stats = parallel.sem_filter_cascade_partitioned(
                recs, node.langex, self.oracle, self.proxy, parts, self._pool,
                **self._targets(node))
        self._count(len(parts))
        out = [t for t, m in zip(recs, mask) if m]
        self._log(stats, node, n_in=len(recs), n_out=len(out))
        return out

    def _part_map(self, node: N.Map) -> list[dict]:
        part = node.child
        recs = self.run(part.child)
        parts = self._split(recs, part)

        def frag(idx):
            texts, _ = _mapex.sem_map([recs[i] for i in idx], node.langex,
                                      self.oracle)
            return texts

        texts, stats = parallel.rows_partitioned("sem_map", parts, self._pool,
                                                 frag)
        self._count(len(parts))
        self._log(stats, node, n_in=len(recs), n_out=len(recs))
        return [{**t, node.out_column: x} for t, x in zip(recs, texts)]

    def _part_fusedmap(self, node: N.FusedMap) -> list[dict]:
        part = node.child
        recs = self.run(part.child)
        parts = self._split(recs, part)

        def frag(idx):
            columns, _ = _mapex.sem_map_fused([recs[i] for i in idx],
                                              node.langexes, self.oracle)
            return list(zip(*columns))  # per-row tuples across out columns

        rows, stats = parallel.rows_partitioned("sem_map_fused", parts,
                                                self._pool, frag)
        self._count(len(parts))
        self._log(stats, node, n_in=len(recs), n_out=len(recs))
        return [{**t, **dict(zip(node.out_columns, row))}
                for t, row in zip(recs, rows)]

    def _part_extract(self, node: N.Extract) -> list[dict]:
        part = node.child
        recs = self.run(part.child)
        parts = self._split(recs, part)

        def frag(idx):
            texts, _ = _mapex.sem_extract([recs[i] for i in idx], node.langex,
                                          self.oracle,
                                          source_field=node.source_field)
            return texts

        texts, stats = parallel.rows_partitioned("sem_extract", parts,
                                                 self._pool, frag)
        self._count(len(parts))
        self._log(stats, node, n_in=len(recs), n_out=len(recs))
        return [{**t, node.out_column: x} for t, x in zip(recs, texts)]

    # -- top-k ---------------------------------------------------------------
    def _part_topk(self, node: N.TopK) -> list[dict]:
        part = node.child
        recs = self.run(part.child)
        parts = self._split(recs, part)
        s = self.session
        pivot_scores = None
        if node.pivot_query is not None and self.embedder is not None:
            index = self._build_index([node.langex.render(t) for t in recs],
                                      kind="exact")
            qv = self.embedder.embed([node.pivot_query])
            pivot_scores = index.pairwise(qv)[0]
        idx, stats = _topk.sem_topk_partitioned(
            recs, node.langex, node.k, self.oracle,
            [list(map(int, p)) for p in parts], pivot_scores=pivot_scores,
            seed=s.seed, fragment_pool=self._pool)
        self._count(len(parts))
        self._log(stats, node, n_in=len(recs), n_out=len(idx))
        return [recs[i] for i in idx]

    # -- agg -----------------------------------------------------------------
    def _part_agg(self, node: N.Agg) -> list[dict]:
        part = node.child
        recs = self.run(part.child)
        if node.group_by is not None:
            parts = self._split(recs, part)
            rows, stats_list = parallel.sem_agg_groupby_partitioned(
                recs, node.langex, self.oracle, node.group_by, parts,
                self._pool, fanout=node.fanout, out_column=node.out_column)
            self._count(len(parts))
            for gi, stats in enumerate(stats_list):
                # observe the node once (first group) — per-group stats all
                # describe the same logical Agg over the same input rows
                if gi == 0:
                    self._log(stats, node, n_in=len(recs), n_out=len(rows))
                else:
                    self._log(stats)
            return rows
        parts = self._split(recs, part, fanout=node.fanout)
        answer, stats = parallel.sem_agg_partitioned(
            recs, node.langex, self.oracle, parts, self._pool,
            fanout=node.fanout)
        self._count(len(parts))
        self._log(stats, node, n_in=len(recs), n_out=1)
        return [{node.out_column: answer}]

    # -- join ----------------------------------------------------------------
    def _part_join(self, node: N.Join) -> list[dict]:
        lpart = node.left
        left = self.run(lpart.child)
        lparts = self._split(left, lpart)
        if isinstance(node.right, N.Partition):      # repartition grid
            right = self.run(node.right.child)
            rparts = self._split(right, node.right)
            exchange = "repartition"
        else:                                        # broadcast right
            right = self.run(node.right)
            rparts = [np.arange(len(right))]
            exchange = "broadcast"
        if node.prefilter_k:
            mask, stats = self._join_prefiltered_partitioned(
                node, left, right, lparts)
            n_frag = len(lparts)
        else:
            mask, stats = parallel.sem_join_gold_partitioned(
                left, right, node.langex, self.oracle, lparts, rparts,
                self._pool, exchange=exchange)
            n_frag = len(lparts) * len(rparts)
        self._count(n_frag)
        out = []
        n1, n2 = mask.shape
        for i in range(n1):
            for j in range(n2):
                if mask[i, j]:
                    out.append({**left[i],
                                **{f"right_{k}": v for k, v in right[j].items()}})
        self._log(stats, node, n_in=n1 * n2, n_out=len(out))
        return out

    def _join_prefiltered_partitioned(self, node: N.Join, left, right, lparts):
        """The optimizer-injected sim-prefilter join, fragment-parallel over
        left partitions: the right index is built once (registry-shared) and
        broadcast; each fragment embeds its probe rows, retrieves top-k
        candidates, and oracles its candidate pairs."""
        lx = node.langex
        with accounting.track("sem_join_prefiltered") as st:
            n1, n2 = len(left), len(right)
            k = min(node.prefilter_k, n2)
            lfields = [f for f in lx.fields if f.side != "right"]
            rfields = [f for f in lx.fields if f.side == "right"]
            right_index = self._build_index(
                _join._render_side(right, rfields), n_queries=n1)
            rendered_left = _join._render_side(left, lfields)

            def frag(pi, lidx):
                def task():
                    with accounting.track(f"fragment[{pi}]"):
                        emb = self.embedder.embed(
                            [rendered_left[int(i)] for i in lidx])
                        _, cand = right_index.search(emb, k)
                        pairs = [(int(i), int(j))
                                 for i, row in zip(lidx, cand) for j in row]
                        passed, _ = self.oracle.predicate(
                            _join._pair_prompts(lx, left, right, pairs))
                        return pairs, passed, dict(right_index.last_stats)
                return task

            results = parallel.run_fragments(
                self._pool, [frag(pi, lidx) for pi, lidx in enumerate(lparts)])
            mask = np.zeros((n1, n2), bool)
            n_pairs = 0
            scored = probed = 0
            for pairs, passed, idx_stats in results:
                n_pairs += len(pairs)
                scored += idx_stats.get("scored_vectors", 0)
                probed += idx_stats.get("probed_clusters", 0)
                for (i, j), p in zip(pairs, passed):
                    mask[i, j] = p
            st.details.update(prefilter_k=k, candidate_pairs=n_pairs,
                              pruned_pairs=n1 * n2 - n_pairs,
                              index=right_index.kind,
                              index_scored_vectors=scored,
                              index_probed_clusters=probed,
                              n_partitions=len(lparts),
                              exchange="broadcast")
            return mask, st.as_dict()

    # -- sim-join ------------------------------------------------------------
    def _part_simjoin(self, node: N.SimJoin) -> list[dict]:
        lpart = node.left
        left = self.run(lpart.child)
        lparts = self._split(left, lpart)
        right = self.run(node.right)  # broadcast marker or plain child
        index = self._corpus_index(node.right,
                                   [str(t[node.right_col]) for t in right],
                                   node.right_col, kind=node.index_kind,
                                   nprobe=node.nprobe, n_queries=len(left),
                                   shards=node.shards, quantize=node.quantize,
                                   index_auto=node.index_auto)
        cutoff = len(right) \
            if isinstance(N.plain(node.right), N.StreamScan) else None
        left_texts = [str(t[node.left_col]) for t in left]
        with accounting.track("sem_sim_join") as st:
            def frag(pi, lidx):
                def task():
                    with accounting.track(f"fragment[{pi}]"):
                        scores, jdx, _ = _search.sem_sim_join(
                            [left_texts[int(i)] for i in lidx], index,
                            self.embedder, k=node.k, max_pos=cutoff)
                        return scores, jdx, dict(index.last_stats)
                return task

            results = parallel.run_fragments(
                self._pool, [frag(pi, lidx) for pi, lidx in enumerate(lparts)])
            width = max((r[1].shape[1] for r in results), default=node.k)
            scores = np.full((len(left), width), MASKED_SCORE, np.float32)
            idx = np.zeros((len(left), width), np.int64)
            scored = probed = 0
            for lidx, (s, j, idx_stats) in zip(lparts, results):
                scores[lidx, :s.shape[1]] = s
                idx[lidx, :j.shape[1]] = j
                scored += idx_stats.get("scored_vectors", 0)
                probed += idx_stats.get("probed_clusters", 0)
            st.details.update(index=index.kind, scored_vectors=scored,
                              probed_clusters=probed,
                              n_partitions=len(lparts))
            stats = st.as_dict()
        self._count(len(lparts))
        out = self._simjoin_rows(left, right, scores, idx)
        self._log(stats, node, n_in=len(left), n_out=len(out))
        return out
