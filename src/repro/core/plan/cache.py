"""BatchedModelCache: prompt-level dedup + LRU memoization over a model.

Layered on ``CountedModel`` so accounting only sees the prompts that actually
reach the backend: within one batched call, duplicate prompts are coalesced
to a single backend row; across pipeline stages, previously answered prompts
are served from the LRU (recorded as ``cache_hits`` in the active OpStats).
This is what makes a repeated predicate — e.g. a filter re-checked after a
join, or overlapping cascade sample/mid-region prompts — never pay twice
inside one optimized pipeline.

The wrapper is protocol-compatible with ``GenerativeModel``, so every
operator implementation works against it unchanged.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core import accounting


class BatchedModelCache:
    def __init__(self, model, *, capacity: int = 100_000):
        self._m = model
        self.capacity = capacity
        self._lru: OrderedDict[tuple, object] = OrderedDict()
        self.hits = 0
        self.misses = 0

    # -- plumbing ----------------------------------------------------------
    @property
    def role(self) -> str:  # CountedModel compat (introspection / logging)
        return getattr(self._m, "role", "model")

    def _get(self, key):
        self._lru.move_to_end(key)
        return self._lru[key]

    def _put(self, key, value) -> None:
        self._lru[key] = value
        if len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    def _through(self, kind: str, prompts: Sequence[str], call, *,
                 extra_key: tuple = ()):
        """Dedup ``prompts`` against the LRU and within the batch, answer the
        misses with one backend ``call``, and reassemble per-prompt rows.

        Reassembly reads from a batch-local row map, not the LRU: one batch
        may be larger than the cache capacity, in which case inserting the
        tail of the batch evicts its own head."""
        keys = [(kind, *extra_key, p) for p in prompts]
        batch_rows: dict[tuple, object] = {}
        todo: list[tuple] = []
        todo_prompts: list[str] = []
        for key, p in zip(keys, prompts):
            if key in batch_rows:
                continue
            if key in self._lru:
                batch_rows[key] = self._get(key)
            else:
                batch_rows[key] = None  # placeholder marks in-batch dedup
                todo.append(key)
                todo_prompts.append(p)
        if todo_prompts:
            rows = call(todo_prompts)
            for key, row in zip(todo, rows):
                batch_rows[key] = row
                self._put(key, row)
        n_hit = len(prompts) - len(todo_prompts)
        self.hits += n_hit
        self.misses += len(todo_prompts)
        accounting.record("cache_hit", n_hit)
        return [batch_rows[k] for k in keys]

    # -- GenerativeModel protocol -----------------------------------------
    def predicate(self, prompts):
        rows = self._through(
            "predicate", prompts,
            lambda ps: list(zip(*(np.asarray(a).tolist()
                                  for a in self._m.predicate(ps)))))
        passed = np.asarray([r[0] for r in rows], bool)
        scores = np.asarray([r[1] for r in rows], np.float32)
        return passed, scores

    def generate(self, prompts):
        return list(self._through("generate", prompts,
                                  lambda ps: list(self._m.generate(ps))))

    def compare(self, prompts):
        rows = self._through("compare", prompts,
                             lambda ps: np.asarray(self._m.compare(ps)).tolist())
        return np.asarray(rows, bool)

    def choose(self, prompts, n_options):
        rows = self._through(
            "choose", prompts,
            lambda ps: np.asarray(self._m.choose(ps, n_options)).tolist(),
            extra_key=(n_options,))
        return np.asarray(rows, int)
