"""BatchedModelCache: prompt-level dedup + memoization over a model.

Layered on ``CountedModel`` so accounting only sees the prompts that actually
reach the backend: within one batched call, duplicate prompts are coalesced
to a single backend row; across pipeline stages, previously answered prompts
are served from the cache (recorded as ``cache_hits`` in the active OpStats).
This is what makes a repeated predicate — e.g. a filter re-checked after a
join, or overlapping cascade sample/mid-region prompts — never pay twice
inside one optimized pipeline.

Two storage modes:

  * **private** (default): an in-wrapper LRU ``OrderedDict`` bounded by
    ``capacity`` — the single-query ``LazySemFrame.collect()`` path;
  * **shared**: pass ``store=`` a ``repro.serve.store.SharedSemanticCache``
    (or anything with its ``get_many``/``put_many`` protocol) and a
    ``namespace`` (model role) — the serving-gateway path, where one
    process-wide store with TTL/eviction/persistence is consulted by every
    session's wrapper, so a predicate answered by *any* query is a hit for
    all of them.  ``requester`` tags this wrapper's session for the store's
    cross-query-hit attribution.

The wrapper is protocol-compatible with ``GenerativeModel``, so every
operator implementation works against it unchanged.

Thread safety: one wrapper may be hit concurrently by a partitioned
operator's fragment threads, so the private LRU and the hit/miss counters
are lock-guarded.  The backend call itself runs outside the lock — two
fragments missing the same prompt may both pay it (the answers are
identical; the duplicate is bounded by the race window), which is the
standard cache-stampede trade against serializing all fragments.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core import accounting
from repro.obs import trace as _trace


class BatchedModelCache:
    def __init__(self, model, *, capacity: int = 100_000, store=None,
                 namespace: str | None = None, requester: str | None = None):
        self._m = model
        self.capacity = capacity
        self._store = store
        self._ns = (namespace or getattr(model, "role", "model"),) \
            if store is not None else ()
        self._requester = requester
        self._lru: OrderedDict[tuple, object] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    # -- plumbing ----------------------------------------------------------
    @property
    def role(self) -> str:  # CountedModel compat (introspection / logging)
        return getattr(self._m, "role", "model")

    def _lookup(self, keys: list[tuple]) -> list[tuple]:
        """-> [(found, row)] per key, from the shared store or the LRU."""
        if self._store is not None:
            return self._store.get_many(keys, requester=self._requester)
        with self._lock:
            out = []
            for key in keys:
                if key in self._lru:
                    self._lru.move_to_end(key)
                    out.append((True, self._lru[key]))
                else:
                    out.append((False, None))
            return out

    def _insert(self, keys: list[tuple], rows: list) -> None:
        if self._store is not None:
            self._store.put_many(keys, rows, owner=self._requester)
            return
        with self._lock:
            for key, row in zip(keys, rows):
                self._lru[key] = row
                if len(self._lru) > self.capacity:
                    self._lru.popitem(last=False)

    def _through(self, kind: str, prompts: Sequence[str], call, *,
                 extra_key: tuple = ()):
        """Dedup ``prompts`` against the cache and within the batch, answer
        the misses with one backend ``call``, and reassemble per-prompt rows.

        Reassembly reads from a batch-local row map, not the backing store:
        one batch may be larger than the cache capacity, in which case
        inserting the tail of the batch evicts its own head."""
        sp = _trace.NOOP_SPAN
        if _trace.current_tracer() is not None:
            # one lookup span per batched cache consult (not per prompt)
            role = self._ns[0] if self._ns else "private"
            sp_cm = _trace.span(f"cache/{role}.{kind}", kind="cache_lookup",
                                prompts=len(prompts))
            sp = sp_cm.__enter__()
        else:
            sp_cm = None
        try:
            return self._through_inner(kind, prompts, call,
                                       extra_key=extra_key, sp=sp)
        finally:
            if sp_cm is not None:
                sp_cm.__exit__(None, None, None)

    def _through_inner(self, kind: str, prompts: Sequence[str], call, *,
                       extra_key: tuple = (), sp=_trace.NOOP_SPAN):
        keys = [(*self._ns, kind, *extra_key, p) for p in prompts]
        batch_rows: dict[tuple, object] = {}
        fresh: list[tuple[tuple, str]] = []
        for key, p in zip(keys, prompts):
            if key not in batch_rows:
                batch_rows[key] = None  # placeholder marks in-batch dedup
                fresh.append((key, p))
        found = self._lookup([k for k, _ in fresh])
        todo = [(k, p) for (k, p), (hit, _) in zip(fresh, found) if not hit]
        for (k, _), (hit, row) in zip(fresh, found):
            if hit:
                batch_rows[k] = row
        if todo:
            rows = call([p for _, p in todo])
            for (key, _), row in zip(todo, rows):
                batch_rows[key] = row
            self._insert([k for k, _ in todo], list(rows))
        n_hit = len(prompts) - len(todo)
        sp.set(hits=n_hit, misses=len(todo))
        with self._lock:
            self.hits += n_hit
            self.misses += len(todo)
        accounting.record("cache_hit", n_hit)
        return [batch_rows[k] for k in keys]

    # -- GenerativeModel protocol -----------------------------------------
    def predicate(self, prompts):
        rows = self._through(
            "predicate", prompts,
            lambda ps: list(zip(*(np.asarray(a).tolist()
                                  for a in self._m.predicate(ps)))))
        passed = np.asarray([r[0] for r in rows], bool)
        scores = np.asarray([r[1] for r in rows], np.float32)
        return passed, scores

    def generate(self, prompts):
        return list(self._through("generate", prompts,
                                  lambda ps: list(self._m.generate(ps))))

    def compare(self, prompts):
        rows = self._through("compare", prompts,
                             lambda ps: np.asarray(self._m.compare(ps)).tolist())
        return np.asarray(rows, bool)

    def choose(self, prompts, n_options):
        rows = self._through(
            "choose", prompts,
            lambda ps: np.asarray(self._m.choose(ps, n_options)).tolist(),
            extra_key=(n_options,))
        return np.asarray(rows, int)
