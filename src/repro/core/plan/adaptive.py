"""Mid-query re-optimization: the adaptive plan executor.

The optimizer prices a plan once, from a static importance sample plus
whatever the :class:`~repro.obs.stats_store.StatsStore` remembers.  This
module closes the loop *inside* a running query: at Exchange and stage-hook
boundaries the :class:`AdaptivePlanExecutor` compares observed against
predicted cardinality/selectivity for completed nodes and re-costs the
remaining subplan —

* **filter chains** run greedily: after every filter the surviving gold
  filters are re-ranked by live blended cost x selectivity (the plan-time
  estimate shrunk toward the store's EWMA), so a predicate whose observed
  selectivity drifted from the costing sample is promoted or demoted
  mid-chain;
* **retrieval** re-chooses exact vs IVF vs int8 tiles when the observed
  corpus size drifts past the threshold from the cardinality estimate rule
  5 priced — only for ``index_auto`` nodes, never for user pins;
* **partition fragments** are re-sized on observed row counts with exactly
  the planner's sizing rule (``parallel.partition_count``), so a filter that
  killed most rows doesn't fan 12 fragments over 40 survivors.

Equivalence contract (the strict mode every re-plan obeys): gold filters
commute — per-row prompts and a conjunction — so reordering them is
record-identical.  Cascade filters calibrate tau on their *input set*, so
they are immovable barriers: the choosable segment is the leading run of
gold filters, and a cascade at the head always executes next.  Retrieval
switches stay inside the recall contract (the same class of change rule 5
makes at plan time), and contiguous fragment resizes are bit-identical by
the PR-5 partitioned-operator construction (one global importance sample,
unchanged prompts).  ``replans`` records every decision for
``explain_analyze``.

``REPRO_ADAPTIVE=1`` flips the default on (CI runs tier-1 once this way to
catch plan-divergence regressions).
"""
from __future__ import annotations

import dataclasses
import os

from repro.core.operators import filter as _filter
from repro.core.plan import nodes as N
from repro.core.plan import parallel
from repro.core.plan.execute import PartitionedExecutor
from repro.core.plan.optimize import (DEFAULT_FILTER_SEL, CASCADE_FILTER_COST,
                                      GOLD_FILTER_COST, estimate_cardinality,
                                      shrinkage_blend)
from repro.index.backend import (IVF_MIN_CORPUS, QUANT_MIN_CORPUS,
                                 choose_retrieval_config)
from repro.obs import trace as _trace


def adaptive_default() -> bool:
    """Process-wide default for adaptivity (the ``REPRO_ADAPTIVE`` env
    switch CI uses to run the whole suite adaptively)."""
    return os.environ.get("REPRO_ADAPTIVE", "").strip().lower() \
        not in ("", "0", "false")


def drift_ratio(pred: float, obs: float) -> float:
    """Symmetric drift between a prediction and an observation (>= 1).
    Unlike the row-count variant in ``obs.analyze``, the floor is tiny —
    selectivities live in [0, 1] and a 0.02 vs 0.2 miss must register."""
    lo, hi = sorted((max(float(pred), 0.0), max(float(obs), 0.0)))
    return hi / max(lo, 1e-9)


@dataclasses.dataclass
class AdaptivePolicy:
    """Knobs for mid-query re-optimization.  The defaults re-plan only on
    clear drift and never touch guarantee-bearing structure."""

    drift_threshold: float = 1.75  # re-cost when obs/pred crosses this
    min_rows: int = 8              # below this, re-planning can't pay off
    reorder_filters: bool = True
    switch_retrieval: bool = True
    resize_fragments: bool = True
    prior_strength: float = 4.0    # shrinkage mass for live store blends


@dataclasses.dataclass
class ReplanEvent:
    """One mid-query decision, for metrics and ``explain_analyze``."""

    kind: str    # "reorder_filters" | "switch_retrieval" | "resize_fragments"
                 # | "switch_join_strategy" | "drift"
    node: str    # label of the node the decision was about
    reason: str


class AdaptivePlanExecutor(PartitionedExecutor):
    """PartitionedExecutor that re-costs the remaining subplan as
    observations come in (see module docstring for the equivalence
    contract).  ``optimizer`` is bound after construction by the frame /
    gateway so re-plans reuse the planner's own knobs (partition counts,
    quantization policy) instead of shadowing them."""

    def __init__(self, session, *, policy: AdaptivePolicy | None = None, **kw):
        super().__init__(session, **kw)
        self.policy = policy if policy is not None else AdaptivePolicy()
        self.optimizer = None
        self.replans: list[ReplanEvent] = []

    def _knob(self, name: str, default=None):
        v = getattr(self.optimizer, name, None) \
            if self.optimizer is not None else None
        return v if v is not None else default

    def _replan(self, kind: str, node, reason: str) -> None:
        label = node.label() if hasattr(node, "label") else str(node)
        self.replans.append(ReplanEvent(kind, label, reason))
        sp = _trace.current_span()
        if sp is not None and sp.kind == "plan_stage":
            prev = sp.attrs.get("replanned")
            note = f"{kind}: {reason}"
            sp.set(replanned=f"{prev}; {note}" if prev else note)

    # -- live cost estimates ----------------------------------------------
    def _filter_sel(self, f: N.Filter) -> float:
        prior = f.selectivity if f.selectivity is not None \
            else DEFAULT_FILTER_SEL
        if self.stats_store is not None:
            obs = self.stats_store.stats_for_node(f)
            if obs is not None and obs.selectivity is not None:
                return shrinkage_blend(prior, obs.selectivity, obs.runs,
                                       self.policy.prior_strength)
        return prior

    def _filter_cost(self, f: N.Filter) -> float:
        unit = CASCADE_FILTER_COST if f.is_cascade else GOLD_FILTER_COST
        if self.stats_store is not None:
            obs = self.stats_store.stats_for_node(f)
            if obs is not None and obs.rows_in > 0:
                return shrinkage_blend(unit, obs.oracle_calls_per_row,
                                       obs.runs, self.policy.prior_strength)
        return unit

    # -- filter chains: greedy re-ranked execution ------------------------
    def _collect_chain(self, node):
        """Walk the consecutive filters below ``node`` (each possibly in its
        own Exchange/Partition sandwich from rule 6).  Returns
        (top-down [(filter, partition-or-None)], base)."""
        chain: list[tuple[N.Filter, N.Partition | None]] = []
        cur = node
        while True:
            if (isinstance(cur, N.Exchange) and cur.kind == "gather"
                    and isinstance(cur.child, N.Filter)
                    and isinstance(cur.child.child, N.Partition)):
                f = cur.child
                chain.append((f, f.child))
                cur = f.child.child
            elif isinstance(cur, N.Filter):
                chain.append((cur, None))
                cur = cur.child
            else:
                return chain, cur

    def _run_exchange(self, node: N.Exchange) -> list[dict]:
        if self.policy.reorder_filters:
            chain, base = self._collect_chain(node)
            if len(chain) >= 2:
                return self._run_filter_chain(chain, base)
        return super()._run_exchange(node)

    def _run_filter(self, node: N.Filter) -> list[dict]:
        if self.policy.reorder_filters:
            chain, base = self._collect_chain(node)
            if len(chain) >= 2:
                return self._run_filter_chain(chain, base)
        return super()._run_filter(node)

    def _pick_next(self, pending) -> int:
        """Index of the filter to execute next.  Strict mode: a cascade
        calibrates tau on its input set, so a cascade at the head must run
        (and none may be jumped over); gold filters permute within the
        leading gold segment by ascending blended cost / (1 - sel).  The
        tie-break is the planned order, so with no new evidence the greedy
        pass replays the static plan exactly."""
        if pending[0][0].is_cascade:
            return 0
        best, best_rank = 0, None
        for j, (f, _) in enumerate(pending):
            if f.is_cascade:
                break
            rank = self._filter_cost(f) / max(1.0 - self._filter_sel(f), 1e-6)
            if best_rank is None or rank < best_rank - 1e-12:
                best, best_rank = j, rank
        return best

    def _run_filter_chain(self, chain, base) -> list[dict]:
        rows = self.run(base)
        pending = list(reversed(chain))  # planned (bottom-up) order
        while pending:
            i = self._pick_next(pending)
            f, part = pending.pop(i)
            reason = None
            if i != 0:
                reason = (f"promoted over {i} planned filter(s): blended "
                          f"sel~{self._filter_sel(f):.2f} ranks cheapest "
                          f"of the gold segment")
            n_in = len(rows)
            rows = self._apply_filter(f, part, rows, reason=reason)
            if pending and n_in:
                pred = f.selectivity if f.selectivity is not None \
                    else DEFAULT_FILTER_SEL
                obs = len(rows) / n_in
                r = drift_ratio(pred, obs)
                if r > self.policy.drift_threshold:
                    self._replan(
                        "drift", f,
                        f"observed sel {obs:.2f} vs predicted {pred:.2f} "
                        f"(x{r:.1f}); re-costing {len(pending)} remaining "
                        f"filter(s)")
        return rows

    def _apply_filter(self, f: N.Filter, part, rows, *, reason=None):
        if _trace.current_tracer() is None:
            if reason:
                self._replan("reorder_filters", f, reason)
            return self._filter_body(f, part, rows)
        # the chain executes under the top node's span: give each filter its
        # own plan_stage span so explain_analyze still joins per-node
        with _trace.span(type(f).__name__, kind="plan_stage",
                         label=f.label(), node_id=id(f)) as sp:
            if reason:
                self._replan("reorder_filters", f, reason)
            out = self._filter_body(f, part, rows)
            sp.set(rows_out=len(out))
            return out

    def _filter_body(self, f: N.Filter, part, rows) -> list[dict]:
        parts = self._split(rows, part) if part is not None else None
        if f.is_cascade and self.proxy is None:
            raise ValueError(
                "optimized sem_filter needs a proxy model in the Session")
        if parts is not None and len(parts) >= 2:
            if not f.is_cascade:
                mask, stats = parallel.sem_filter_gold_partitioned(
                    rows, f.langex, self.oracle, parts, self._pool)
            else:
                mask, stats = parallel.sem_filter_cascade_partitioned(
                    rows, f.langex, self.oracle, self.proxy, parts,
                    self._pool, **self._targets(f))
            self._count(len(parts))
        elif not f.is_cascade:
            mask, stats = _filter.sem_filter_gold(rows, f.langex, self.oracle)
        else:
            mask, stats = _filter.sem_filter_cascade(
                rows, f.langex, self.oracle, self.proxy, **self._targets(f))
        out = [t for t, m in zip(rows, mask) if m]
        self._log(stats, f, n_in=len(rows), n_out=len(out))
        return out

    # -- fragment resizing on observed cardinality -------------------------
    def _split(self, records, part: N.Partition, *, fanout: int = 8):
        if self.policy.resize_fragments and part.strategy == "contiguous":
            configured = self._knob("n_partitions") or part.n_partitions
            P = parallel.partition_count(
                len(records), configured, self._knob("partition_min_rows", 32))
            if P != part.n_partitions:
                self._replan(
                    "resize_fragments", part,
                    f"{part.n_partitions} -> {P} fragments for "
                    f"{len(records)} observed rows")
                part = dataclasses.replace(part, n_partitions=P)
        return super()._split(records, part, fanout=fanout)

    # -- join strategy re-choice on observed cardinalities -----------------
    def _join_dispatch(self, node: N.Join, left, right):
        """Re-resolve an optimizer-chosen join strategy when the observed
        pair grid drifts past the threshold from what rule 4b priced.  Only
        ``strategy_auto`` nodes re-choose — a user pin stays fixed — and the
        switch is the same class of change the optimizer makes at plan time
        (both sides honor the node's (recall, precision, delta) targets)."""
        if (node.strategy_auto and node.strategy in ("block", "cascade")
                and len(left) >= self.policy.min_rows):
            from repro.core.plan.optimize import resolve_join_strategy
            n1_est = estimate_cardinality(N.plain(node.left))
            n2_est = estimate_cardinality(N.plain(node.right))
            pairs_est = max(n1_est * n2_est, 1.0)
            pairs_obs = max(len(left) * len(right), 1)
            if drift_ratio(pairs_est, pairs_obs) > self.policy.drift_threshold:
                chosen = resolve_join_strategy(len(left), len(right))
                if chosen != node.strategy:
                    self._replan(
                        "switch_join_strategy", node,
                        f"pair grid est ~{pairs_est:.0f} vs {pairs_obs} "
                        f"observed: {node.strategy} -> {chosen}")
                    node = dataclasses.replace(node, strategy=chosen)
        return super()._join_dispatch(node, left, right)

    # -- retrieval switching on observed corpus size -----------------------
    def _corpus_index(self, child, texts, column, *, kind="auto", nprobe=None,
                      n_queries=1, shards=None, quantize=None,
                      index_auto=False):
        if (self.policy.switch_retrieval and index_auto and kind != "auto"
                and len(texts) >= self.policy.min_rows):
            n_est = estimate_cardinality(N.plain(child))
            if drift_ratio(n_est, len(texts)) > self.policy.drift_threshold:
                cfg = choose_retrieval_config(
                    len(texts), max(int(n_queries), 1),
                    recall_target=self.recall_target,
                    min_corpus=self.index_min_corpus or IVF_MIN_CORPUS,
                    shared=self.index_registry is not None,
                    quantize=self._knob("quantize", "auto"),
                    min_quant_corpus=self._knob("quant_min_corpus",
                                                QUANT_MIN_CORPUS))
                if (cfg["kind"], cfg["quantize"]) != (kind, quantize):
                    self._replan(
                        "switch_retrieval", N.plain(child),
                        f"corpus est ~{n_est:.0f} rows vs {len(texts)} "
                        f"observed: {kind}/{quantize or 'none'} -> "
                        f"{cfg['kind']}/{cfg['quantize'] or 'none'}")
                    kind, quantize = cfg["kind"], cfg["quantize"]
                    # same stream-corpus rule as the planner: never pin a
                    # size-derived nprobe into a versioned registry key
                    nprobe = None \
                        if isinstance(N.plain(child), N.StreamScan) \
                        else cfg["nprobe"]
        return super()._corpus_index(child, texts, column, kind=kind,
                                     nprobe=nprobe, n_queries=n_queries,
                                     shards=shards, quantize=quantize,
                                     index_auto=index_auto)
