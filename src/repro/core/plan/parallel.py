"""Partitioned physical operators + fragment scheduling.

This is the execution side of the plan layer's ``Partition``/``Exchange``
nodes: every function here runs one operator over row partitions and merges
with semantics that *provably preserve* the single-partition output:

  * gold filter / map family — row-parallel fragments, gather = positional
    concat (prompts are per-row, so fragment outputs are the global outputs);
  * cascade filter — the proxy scores and the mid-region oracle labels are
    produced by per-partition fragments, but the importance sample, the
    learned (tau+, tau-) thresholds, and the decision rule stay GLOBAL: the
    cascade sees exactly the score vector and sample labels of the
    unpartitioned run, so thresholds — and the statistical guarantee — are
    bit-identical;
  * hierarchical agg — fragment boundaries align to the reduction tree's
    root subtrees (``fanout ** (depth-1)`` leaves each), so partition-local
    reduces are exactly the root's child subtrees and the one root reduce
    reproduces the unpartitioned tree prompt-for-prompt;
  * gold join — fragments tile the (left x right) pair space (broadcast:
    left partitions x full right; repartition: a fragment grid); each pair's
    prompt is unchanged, so the merged mask is the gold mask.

Top-k's per-partition quickselect + lossless merge lives with its algorithm
in ``repro.core.operators.topk`` (``sem_topk_partitioned``).

``run_fragments`` is the scheduler seam: tasks run serially without a pool
(deterministic library mode) or concurrently on the caller's
``ThreadPoolExecutor`` (the serving gateway shares one across sessions).
Each fragment re-installs the coordinating thread's accounting context
(``accounting.capture``/``activate``) so per-partition model calls roll up
into the same operator block and serve-session scope.
"""
from __future__ import annotations

import numpy as np

from repro.core import accounting
from repro.core.langex import as_langex
from repro.obs import audit as _audit
from repro.obs import trace as _trace
from repro.core.operators.agg import _agg_prompt
from repro.core.operators.filter import predicate_prompt
from repro.core.operators.join import _pair_prompts
from repro.core.optimizer import cascades
from repro.core.plan import nodes as N


# ---------------------------------------------------------------------------
# Fragment scheduling
# ---------------------------------------------------------------------------


def run_fragments(pool, tasks):
    """Run ``tasks`` (thunks) and return their results in order.

    ``pool=None`` runs serially on the calling thread.  With a pool, every
    task is wrapped to carry the submitting thread's accounting context so
    fragment model calls are attributed exactly like serial ones."""
    tasks = list(tasks)
    # annotate the owning operator span with the fan-out shape (fragment
    # spans themselves come from the per-fragment ``accounting.track``)
    sp = _trace.current_span()
    if sp is not None:
        sp.set(n_fragments=len(tasks),
               fragment_pooled=pool is not None and len(tasks) > 1)
    if pool is None or len(tasks) <= 1:
        return [t() for t in tasks]
    ctx = accounting.capture()

    def wrap(task):
        def run():
            with accounting.activate(ctx):
                return task()
        return run

    futures = [pool.submit(wrap(t)) for t in tasks]
    return [f.result() for f in futures]


# ---------------------------------------------------------------------------
# Partition splitters
# ---------------------------------------------------------------------------


def contiguous_partitions(n: int, n_partitions: int) -> list[np.ndarray]:
    """Near-equal contiguous index ranges (first ``n % P`` get the extra)."""
    P = max(1, min(n_partitions, n)) if n else 1
    bounds = np.linspace(0, n, P + 1).astype(int)
    return [np.arange(lo, hi) for lo, hi in zip(bounds, bounds[1:])]


def partition_count(n_rows: float, configured: int | None,
                    min_rows: int) -> int:
    """Fragments for an operator over ``n_rows`` rows: the configured count,
    gated by ``min_rows`` and capped so no fragment is empty.  One
    definition shared by the optimizer (estimated rows, plan time) and the
    adaptive executor (observed rows, run time), so a mid-query fragment
    resize is exactly the partitioning the planner would have chosen had it
    known the true cardinality — bit-identical output either way (the
    contiguous gather is a positional concat)."""
    if not configured or configured < 2 or n_rows < min_rows:
        return 1
    return max(1, min(int(configured), int(n_rows)))


def hash_partitions(records, n_partitions: int, key: str) -> list[np.ndarray]:
    """Rows bucketed by the group key's *equality class* (built-in ``hash``,
    under which 1, 1.0 and True coincide exactly as they do in the
    unpartitioned group dict) — every group lands whole in one partition,
    original order kept within each.  Assignment is stable within a process
    (string hashing is interpreter-seeded), which is all the
    partitioned-equals-unpartitioned contract needs.  Partitions may be
    empty."""
    P = max(1, n_partitions)
    buckets: list[list[int]] = [[] for _ in range(P)]
    for i, t in enumerate(records):
        buckets[hash(t[key]) % P].append(i)
    return [np.asarray(b, int) for b in buckets]


def range_partitions(records, n_partitions: int, key: str) -> list[np.ndarray]:
    """Rows sorted by ``record[key]`` then cut into contiguous runs: order
    statistics over the key stay partition-local.  Sorts on the native key
    value (numeric keys order numerically, not lexicographically), falling
    back to string order only for un-comparable mixed types.  No optimizer
    rule emits this strategy yet — it is IR surface for hand-built plans
    and future range-aware rewrites."""
    try:
        order = sorted(range(len(records)), key=lambda i: records[i][key])
    except TypeError:  # mixed/unorderable key types
        order = sorted(range(len(records)), key=lambda i: str(records[i][key]))
    parts = contiguous_partitions(len(records), n_partitions)
    order = np.asarray(order, int)
    return [order[p] for p in parts]


def subtree_partitions(n: int, fanout: int, n_partitions: int
                       ) -> list[np.ndarray]:
    """Contiguous ranges aligned to the hierarchical-reduce tree: with
    ``depth = ceil(log_fanout n)`` levels, each partition takes
    ``fanout ** (depth-1)`` consecutive leaves — exactly the leaves of one
    child subtree of the root, so partition-local reduces compose into the
    unpartitioned tree verbatim.  ``n_partitions`` caps nothing here (the
    alignment fixes the count, always <= fanout); it is accepted for
    interface symmetry."""
    del n_partitions
    if n <= 0:
        return [np.arange(0)]
    f = max(fanout, 2)
    if n <= f:  # single root group: the whole reduce is one prompt already
        return [np.arange(n)]
    depth = 1
    while f ** depth < n:
        depth += 1
    chunk = f ** (depth - 1)
    return [np.arange(lo, min(lo + chunk, n)) for lo in range(0, n, chunk)]


def split_partitions(records, part: "N.Partition", *,
                     fanout: int = 8) -> list[np.ndarray]:
    """Materialize a Partition node's strategy into index arrays."""
    if part.strategy == "contiguous":
        return contiguous_partitions(len(records), part.n_partitions)
    if part.strategy == "hash":
        return hash_partitions(records, part.n_partitions, part.key)
    if part.strategy == "range":
        return range_partitions(records, part.n_partitions, part.key)
    if part.strategy == "subtree":
        return subtree_partitions(len(records), fanout, part.n_partitions)
    raise ValueError(f"unknown partition strategy {part.strategy!r}")


def _fragment_sizes(parts) -> list[int]:
    return [int(len(p)) for p in parts]


# ---------------------------------------------------------------------------
# Filter
# ---------------------------------------------------------------------------


def sem_filter_gold_partitioned(records, langex, oracle, parts, pool
                                ) -> tuple[np.ndarray, dict]:
    """Row-parallel gold filter: one oracle fragment per partition; the
    gathered mask is positionally identical to the unpartitioned scan."""
    lx = as_langex(langex)
    with accounting.track("sem_filter_gold") as st:
        def frag(pi, idx):
            def task():
                with accounting.track(f"fragment[{pi}]"):
                    passed, _ = oracle.predicate(
                        [predicate_prompt(lx, records[i]) for i in idx])
                    return np.asarray(passed, bool)
            return task

        results = run_fragments(pool, [frag(pi, idx)
                                       for pi, idx in enumerate(parts)])
        mask = np.zeros(len(records), bool)
        for idx, sub in zip(parts, results):
            mask[idx] = sub
        st.details.update(n_partitions=len(parts),
                          partition_sizes=_fragment_sizes(parts))
        return mask, st.as_dict()


def sem_filter_cascade_partitioned(records, langex, oracle, proxy, parts,
                                   pool, *, recall_target: float = 0.9,
                                   precision_target: float = 0.9,
                                   delta: float = 0.2, sample_size: int = 100,
                                   seed: int = 0) -> tuple[np.ndarray, dict]:
    """Partitioned Algorithm 1 with the calibration kept global.

    Fragments do the *scoring work* (proxy pass, mid-region oracle labels)
    partition-locally, but the importance sample is drawn over the full
    score vector with the same seed and the thresholds are learned from the
    same sample labels as the unpartitioned run — so ``tau_plus`` /
    ``tau_minus``, the accept/reject/mid regions, and the returned pass-set
    are identical, and the (recall, precision, delta) guarantee carries
    over unchanged."""
    lx = as_langex(langex)
    n = len(records)
    owner = np.zeros(n, int)
    for pi, idx in enumerate(parts):
        owner[idx] = pi
    with accounting.track("sem_filter") as st:
        prompts = [predicate_prompt(lx, t) for t in records]

        def score_frag(pi, idx):
            def task():
                with accounting.track(f"fragment[{pi}]"):
                    _, s = proxy.predicate([prompts[i] for i in idx])
                    return np.asarray(s, float)
            return task

        scores = np.zeros(n, float)
        for idx, s in zip(parts, run_fragments(
                pool, [score_frag(pi, idx) for pi, idx in enumerate(parts)])):
            scores[idx] = s

        def oracle_fn(indices):
            indices = np.asarray(indices, int)
            by_part: dict[int, list[int]] = {}
            for pos, i in enumerate(indices):
                by_part.setdefault(int(owner[i]), []).append(pos)

            def label_frag(pi, positions):
                def task():
                    with accounting.track(f"fragment[{pi}]"):
                        passed, _ = oracle.predicate(
                            [prompts[indices[p]] for p in positions])
                        return np.asarray(passed, bool)
                return task

            out = np.zeros(len(indices), bool)
            results = run_fragments(
                pool, [label_frag(pi, pos) for pi, pos in
                       sorted(by_part.items())])
            for (_, positions), labels in zip(sorted(by_part.items()), results):
                out[positions] = labels
            return out

        res = cascades.run_cascade(
            scores, oracle_fn, recall_target=recall_target,
            precision_target=precision_target, delta=delta,
            sample_size=sample_size, seed=seed)
        _audit.emit_cascade("Filter", lx.template, res,
                            lambda idx: [prompts[i] for i in idx],
                            recall_target=recall_target,
                            precision_target=precision_target)
        st.details.update(tau_plus=res.tau_plus, tau_minus=res.tau_minus,
                          oracle_calls_cascade=res.oracle_calls,
                          auto_accepted=res.auto_accepted,
                          auto_rejected=res.auto_rejected,
                          oracle_region=res.oracle_region,
                          n_partitions=len(parts),
                          partition_sizes=_fragment_sizes(parts))
        return res.passed, st.as_dict()


# ---------------------------------------------------------------------------
# Map family
# ---------------------------------------------------------------------------


def rows_partitioned(op_name: str, parts, pool, frag_fn) -> tuple[list, dict]:
    """Generic row-parallel runner: ``frag_fn(idx) -> per-row outputs`` for
    one partition; outputs are gathered back into global row order.
    Returns (outputs aligned to the input rows, stats)."""
    with accounting.track(op_name) as st:
        def frag(pi, idx):
            def task():
                with accounting.track(f"fragment[{pi}]"):
                    return frag_fn(idx)
            return task

        results = run_fragments(pool, [frag(pi, idx)
                                       for pi, idx in enumerate(parts)])
        n = int(sum(len(p) for p in parts))
        out: list = [None] * n
        for idx, sub in zip(parts, results):
            for i, row in zip(idx, sub):
                out[int(i)] = row
        st.details.update(n_partitions=len(parts),
                          partition_sizes=_fragment_sizes(parts))
        return out, st.as_dict()


# ---------------------------------------------------------------------------
# Hierarchical aggregation
# ---------------------------------------------------------------------------


def _reduce_levels(texts: list[str], template: str, model, fanout: int,
                   levels: int) -> list[str]:
    """Run exactly ``levels`` rounds of the level-synchronous reduce.  A
    length-1 level is still re-prompted as a singleton group — exactly what
    the unpartitioned loop does to a small trailing subtree whose partial
    closes early — so partition-local trees stay level-aligned with the
    global one (with a real model, ``agg([x]) != x``, so skipping those
    rounds would feed the root different inputs)."""
    level = list(texts)
    for _ in range(levels):
        groups = [level[i:i + fanout] for i in range(0, len(level), fanout)]
        level = model.generate([_agg_prompt(template, g) for g in groups])
    return level


def sem_agg_partitioned(records, langex, model, parts, pool, *,
                        fanout: int = 8) -> tuple[str, dict]:
    """Hierarchical reduce as partition-local subtrees + one global root.

    ``parts`` must be subtree-aligned (``subtree_partitions``): each
    fragment runs the first ``depth-1`` reduce levels of its subtree
    (including any singleton re-prompts of an early-closing tail), and the
    root prompt combines the partials — prompt-for-prompt the tree the
    unpartitioned ``sem_agg_hierarchical`` issues, so the final answer is
    record-identical for any corpus size."""
    lx = as_langex(langex)
    with accounting.track("sem_agg") as st:
        leaves = [lx.render(t) for t in records]
        depth = _tree_depth(len(leaves), fanout)

        def frag(pi, idx):
            def task():
                with accounting.track(f"fragment[{pi}]"):
                    return _reduce_levels([leaves[i] for i in idx],
                                          lx.template, model, fanout,
                                          depth - 1)
            return task

        partials = [x for chunk in run_fragments(
            pool, [frag(pi, idx) for pi, idx in enumerate(parts)])
            for x in chunk]
        # level ``depth``: one root group (<= fanout partials by alignment;
        # with depth == 1 the "partials" are the leaves themselves and this
        # is the unpartitioned run's single prompt)
        answer = model.generate([_agg_prompt(lx.template, partials)])[0]
        st.details.update(depth=depth, n_partitions=len(parts),
                          partition_sizes=_fragment_sizes(parts))
        return answer, st.as_dict()


def _tree_depth(n: int, fanout: int) -> int:
    f = max(fanout, 2)
    depth = 1
    while f ** depth < max(n, 1):
        depth += 1
    return depth


def sem_agg_groupby_partitioned(records, langex, model, group_by: str,
                                parts, pool, *, fanout: int = 8,
                                out_column: str = "aggregate"
                                ) -> tuple[list[dict], list[dict]]:
    """Group-by aggregation over hash partitions: every group's rows land
    whole in one fragment (hash on the group key), so each fragment runs
    the ordinary per-group hierarchical reduce; the merge re-orders group
    rows to the key's global first-seen order — exactly the unpartitioned
    iteration order.  Returns (rows, per-group stats dicts)."""
    from repro.core.operators.agg import sem_agg_hierarchical
    lx = as_langex(langex)

    def frag(pi, idx):
        def task():
            with accounting.track(f"fragment[{pi}]"):
                groups: dict = {}
                for i in idx:
                    groups.setdefault(records[i][group_by],
                                      []).append(records[i])
                out = []
                for g, sub in groups.items():
                    answer, stats = sem_agg_hierarchical(sub, lx, model,
                                                         fanout=fanout)
                    out.append((g, answer, stats))
                return out
        return task

    results = run_fragments(pool, [frag(pi, idx)
                                   for pi, idx in enumerate(parts)])
    by_key = {g: (answer, stats) for chunk in results
              for g, answer, stats in chunk}
    rows, stats_list = [], []
    seen = set()
    for t in records:  # global first-seen order of group keys
        g = t[group_by]
        if g in seen:
            continue
        seen.add(g)
        answer, stats = by_key[g]
        rows.append({group_by: g, out_column: answer})
        stats_list.append(stats)
    return rows, stats_list


# ---------------------------------------------------------------------------
# Join
# ---------------------------------------------------------------------------


def sem_join_gold_partitioned(left, right, langex, oracle, lparts, rparts,
                              pool, *, exchange: str
                              ) -> tuple[np.ndarray, dict]:
    """Gold nested-loop join over a fragment tiling of the pair space:
    ``broadcast`` pairs each left partition with the full right side;
    ``repartition`` runs the (lparts x rparts) grid.  Per-pair prompts are
    unchanged, so the stitched mask equals the unpartitioned gold mask."""
    lx = as_langex(langex)
    with accounting.track("sem_join_gold") as st:
        n1, n2 = len(left), len(right)
        mask = np.zeros((n1, n2), bool)
        tiles = [(li, ri) for li in range(len(lparts))
                 for ri in range(len(rparts))]

        def frag(li, ri):
            lidx, ridx = lparts[li], rparts[ri]

            def task():
                with accounting.track(f"fragment[{li},{ri}]"):
                    pairs = [(int(i), int(j)) for i in lidx for j in ridx]
                    passed, _ = oracle.predicate(
                        _pair_prompts(lx, left, right, pairs))
                    sub = np.zeros((len(lidx), len(ridx)), bool)
                    for (pi, pj), p in zip(
                            ((a, b) for a in range(len(lidx))
                             for b in range(len(ridx))), passed):
                        sub[pi, pj] = p
                    return sub
            return task

        results = run_fragments(pool, [frag(li, ri) for li, ri in tiles])
        for (li, ri), sub in zip(tiles, results):
            mask[np.ix_(lparts[li], rparts[ri])] = sub
        st.details.update(exchange=exchange, n_fragments=len(tiles),
                          grid=(len(lparts), len(rparts)))
        return mask, st.as_dict()
