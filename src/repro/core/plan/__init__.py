"""Lazy logical-plan layer for semantic operators (paper §2: "each operator
opens a rich space for execution plans, similar to relational operators").

Four pieces:

  * ``nodes``    — the logical IR (Scan/Filter/Join/TopK/Agg/GroupBy/Map/
                   FusedMap/Extract/Search/SimJoin dataclasses forming a DAG,
                   plus the Partition/Exchange fragment boundaries);
  * ``optimize`` — rule-based rewrites over the DAG (filter reordering by
                   cost x selectivity, filter pushdown below joins, map
                   fusion, sim-join prefilters under high-fanout joins,
                   cost-based retrieval choice, partition planning);
  * ``execute``  — the batched physical executor: walks the optimized DAG,
                   dispatches to the gold/cascade operator implementations,
                   and routes all model traffic through ``BatchedModelCache``
                   (prompt dedup + LRU memoization across pipeline stages).
                   ``PartitionedExecutor`` additionally runs Exchange-bounded
                   plan fragments over row partitions (``plan.parallel``)
                   with guarantee-preserving merge semantics;
  * ``parallel`` — the partitioned operator implementations + fragment
                   scheduling;
  * ``adaptive`` — :class:`AdaptivePlanExecutor`, mid-query re-optimization:
                   filter chains re-ranked on live blended selectivities,
                   retrieval re-chosen on observed corpus size, fragments
                   re-sized on observed row counts — record-identical by the
                   strict-mode equivalence contract.

``SemFrame.lazy()`` is the entry point; the default eager path builds the
same single-node plans and executes them immediately (identical behavior and
stats to the pre-plan-layer code).
"""
from repro.core.plan.adaptive import (AdaptivePlanExecutor, AdaptivePolicy,
                                      adaptive_default)
from repro.core.plan.cache import BatchedModelCache
from repro.core.plan.execute import PartitionedExecutor, PlanExecutor
from repro.core.plan.nodes import (Agg, Exchange, Extract, Filter, FusedMap,
                                   GroupBy, Join, LogicalNode, Map, Partition,
                                   Scan, Search, SimJoin, TopK)
from repro.core.plan.optimize import PlanOptimizer, explain_plan

__all__ = [
    "AdaptivePlanExecutor", "AdaptivePolicy", "Agg", "BatchedModelCache",
    "Exchange", "Extract", "Filter", "FusedMap", "GroupBy", "Join",
    "LogicalNode", "Map", "Partition", "PartitionedExecutor", "PlanExecutor",
    "PlanOptimizer", "Scan", "Search", "SimJoin", "TopK", "adaptive_default",
    "explain_plan",
]
