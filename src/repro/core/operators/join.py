"""sem_join (§2.3, §3.2).

Gold algorithm: nested-loop predicate evaluation, O(N1*N2) oracle calls.

Optimized: two embedding-based proxy plans with learned cascade thresholds —
  * sim-filter:          A1(i,j) = sim(emb(left_i),            emb(right_j))
  * project-sim-filter:  A2(i,j) = sim(emb(project(left_i)),   emb(right_j))
    (the projection is an *ungrounded* sem_map over the left table: predict
    the right join key from the left tuple alone — fully parallel, N1 calls)
— the optimizer prices both plans from one oracle-labeled pair sample and
executes the cheaper one (paper Table 5).
"""
from __future__ import annotations

import numpy as np

from repro.core import accounting
from repro.core.langex import as_langex
from repro.core.operators.filter import predicate_prompt
from repro.core.optimizer import cascades, stats
from repro.index.quantile import quantile_calibrate
from repro.index.vector_index import VectorIndex
from repro.obs import audit as _audit

PROJECT_INSTRUCTION = (
    "{rendered}\nPredict the most likely value of the missing right-hand "
    "field, given only this input. Answer with the value only.\nAnswer:")


def _pair_prompts(lx, left, right, pairs):
    return [predicate_prompt(lx, left[i], right[j]) for i, j in pairs]


def sem_join_gold(left: list[dict], right: list[dict], langex, oracle,
                  *, batch: int = 4096) -> tuple[np.ndarray, dict]:
    """Returns (mask [N1,N2] bool, stats)."""
    lx = as_langex(langex)
    with accounting.track("sem_join_gold") as st:
        n1, n2 = len(left), len(right)
        out = np.zeros((n1, n2), bool)
        pairs = [(i, j) for i in range(n1) for j in range(n2)]
        for s in range(0, len(pairs), batch):
            chunk = pairs[s:s + batch]
            passed, _ = oracle.predicate(_pair_prompts(lx, left, right, chunk))
            for (i, j), p in zip(chunk, passed):
                out[i, j] = p
        return out, st.as_dict()


def _render_side(records, fields):
    return [" ".join(str(t[f.name]) for f in fields) for t in records]


def sem_join_cascade(left: list[dict], right: list[dict], langex, oracle,
                     embedder, *, project_fn=None,
                     recall_target: float = 0.9, precision_target: float = 0.9,
                     delta: float = 0.2, sample_size: int = 100, seed: int = 0,
                     force_plan: str | None = None) -> tuple[np.ndarray, dict]:
    """Optimized join: plan selection between sim-filter and
    project-sim-filter, each a cascade with (recall, precision, delta)
    guarantees vs the gold nested-loop join.

    ``project_fn(left_records) -> list[str]`` overrides the LLM projection
    (defaults to oracle.generate over the ungrounded projection prompt).
    """
    lx = as_langex(langex)
    with accounting.track("sem_join") as st:
        n1, n2 = len(left), len(right)
        lfields = [f for f in lx.fields if f.side != "right"]
        rfields = [f for f in lx.fields if f.side == "right"]
        left_texts = _render_side(left, lfields)
        right_texts = _render_side(right, rfields)

        # -- plan 1 proxy: raw embedding similarity (scored through the
        # retrieval layer: proxy calibration needs the full exact matrix) ---
        emb_l = embedder.embed(left_texts)
        right_index = VectorIndex(embedder.embed(right_texts))
        a1 = quantile_calibrate(right_index.pairwise(emb_l)).ravel()

        # -- plan 2 proxy: project left -> right-key space -----------------
        if project_fn is None:
            proj_prompts = [PROJECT_INSTRUCTION.format(rendered=lx.render(t, None)
                            if not lx.is_binary else lx.render(t, {f.name: "?" for f in rfields}))
                            for t in left]
            projected = oracle.generate(proj_prompts)
        else:
            projected = project_fn(left)
        emb_p = embedder.embed(list(projected))
        a2 = quantile_calibrate(right_index.pairwise(emb_p)).ravel()

        # -- one oracle-labeled pair sample prices both plans --------------
        rng = np.random.default_rng(seed)
        s = min(sample_size, n1 * n2)
        mix_scores = np.maximum(a1, a2)          # defensive union of proxies
        probs = stats.defensive_importance_probs(mix_scores, power=16.0)
        idx = stats.importance_sample(rng, probs, s)
        uniq = np.unique(idx)
        pairs = [(int(i) // n2, int(i) % n2) for i in uniq]
        labels_uniq, _ = oracle.predicate(_pair_prompts(lx, left, right, pairs))
        label_of = dict(zip(uniq.tolist(), np.asarray(labels_uniq, bool).tolist()))
        labels = np.asarray([label_of[i] for i in idx], bool)

        plans = []
        for name, scores, extra in (("sim-filter", a1, 0),
                                    ("project-sim-filter", a2, n1)):
            sample = stats.Sample(idx=idx, probs=probs, labels=labels,
                                  scores=scores[idx])
            plans.append(cascades.estimate_plan(
                name, scores, sample, label_of,
                recall_target=recall_target, precision_target=precision_target,
                delta=delta, extra_lm_calls=extra))

        if force_plan:
            chosen = next(p for p in plans if p.name == force_plan)
        else:
            chosen = min(plans, key=lambda p: p.total_cost)

        def oracle_fn(flat_indices):
            prs = [(int(i) // n2, int(i) % n2) for i in flat_indices]
            passed, _ = oracle.predicate(_pair_prompts(lx, left, right, prs))
            return passed

        res = cascades.execute_plan(chosen, oracle_fn)
        _audit.emit_cascade(
            "Join", lx.template, res,
            lambda idx: _pair_prompts(
                lx, left, right, [(int(i) // n2, int(i) % n2) for i in idx]),
            recall_target=recall_target, precision_target=precision_target)
        st.details.update(plan=chosen.name, tau_plus=res.tau_plus, tau_minus=res.tau_minus,
                          plan_costs={p.name: p.total_cost for p in plans},
                          oracle_calls_cascade=res.oracle_calls,
                          auto_accepted=res.auto_accepted, oracle_region=res.oracle_region)
        return res.passed.reshape(n1, n2), st.as_dict()
