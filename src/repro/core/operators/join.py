"""sem_join (§2.3, §3.2).

Gold algorithm: nested-loop predicate evaluation, O(N1*N2) oracle calls.

Optimized: two embedding-based proxy plans with learned cascade thresholds —
  * sim-filter:          A1(i,j) = sim(emb(left_i),            emb(right_j))
  * project-sim-filter:  A2(i,j) = sim(emb(project(left_i)),   emb(right_j))
    (the projection is an *ungrounded* sem_map over the left table: predict
    the right join key from the left tuple alone — fully parallel, N1 calls)
— the optimizer prices both plans from one oracle-labeled pair sample and
executes the cheaper one (paper Table 5).
"""
from __future__ import annotations

import numpy as np

from repro.core import accounting
from repro.core.langex import as_langex
from repro.core.operators.filter import predicate_prompt
from repro.core.optimizer import blocks, cascades, stats
from repro.index.quantile import quantile_calibrate
from repro.index.vector_index import VectorIndex
from repro.obs import audit as _audit

PROJECT_INSTRUCTION = (
    "{rendered}\nPredict the most likely value of the missing right-hand "
    "field, given only this input. Answer with the value only.\nAnswer:")


def _pair_prompts(lx, left, right, pairs):
    return [predicate_prompt(lx, left[i], right[j]) for i, j in pairs]


def sem_join_gold(left: list[dict], right: list[dict], langex, oracle,
                  *, batch: int = 4096) -> tuple[np.ndarray, dict]:
    """Returns (mask [N1,N2] bool, stats).

    Pair batches are generated lazily from the flat row-major pair index —
    the full O(N1*N2) tuple list is never materialized, so a gold join over
    large tables holds only one ``batch`` of pairs in host memory at a time
    (prompt order is unchanged: row-major, exactly the eager list's)."""
    lx = as_langex(langex)
    with accounting.track("sem_join_gold") as st:
        n1, n2 = len(left), len(right)
        out = np.zeros((n1, n2), bool)
        total = n1 * n2
        for s in range(0, total, batch):
            chunk = [divmod(flat, n2) for flat in range(s, min(s + batch, total))]
            passed, _ = oracle.predicate(_pair_prompts(lx, left, right, chunk))
            for (i, j), p in zip(chunk, passed):
                out[i, j] = p
        return out, st.as_dict()


def _render_side(records, fields):
    return [" ".join(str(t[f.name]) for f in fields) for t in records]


def sem_join_cascade(left: list[dict], right: list[dict], langex, oracle,
                     embedder, *, project_fn=None,
                     recall_target: float = 0.9, precision_target: float = 0.9,
                     delta: float = 0.2, sample_size: int = 100, seed: int = 0,
                     force_plan: str | None = None) -> tuple[np.ndarray, dict]:
    """Optimized join: plan selection between sim-filter and
    project-sim-filter, each a cascade with (recall, precision, delta)
    guarantees vs the gold nested-loop join.

    ``project_fn(left_records) -> list[str]`` overrides the LLM projection
    (defaults to oracle.generate over the ungrounded projection prompt).
    """
    lx = as_langex(langex)
    with accounting.track("sem_join") as st:
        n1, n2 = len(left), len(right)
        lfields = [f for f in lx.fields if f.side != "right"]
        rfields = [f for f in lx.fields if f.side == "right"]
        left_texts = _render_side(left, lfields)
        right_texts = _render_side(right, rfields)

        # -- plan 1 proxy: raw embedding similarity (scored through the
        # retrieval layer: proxy calibration needs the full exact matrix) ---
        emb_l = embedder.embed(left_texts)
        right_index = VectorIndex(embedder.embed(right_texts))
        a1 = quantile_calibrate(right_index.pairwise(emb_l)).ravel()

        # -- plan 2 proxy: project left -> right-key space -----------------
        if project_fn is None:
            proj_prompts = [PROJECT_INSTRUCTION.format(rendered=lx.render(t, None)
                            if not lx.is_binary else lx.render(t, {f.name: "?" for f in rfields}))
                            for t in left]
            projected = oracle.generate(proj_prompts)
        else:
            projected = project_fn(left)
        emb_p = embedder.embed(list(projected))
        a2 = quantile_calibrate(right_index.pairwise(emb_p)).ravel()

        # -- one oracle-labeled pair sample prices both plans --------------
        rng = np.random.default_rng(seed)
        s = min(sample_size, n1 * n2)
        mix_scores = np.maximum(a1, a2)          # defensive union of proxies
        probs = stats.defensive_importance_probs(mix_scores, power=16.0)
        idx = stats.importance_sample(rng, probs, s)
        uniq = np.unique(idx)
        pairs = [(int(i) // n2, int(i) % n2) for i in uniq]
        labels_uniq, _ = oracle.predicate(_pair_prompts(lx, left, right, pairs))
        label_of = dict(zip(uniq.tolist(), np.asarray(labels_uniq, bool).tolist()))
        labels = np.asarray([label_of[i] for i in idx], bool)

        plans = []
        for name, scores, extra in (("sim-filter", a1, 0),
                                    ("project-sim-filter", a2, n1)):
            sample = stats.Sample(idx=idx, probs=probs, labels=labels,
                                  scores=scores[idx])
            plans.append(cascades.estimate_plan(
                name, scores, sample, label_of,
                recall_target=recall_target, precision_target=precision_target,
                delta=delta, extra_lm_calls=extra))

        if force_plan:
            chosen = next(p for p in plans if p.name == force_plan)
        else:
            chosen = min(plans, key=lambda p: p.total_cost)

        def oracle_fn(flat_indices):
            prs = [(int(i) // n2, int(i) % n2) for i in flat_indices]
            passed, _ = oracle.predicate(_pair_prompts(lx, left, right, prs))
            return passed

        res = cascades.execute_plan(chosen, oracle_fn)
        _audit.emit_cascade(
            "Join", lx.template, res,
            lambda idx: _pair_prompts(
                lx, left, right, [(int(i) // n2, int(i) % n2) for i in idx]),
            recall_target=recall_target, precision_target=precision_target)
        st.details.update(plan=chosen.name, tau_plus=res.tau_plus, tau_minus=res.tau_minus,
                          plan_costs={p.name: p.total_cost for p in plans},
                          oracle_calls_cascade=res.oracle_calls,
                          auto_accepted=res.auto_accepted, oracle_region=res.oracle_region)
        return res.passed.reshape(n1, n2), st.as_dict()


def sem_join_block(left: list[dict], right: list[dict], langex, oracle,
                   embedder, *, recall_target: float = 0.9,
                   precision_target: float = 0.9, delta: float = 0.2,
                   sample_size: int = 100, seed: int = 0,
                   block_size: int | None = None,
                   candidate_k: int | None = None,
                   equivalence: bool | None = None,
                   agreement_floor: float = 0.9,
                   probe_size: int = 24,
                   index_builder=None) -> tuple[np.ndarray, dict]:
    """Three-stage fast join: IVF blocking -> block-prompted oracle ->
    transitivity-based verdict inference.

    Stage 1 (*blocking*): the right side is indexed through the retrieval
    layer (``index_builder(texts, n_queries)`` — the executor passes its
    cost-model-driven builder, so large corpora get IVF / int8 tiles) and
    each left row retrieves only its top-``k`` candidate block: candidate
    compute and memory are O(n1*k), never O(n1*n2).  A small uniform
    pairwise probe estimates the candidate set's match *coverage*; ``k``
    doubles (up to 3x) until coverage reaches the recall target, and the
    cascade's effective recall target is divided by the final coverage so
    the end-to-end guarantee is stated against the gold O(n1*n2) join.

    Stage 2 (*block prompts*): calibration labels and mid-region verdicts
    come from :class:`~repro.core.optimizer.blocks.BlockJudge` — B pairs per
    structured prompt through ``oracle.generate`` (micro-batch-fused), with
    parse-validate-retry and a pairwise fallback so verdicts are never
    silently dropped.  Calibration blocks are agreement-checked against
    pairwise gold (:func:`~repro.core.optimizer.cascades.block_labeled_sample`).

    Stage 3 (*verdict inference*): when the predicate is an equivalence
    (``equivalence=True``, the langex declares it, or
    :func:`~repro.core.optimizer.blocks.detect_equivalence` confirms it on
    the calibration sample), confirmed verdicts propagate through a
    union-find transitivity closure: implied candidate pairs are pruned
    from the oracle bill entirely, and the closure is applied over the full
    pair grid at the end so true matches the blocking stage never retrieved
    are still recovered (``pairs_recovered_by_inference``).
    """
    lx = as_langex(langex)
    with accounting.track("sem_join_block") as st:
        n1, n2 = len(left), len(right)
        st.details.update(strategy="block")
        if n1 == 0 or n2 == 0:
            st.details.update(candidate_pairs=0, block_prompts=0,
                              block_fallbacks=0, pairs_pruned_by_inference=0)
            return np.zeros((n1, n2), bool), st.as_dict()
        lfields = [f for f in lx.fields if f.side != "right"]
        rfields = [f for f in lx.fields if f.side == "right"]
        left_texts = _render_side(left, lfields)
        right_texts = _render_side(right, rfields)

        if index_builder is None:
            def index_builder(texts, n_queries):
                from repro.index.backend import build_index
                return build_index(embedder.embed(texts), kind="auto")
        right_index = index_builder(right_texts, n1)
        emb_l = embedder.embed(left_texts)
        rng = np.random.default_rng(seed)

        # pairwise gold judge with a label cache: coverage probes, block
        # agreement checks and mid-region reuse all share one bill
        label_cache: dict[tuple[int, int], bool] = {}

        def pairwise(prs):
            prs = [(int(i), int(j)) for i, j in prs]
            need = [p for p in dict.fromkeys(prs) if p not in label_cache]
            if need:
                passed, _ = oracle.predicate(_pair_prompts(lx, left, right, need))
                for p, v in zip(need, np.asarray(passed, bool)):
                    label_cache[p] = bool(v)
            return np.asarray([label_cache[p] for p in prs], bool)

        # -- stage 1: blocking with coverage-adaptive candidate width -------
        from repro.index.backend import MASKED_SCORE
        k = min(int(candidate_k) if candidate_k else blocks.blocking_k(n2), n2)
        doublings = 0
        while True:
            scores_m, cand_m = right_index.search(emb_l, k)
            cand_pairs: list[tuple[int, int]] = []
            cand_scores: list[float] = []
            cand_set: set[tuple[int, int]] = set()
            for i in range(n1):
                for r in range(cand_m.shape[1]):
                    j, sc = int(cand_m[i, r]), float(scores_m[i, r])
                    if j < 0 or j >= n2 or sc <= MASKED_SCORE / 2:
                        continue
                    cand_pairs.append((i, j))
                    cand_scores.append(sc)
                    cand_set.add((i, j))
            n_cand, n_off = len(cand_pairs), n1 * n2 - len(cand_set)
            if n_off <= 0 or n_cand == 0:
                coverage = 1.0
            else:
                pick = rng.choice(n_cand, size=min(probe_size, n_cand),
                                  replace=False)
                p_cand = float(pairwise([cand_pairs[int(x)] for x in pick]).mean())
                off_probe: list[tuple[int, int]] = []
                tries = 0
                while len(off_probe) < min(probe_size, n_off) and tries < probe_size * 20:
                    pr = (int(rng.integers(n1)), int(rng.integers(n2)))
                    tries += 1
                    if pr not in cand_set:
                        off_probe.append(pr)
                p_off = float(pairwise(off_probe).mean()) if off_probe else 0.0
                mass_c, mass_o = p_cand * n_cand, p_off * n_off
                coverage = mass_c / (mass_c + mass_o) if mass_c + mass_o > 0 else 1.0
            if coverage >= recall_target or k >= n2 or doublings >= 3:
                break
            k = min(2 * k, n2)
            doublings += 1
        if n_cand == 0:
            st.details.update(candidate_pairs=0, candidate_k=k, block_prompts=0,
                              block_fallbacks=0, pairs_pruned_by_inference=0,
                              index=right_index.kind)
            return np.zeros((n1, n2), bool), st.as_dict()
        a = quantile_calibrate(np.asarray(cand_scores, np.float32)).ravel()

        # -- stage 2: block-labeled calibration sample + thresholds ---------
        judge = blocks.BlockJudge(
            oracle, lx, left, right,
            lambda prs: _pair_prompts(lx, left, right, prs),
            block_size=int(block_size) if block_size else blocks.DEFAULT_BLOCK_SIZE)
        s = min(sample_size, n_cand)
        probs = stats.defensive_importance_probs(a, power=16.0)
        idx = stats.importance_sample(rng, probs, s)
        uniq = np.unique(idx)
        uniq_pairs = [cand_pairs[int(u)] for u in uniq]
        cal = cascades.block_labeled_sample(uniq_pairs, judge, pairwise, rng=rng,
                                            agreement_floor=agreement_floor)
        label_of = dict(zip(uniq.tolist(), np.asarray(cal.labels, bool).tolist()))
        labels = np.asarray([label_of[int(i)] for i in idx], bool)
        sample = stats.Sample(idx=idx, probs=probs, labels=labels, scores=a[idx])
        # the cascade guarantees recall vs the *candidate* set; dividing the
        # target by the blocking coverage states it vs the gold join
        rt_eff = min(0.999, recall_target / max(coverage, 1e-6))
        plan = cascades.estimate_plan("block-join", a, sample, label_of,
                                      recall_target=rt_eff,
                                      precision_target=precision_target,
                                      delta=delta)

        # -- stage 3: equivalence resolution + inference-pruned execution --
        eq = equivalence
        if eq is None:
            eq = bool(getattr(lx, "equivalence", False)) or \
                blocks.detect_equivalence(uniq_pairs, cal.labels)
        inference = blocks.MatchInference(n1, n2) if eq else None
        if inference is not None:
            for (pi, pj), v in zip(uniq_pairs, cal.labels):
                inference.observe(pi, pj, bool(v))

        passed = np.zeros(n_cand, bool)
        auto = a >= plan.tau_plus
        passed[auto] = True
        mid = (~auto) & (a >= plan.tau_minus)
        known_mask = np.zeros(n_cand, bool)
        known_mask[uniq] = True
        for u in uniq:
            if mid[u]:
                passed[u] = label_of[int(u)]
        need = np.flatnonzero(mid & ~known_mask)
        # high-score-first waves: confident verdicts land early and seed the
        # transitivity closure, so later waves prune more implied pairs
        order = need[np.argsort(-a[need], kind="stable")]
        pruned = 0
        block_pairs: list[tuple[int, int]] = []
        block_verdicts: list[bool] = []
        wave = judge.block_size * 4
        pos = 0
        while pos < len(order):
            batch_idx: list[int] = []
            while pos < len(order) and len(batch_idx) < wave:
                fi = int(order[pos])
                pos += 1
                i, j = cand_pairs[fi]
                if (i, j) in label_cache:
                    passed[fi] = label_cache[(i, j)]
                    if inference is not None:
                        inference.observe(i, j, bool(passed[fi]))
                    continue
                if inference is not None:
                    v = inference.resolve(i, j)
                    if v is not None:
                        passed[fi] = v
                        pruned += 1
                        continue
                batch_idx.append(fi)
            if batch_idx:
                prs = [cand_pairs[fi] for fi in batch_idx]
                verdicts = np.asarray(judge.judge_pairs(prs), bool)
                for fi, v in zip(batch_idx, verdicts):
                    passed[fi] = bool(v)
                    i, j = cand_pairs[fi]
                    if inference is not None:
                        inference.observe(i, j, bool(v))
                block_pairs.extend(prs)
                block_verdicts.extend(bool(v) for v in verdicts)

        oracle_calls = judge.stats.block_prompts + \
            judge.stats.pairs_fallback_judged + len(label_cache)
        res = cascades.CascadeResult(
            passed=passed, tau_plus=plan.tau_plus, tau_minus=plan.tau_minus,
            oracle_calls=oracle_calls, sample_size=s,
            auto_accepted=int(auto.sum()),
            auto_rejected=int((a < plan.tau_minus).sum()),
            oracle_region=int(mid.sum()), judged=mid.copy())
        _audit.emit_cascade(
            "Join", lx.template, res,
            lambda fidx: _pair_prompts(
                lx, left, right, [cand_pairs[int(f)] for f in fidx]),
            recall_target=recall_target, precision_target=precision_target)
        _audit.emit_block_join(
            "Join", lx.template, block_pairs, block_verdicts,
            lambda fidx: _pair_prompts(
                lx, left, right, [block_pairs[int(f)] for f in fidx]),
            agreement_target=agreement_floor)

        mask = np.zeros((n1, n2), bool)
        for (i, j), p in zip(cand_pairs, passed):
            if p:
                mask[i, j] = True
        recovered = 0
        if inference is not None:
            # close the verdicts over the FULL pair grid: a true match the
            # blocking stage never retrieved still joins when the confirmed
            # classes imply it, so end-to-end recall is not capped by the
            # candidate coverage
            implied = inference.implied_matrix()
            recovered = int((implied & ~mask).sum())
            mask |= implied
        st.details.update(
            candidate_pairs=n_cand, candidate_k=k,
            coverage_est=round(float(coverage), 4),
            tau_plus=res.tau_plus, tau_minus=res.tau_minus,
            block_prompts=judge.stats.block_prompts,
            block_retries=judge.stats.block_retries,
            block_fallbacks=judge.stats.block_fallbacks,
            pairs_block_judged=judge.stats.pairs_block_judged,
            pairs_pruned_by_inference=pruned,
            pairs_recovered_by_inference=recovered,
            match_classes=inference.n_classes() if inference is not None else 0,
            block_agreement=round(float(cal.agreement), 4),
            blocks_rejudged=cal.blocks_rejudged,
            equivalence=bool(eq), auto_accepted=res.auto_accepted,
            oracle_region=res.oracle_region,
            oracle_calls_cascade=res.oracle_calls, index=right_index.kind)
        return mask, st.as_dict()
