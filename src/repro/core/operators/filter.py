"""sem_filter (§2.3, §3.1).

Gold algorithm: one oracle predicate call per tuple (batched row-wise pass —
avoids long-context degradation by never packing multiple tuples per prompt).

Optimized: Algorithm 1 proxy-oracle cascade with (gamma_R, gamma_P, delta)
guarantees.  The proxy is either a cheaper LLM's True-token probability
(paper's Llama-8B / TinyLlama setting) or an embedding-similarity scorer.
"""
from __future__ import annotations

import numpy as np

from repro.core import accounting
from repro.core.langex import as_langex
from repro.core.optimizer import cascades
from repro.obs import audit as _audit

PREDICATE_INSTRUCTION = (
    "Claim: {claim}\nIs the claim true for this input? Answer <true> or <false>.\nAnswer:")


def predicate_prompt(langex, tup, right=None) -> str:
    return PREDICATE_INSTRUCTION.format(claim=as_langex(langex).render(tup, right))


def sem_filter_gold(records: list[dict], langex, oracle) -> tuple[np.ndarray, dict]:
    """Returns (mask [N] bool, stats)."""
    lx = as_langex(langex)
    with accounting.track("sem_filter_gold") as st:
        prompts = [predicate_prompt(lx, t) for t in records]
        passed, _ = oracle.predicate(prompts)
        return np.asarray(passed, bool), st.as_dict()


def sem_filter_cascade(records: list[dict], langex, oracle, proxy, *,
                       recall_target: float = 0.9, precision_target: float = 0.9,
                       delta: float = 0.2, sample_size: int = 100, seed: int = 0
                       ) -> tuple[np.ndarray, dict]:
    """Algorithm 1. Proxy scores all tuples; oracle labels the sample plus the
    undecided mid-region."""
    lx = as_langex(langex)
    with accounting.track("sem_filter") as st:
        prompts = [predicate_prompt(lx, t) for t in records]
        _, scores = proxy.predicate(prompts)

        def oracle_fn(indices):
            passed, _ = oracle.predicate([prompts[i] for i in indices])
            return passed

        res = cascades.run_cascade(
            np.asarray(scores, float), oracle_fn,
            recall_target=recall_target, precision_target=precision_target,
            delta=delta, sample_size=sample_size, seed=seed)
        _audit.emit_cascade("Filter", lx.template, res,
                            lambda idx: [prompts[i] for i in idx],
                            recall_target=recall_target,
                            precision_target=precision_target)
        st.details.update(tau_plus=res.tau_plus, tau_minus=res.tau_minus,
                          oracle_calls_cascade=res.oracle_calls,
                          auto_accepted=res.auto_accepted,
                          auto_rejected=res.auto_rejected,
                          oracle_region=res.oracle_region)
        return res.passed, st.as_dict()
