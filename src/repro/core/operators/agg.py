"""sem_agg (§2.3): commutative/associative natural-language reduction.

Gold algorithm: hierarchical reduce — batch tuples into fanout-sized groups,
aggregate each with one model call, recurse until one answer remains (higher
quality than the sequential fold for summarization-like tasks [21] and
embarrassingly parallel per level).  The fold pattern is implemented as the
comparison baseline.  A user ``partitioner`` may override grouping/order
(footnote 4: input order can matter; commutativity is an assumption the
programmer can opt out of).
"""
from __future__ import annotations

from typing import Callable, Sequence

from repro.core import accounting
from repro.core.langex import as_langex

AGG_INSTRUCTION = ("Task: {task}\nInputs:\n{items}\n"
                   "Produce a single combined answer for the task over all inputs.\nAnswer:")


def _agg_prompt(task: str, items: Sequence[str]) -> str:
    body = "\n".join(f"- {t}" for t in items)
    return AGG_INSTRUCTION.format(task=task, items=body)


def sem_agg_hierarchical(records: list[dict], langex, model, *, fanout: int = 8,
                         partitioner: Callable[[list[str]], list[list[str]]] | None = None
                         ) -> tuple[str, dict]:
    lx = as_langex(langex)
    with accounting.track("sem_agg") as st:
        level = [lx.render(t) for t in records]
        depth = 0
        while len(level) > 1 or depth == 0:
            if partitioner is not None and depth == 0:
                groups = partitioner(level)
            else:
                groups = [level[i:i + fanout] for i in range(0, len(level), fanout)]
            prompts = [_agg_prompt(lx.template, g) for g in groups]
            level = model.generate(prompts)
            depth += 1
            if len(groups) == 1:
                break
        st.details.update(depth=depth)
        return level[0], st.as_dict()


def sem_agg_fold(records: list[dict], langex, model) -> tuple[str, dict]:
    """Sequential fold baseline: accumulate a running partial answer."""
    lx = as_langex(langex)
    with accounting.track("sem_agg_fold") as st:
        acc = lx.render(records[0])
        for t in records[1:]:
            acc = model.generate(
                [_agg_prompt(lx.template, [f"(partial answer) {acc}", lx.render(t)])])[0]
        return acc, st.as_dict()
