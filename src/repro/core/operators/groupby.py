"""sem_group_by (§2.3, §3.3).

Gold algorithm (two stages):
  1. discover C group labels: sem_map each tuple to a candidate label ->
     embed -> k-means -> for each cluster, sem_agg a label over the top-m
     centroid-nearest members;
  2. point-wise classification: M(t, mu_1..mu_C) for every tuple.

Optimized classification: embedding-similarity proxy between each tuple's
candidate label and the discovered centers, with a PT-style learned threshold
guaranteeing classification accuracy >= gamma w.p. 1-delta (uniform sample);
below-threshold tuples fall back to the oracle classifier.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import accounting
from repro.core.langex import as_langex
from repro.core.operators.agg import sem_agg_hierarchical
from repro.core.optimizer import stats
from repro.index.kmeans import kmeans
from repro.index.vector_index import VectorIndex

MAP_LABEL_INSTRUCTION = ("Task: produce a short category label for: {item}\n"
                         "Criteria: {criteria}\nLabel:")
CLASSIFY_INSTRUCTION = ("Criteria: {criteria}\nItem: {item}\nCategories:\n{cats}\n"
                        "Answer with the number of the best category.\nAnswer:")


@dataclasses.dataclass
class GroupByResult:
    labels: list[str]           # C discovered group labels
    assignment: np.ndarray      # [N] group index per tuple
    stats: dict


def _discover(records, lx, model, embedder, C, *, label_sample: int, seed: int):
    """Stage 1: candidate labels -> embed -> kmeans -> label each cluster.

    -> (cand_labels [N], center_sims [N, C], group_labels [C])."""
    cand_prompts = [MAP_LABEL_INSTRUCTION.format(item=lx.render(t), criteria=lx.template)
                    for t in records]
    cand_labels = model.generate(cand_prompts)
    emb = embedder.embed(list(cand_labels))
    centers, assign = kmeans(emb, C, seed=seed)
    # center scoring rides the retrieval layer (the exact backend over the
    # C discovered centers) so the similarity math matches search/sim_join;
    # the same [N, C] matrix doubles as the cascade's proxy scores
    center_sims = VectorIndex(centers).pairwise(emb)
    group_labels: list[str] = []
    for j in range(len(centers)):
        members = np.flatnonzero(assign == j)
        if len(members) == 0:
            group_labels.append(f"group-{j}")
            continue
        top = members[np.argsort(-center_sims[members, j])[:label_sample]]
        label, _ = sem_agg_hierarchical(
            [{"label": cand_labels[i]} for i in top],
            "a short category label capturing all of: {label}", model)
        group_labels.append(label)
    return cand_labels, center_sims, group_labels


def _oracle_classify(records, lx, model, group_labels, indices) -> np.ndarray:
    cats = "\n".join(f"{i}. {l}" for i, l in enumerate(group_labels))
    prompts = [CLASSIFY_INSTRUCTION.format(criteria=lx.template,
                                           item=lx.render(records[i]), cats=cats)
               for i in indices]
    return np.asarray(model.choose(prompts, len(group_labels)), int)


def sem_group_by_gold(records, langex, C, model, embedder, *,
                      label_sample: int = 8, seed: int = 0) -> GroupByResult:
    lx = as_langex(langex)
    with accounting.track("sem_group_by_gold") as st:
        _, _, group_labels = _discover(records, lx, model, embedder, C,
                                       label_sample=label_sample, seed=seed)
        assign = _oracle_classify(records, lx, model, group_labels, range(len(records)))
        return GroupByResult(group_labels, assign, st.as_dict())


def sem_group_by_cascade(records, langex, C, model, embedder, *,
                         accuracy_target: float = 0.9, delta: float = 0.2,
                         sample_size: int = 100, label_sample: int = 8,
                         seed: int = 0) -> GroupByResult:
    lx = as_langex(langex)
    with accounting.track("sem_group_by") as st:
        _, sims, group_labels = _discover(
            records, lx, model, embedder, C, label_sample=label_sample, seed=seed)

        # proxy: candidate-label similarity to the discovered centers
        # (the [N, C] matrix _discover already scored)
        proxy_label = np.argmax(sims, axis=1)
        proxy_score = np.max(sims, axis=1)      # A(t_i, mu_j) = sim(t'_i, mu_j)

        # learn accuracy threshold on a uniform sample (PT-style, §3.3)
        rng = np.random.default_rng(seed)
        n = len(records)
        s = min(sample_size, n)
        sample_idx = rng.choice(n, size=s, replace=False)
        oracle_lab = _oracle_classify(records, lx, model, group_labels, sample_idx)
        correct = oracle_lab == proxy_label[sample_idx]
        tau = stats.accuracy_threshold(proxy_score[sample_idx], correct,
                                       accuracy_target, delta)

        assign = proxy_label.copy()
        known = dict(zip(sample_idx.tolist(), oracle_lab.tolist()))
        for i, lab in known.items():
            assign[i] = lab
        need = np.flatnonzero((proxy_score < tau)
                              & ~np.isin(np.arange(n), sample_idx))
        if len(need):
            assign[need] = _oracle_classify(records, lx, model, group_labels, need)
        st.details.update(tau=float(tau), oracle_classified=len(need) + s,
                          proxy_classified=int(n - len(need) - s))
        return GroupByResult(group_labels, assign, st.as_dict())
