"""sem_map & sem_extract (§2.3, §4.2): row-wise natural-language projection.

sem_map generates an arbitrary text attribute; sem_extract restricts the
output to substrings of the source text (entity extraction / verified quotes
— generations that do not appear verbatim in the source are snapped to the
closest matching source span or dropped).
"""
from __future__ import annotations

import difflib
import re

from repro.core import accounting
from repro.core.langex import as_langex

MAP_INSTRUCTION = "Task: {task}\nInput: {item}\nAnswer concisely.\nAnswer:"
EXTRACT_INSTRUCTION = ("Task: {task}\nSource text: {item}\n"
                       "Answer ONLY with an exact snippet copied from the source text.\nAnswer:")
FUSED_MAP_INSTRUCTION = ("Tasks:\n{tasks}\n"
                         "Answer every task, each on its own line as "
                         "'<task number>. <answer>'. Answer concisely.\nAnswers:")
_FUSED_ANSWER_RE = re.compile(r"^\s*(\d+)\s*[.:)]\s*(.*)$")


def sem_map(records: list[dict], langex, model) -> tuple[list[str], dict]:
    lx = as_langex(langex)
    with accounting.track("sem_map") as st:
        prompts = [MAP_INSTRUCTION.format(task=lx.template, item=lx.render(t))
                   for t in records]
        return model.generate(prompts), st.as_dict()


def sem_map_fused(records: list[dict], langexes, model
                  ) -> tuple[list[list[str]], dict]:
    """K consecutive sem_maps over the same input in ONE prompt pass: a single
    generate call per record asks all K tasks as a numbered list and the
    numbered answer lines are parsed back out (lines that fail to parse fall
    back to the whole generation, so a weak model degrades to duplicated
    rather than missing columns).  Returns (columns [K][N], stats)."""
    lxs = [as_langex(l) for l in langexes]
    with accounting.track("sem_map_fused") as st:
        prompts = []
        for t in records:
            tasks = "\n".join(f"{i + 1}. Task: {lx.template} Input: {lx.render(t)}"
                              for i, lx in enumerate(lxs))
            prompts.append(FUSED_MAP_INSTRUCTION.format(tasks=tasks))
        raw = model.generate(prompts)
        columns = [["" for _ in records] for _ in lxs]
        for n, text in enumerate(raw):
            parsed: dict[int, str] = {}
            for line in str(text).splitlines():
                m = _FUSED_ANSWER_RE.match(line)
                if m and 1 <= int(m.group(1)) <= len(lxs):
                    parsed[int(m.group(1)) - 1] = m.group(2).strip()
            for i in range(len(lxs)):
                columns[i][n] = parsed.get(i, str(text).strip())
        st.details.update(fused=len(lxs))
        return columns, st.as_dict()


def _snap_to_source(answer: str, source: str) -> str:
    """Return the closest matching source substring (verified-quote contract)."""
    if answer and answer in source:
        return answer
    sm = difflib.SequenceMatcher(a=source, b=answer)
    m = sm.find_longest_match(0, len(source), 0, len(answer))
    return source[m.a: m.a + m.size] if m.size > 0 else ""


def sem_extract(records: list[dict], langex, model, *, source_field: str
                ) -> tuple[list[str], dict]:
    lx = as_langex(langex)
    with accounting.track("sem_extract") as st:
        prompts = [EXTRACT_INSTRUCTION.format(task=lx.template, item=lx.render(t))
                   for t in records]
        raw = model.generate(prompts)
        snapped = [_snap_to_source(a.strip(), str(t[source_field]))
                   for a, t in zip(raw, records)]
        st.details.update(verbatim=sum(1 for a, t in zip(raw, records)
                                       if a.strip() and a.strip() in str(t[source_field])))
        return snapped, st.as_dict()
