"""sem_map & sem_extract (§2.3, §4.2): row-wise natural-language projection.

sem_map generates an arbitrary text attribute; sem_extract restricts the
output to substrings of the source text (entity extraction / verified quotes
— generations that do not appear verbatim in the source are snapped to the
closest matching source span or dropped).
"""
from __future__ import annotations

import difflib

from repro.core import accounting
from repro.core.langex import as_langex

MAP_INSTRUCTION = "Task: {task}\nInput: {item}\nAnswer concisely.\nAnswer:"
EXTRACT_INSTRUCTION = ("Task: {task}\nSource text: {item}\n"
                       "Answer ONLY with an exact snippet copied from the source text.\nAnswer:")


def sem_map(records: list[dict], langex, model) -> tuple[list[str], dict]:
    lx = as_langex(langex)
    with accounting.track("sem_map") as st:
        prompts = [MAP_INSTRUCTION.format(task=lx.template, item=lx.render(t))
                   for t in records]
        return model.generate(prompts), st.as_dict()


def _snap_to_source(answer: str, source: str) -> str:
    """Return the closest matching source substring (verified-quote contract)."""
    if answer and answer in source:
        return answer
    sm = difflib.SequenceMatcher(a=source, b=answer)
    m = sm.find_longest_match(0, len(source), 0, len(answer))
    return source[m.a: m.a + m.size] if m.size > 0 else ""


def sem_extract(records: list[dict], langex, model, *, source_field: str
                ) -> tuple[list[str], dict]:
    lx = as_langex(langex)
    with accounting.track("sem_extract") as st:
        prompts = [EXTRACT_INSTRUCTION.format(task=lx.template, item=lx.render(t))
                   for t in records]
        raw = model.generate(prompts)
        snapped = [_snap_to_source(a.strip(), str(t[source_field]))
                   for a, t in zip(raw, records)]
        st.details.update(verbatim=sum(1 for a, t in zip(raw, records)
                                       if a.strip() and a.strip() in str(t[source_field])))
        return snapped, st.as_dict()
