"""sem_search, sem_sim_join, sem_index (§4.2): similarity-specialized
operators served by the retrieval layer (the equi-join analogues that expose
vector-search optimization opportunities to the engine).

All three go through the `RetrievalBackend` interface: ``index="exact"``
scans the full corpus (gold), ``index="ivf"`` prunes with the ANN inverted
file (recall knob: ``nprobe`` / ``recall_target``), ``index="auto"`` lets
the shared cost model decide.  Per-search retrieval cost (index kind,
probed clusters, scored vectors) lands in the op's accounting ``details``
so BENCH_*/serve metrics can attribute it.
"""
from __future__ import annotations

import numpy as np

from repro.core import accounting
from repro.index.backend import (MASKED_SCORE, RetrievalBackend, build_index,
                                 load_index)


def sem_index(texts: list[str], embedder, *, path: str | None = None,
              index: str = "exact", **index_kw) -> RetrievalBackend:
    """Embed ``texts`` and build a retrieval index over them.

    ``index`` picks the backend ("exact" | "ivf" | "auto"); ``index_kw``
    (n_clusters, nprobe, recall_target, ...) flows to the IVF build.  Both
    formats persist to ``path`` and come back via :func:`load_sem_index`.
    """
    with accounting.track("sem_index") as st:
        vectors = embedder.embed(texts)
        built = build_index(vectors, kind=index, **index_kw)
        st.details.update(index=built.kind, **{
            k: v for k, v in built.describe().items() if k != "kind"})
        if path:
            built.save(path)
        return built


def load_sem_index(path: str) -> RetrievalBackend:
    """Load a persisted sem_index of either format (kind in meta.json)."""
    return load_index(path)


def _record_retrieval(st, index: RetrievalBackend) -> None:
    st.details.update(index=index.kind,
                      scored_vectors=index.last_stats.get("scored_vectors", 0),
                      probed_clusters=index.last_stats.get("probed_clusters", 0))
    # dtype-aware byte accounting: int8 IVF tiles stream d+4 bytes per
    # scanned vector (plus fp32 rerank re-reads) vs 4d at full precision
    if "scanned_bytes" in index.last_stats:
        st.details.update(
            scanned_bytes=index.last_stats["scanned_bytes"],
            quantize=index.last_stats.get("quantize", "none"))
        if index.last_stats.get("reranked"):
            st.details.update(
                rerank_exact_rows=index.last_stats["reranked"])


def sem_search(index: RetrievalBackend, query: str, embedder, *, k: int = 10,
               n_rerank: int = 0, rerank_model=None, records=None,
               rerank_langex=None, max_pos: int | None = None
               ) -> tuple[list[int], dict]:
    """Top-k by embedding similarity; optional LLM re-ranking of the top-k
    down to ``n_rerank`` results (the advanced search path of §4.2).
    ``max_pos`` bounds hits to index positions < max_pos (the snapshot
    cutoff for version-pinned queries over a shared streaming index)."""
    with accounting.track("sem_search") as st:
        qv = embedder.embed([query])
        kw = {} if max_pos is None else {"max_pos": max_pos}
        scores, idx = index.search(qv, k, **kw)
        # unfilled slots (possible only under a max_pos cutoff racing a
        # retrain) carry the masked sentinel: drop them
        hits = [int(i) for i, s in zip(idx[0], scores[0]) if s > MASKED_SCORE / 2]
        _record_retrieval(st, index)
        n_rerank = min(n_rerank, k)  # can't re-rank more than we retrieved
        if n_rerank and rerank_model is not None and records is not None:
            from repro.core.operators.topk import sem_topk_quickselect
            sub = [records[i] for i in hits]
            order, _ = sem_topk_quickselect(sub, rerank_langex or "most relevant: {text}",
                                            n_rerank, rerank_model)
            hits = [hits[i] for i in order]
            st.details.update(reranked=n_rerank)
        return hits, st.as_dict()


def sem_sim_join(left_texts: list[str], right_index: RetrievalBackend, embedder,
                 *, k: int = 1, max_pos: int | None = None
                 ) -> tuple[np.ndarray, np.ndarray, dict]:
    """Left join: K most-similar right rows per left row (§4.2 Figure 4).

    Returns (scores [n1,k], indices [n1,k], stats); slots carrying the
    masked sentinel (possible only under a ``max_pos`` snapshot cutoff)
    must be skipped by the consumer."""
    with accounting.track("sem_sim_join") as st:
        emb_l = embedder.embed(left_texts)
        kw = {} if max_pos is None else {"max_pos": max_pos}
        scores, idx = right_index.search(emb_l, k, **kw)
        _record_retrieval(st, right_index)
        return scores, idx, st.as_dict()
