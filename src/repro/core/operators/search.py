"""sem_search, sem_sim_join, sem_index (§4.2): similarity-specialized
operators served by the vector index (the equi-join analogues that expose
vector-search optimization opportunities to the engine)."""
from __future__ import annotations

import numpy as np

from repro.core import accounting
from repro.index.vector_index import VectorIndex


def sem_index(texts: list[str], embedder, *, path: str | None = None) -> VectorIndex:
    with accounting.track("sem_index"):
        vectors = embedder.embed(texts)
        index = VectorIndex(vectors)
        if path:
            index.save(path)
        return index


def sem_search(index: VectorIndex, query: str, embedder, *, k: int = 10,
               n_rerank: int = 0, rerank_model=None, records=None,
               rerank_langex=None) -> tuple[list[int], dict]:
    """Top-k by embedding similarity; optional LLM re-ranking of the top-k
    down to ``n_rerank`` results (the advanced search path of §4.2)."""
    with accounting.track("sem_search") as st:
        qv = embedder.embed([query])
        _, idx = index.search(qv, k)
        hits = [int(i) for i in idx[0]]
        if n_rerank and rerank_model is not None and records is not None:
            from repro.core.operators.topk import sem_topk_quickselect
            sub = [records[i] for i in hits]
            order, _ = sem_topk_quickselect(sub, rerank_langex or "most relevant: {text}",
                                            n_rerank, rerank_model)
            hits = [hits[i] for i in order]
            st.details.update(reranked=n_rerank)
        return hits, st.as_dict()


def sem_sim_join(left_texts: list[str], right_index: VectorIndex, embedder,
                 *, k: int = 1) -> tuple[np.ndarray, np.ndarray, dict]:
    """Left join: K most-similar right rows per left row (§4.2 Figure 4).

    Returns (scores [n1,k], indices [n1,k], stats)."""
    with accounting.track("sem_sim_join") as st:
        emb_l = embedder.embed(left_texts)
        scores, idx = right_index.search(emb_l, k)
        return scores, idx, st.as_dict()
