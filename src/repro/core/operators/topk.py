"""sem_topk (§2.3, §3.4).

Gold algorithm: pairwise LLM comparisons aggregated by quick-select — each
round compares all remaining tuples to one pivot (fully batchable), then
recurses on the side containing rank k; the winning k are then ordered by
recursive quick-sort on the same comparator.

Alternatives implemented for the Table-7 study: quadratic all-pairs (Copeland
count) and a sequential heap top-k.

Optimization (lossless): similarity-guided pivot selection — the first pivot
is the (k+eps)-th item under embedding similarity to the ranking criteria;
under rank/similarity correlation this lands near the true k-boundary and
cuts comparison rounds; an adversarial pivot costs one extra round, never
quality (§3.4).
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core import accounting
from repro.core.langex import as_langex

COMPARE_INSTRUCTION = (
    "Criteria: {criteria}\nOption A: {a}\nOption B: {b}\n"
    "Which option better satisfies the criteria? Answer <A> or <B>.\nAnswer:")


def _render_item(lx, t) -> str:
    return lx.render(t)


def compare_prompt(lx, criteria_text, a, b) -> str:
    return COMPARE_INSTRUCTION.format(criteria=criteria_text, a=a, b=b)


class _Comparator:
    """Batched pairwise comparator with call accounting + cache.

    ``batch`` dedups within the batch before prompting: a repeated ``(i, j)``
    is asked once, and of a symmetric ``(i, j)`` / ``(j, i)`` pair only the
    first-seen orientation reaches the model (the mirror is derived by
    negation — asking both could sample *inconsistent* answers from a noisy
    comparator, and every redundant prompt is a real model call).

    Thread safety (one comparator is shared by the partitioned top-k's
    concurrent fragments): cache lookups and writes are lock-guarded, but
    the model call itself runs OUTSIDE the lock so fragments' compare
    batches genuinely overlap.  Two fragments racing on the same pair may
    both prompt it (the bounded stampede trade, as in BatchedModelCache);
    each writes both orientations atomically under the lock, so the cache
    can never hold an inconsistent (i,j)/(j,i) pair.
    """

    def __init__(self, records, langex, model):
        self.lx = as_langex(langex)
        self.texts = [_render_item(self.lx, t) for t in records]
        self.criteria = self.lx.template
        self.model = model
        self.cache: dict[tuple[int, int], bool] = {}
        self._lock = threading.Lock()

    def batch(self, pairs: list[tuple[int, int]]) -> np.ndarray:
        """pairs (i, j) -> bool[i beats j]."""
        with self._lock:
            todo: list[tuple[int, int]] = []
            queued: set[tuple[int, int]] = set()
            for i, j in pairs:
                if (i, j) in self.cache or (i, j) in queued or (j, i) in queued:
                    continue
                queued.add((i, j))
                todo.append((i, j))
        if todo:
            prompts = [compare_prompt(self.lx, self.criteria,
                                      self.texts[i], self.texts[j])
                       for i, j in todo]
            wins = self.model.compare(prompts)  # unlocked: fragments overlap
            with self._lock:
                for (i, j), w in zip(todo, wins):
                    self.cache[(i, j)] = bool(w)
                    self.cache[(j, i)] = not bool(w)
        with self._lock:
            # every requested pair is now either cached (possibly by a
            # racing fragment) or was in our own todo
            return np.asarray([self.cache[p] for p in pairs], bool)


def _order_topk(cmp: _Comparator, idx: list[int]) -> list[int]:
    """Order a small set by repeated pivot partitioning (quick-sort)."""
    if len(idx) <= 1:
        return list(idx)
    pivot = idx[len(idx) // 2]
    others = [i for i in idx if i != pivot]
    wins = cmp.batch([(i, pivot) for i in others])
    better = [i for i, w in zip(others, wins) if w]
    worse = [i for i, w in zip(others, wins) if not w]
    return _order_topk(cmp, better) + [pivot] + _order_topk(cmp, worse)


def _quickselect(cmp: _Comparator, candidates: list[int], k: int, rng,
                 *, pivot_scores=None, pivot_eps: int = 2
                 ) -> tuple[list[int], int]:
    """Pivot-partitioning selection of the (unordered) top-``k`` of
    ``candidates`` (global record indices) -> (top list, comparison rounds).
    Shared by the single-partition operator and the per-partition / merge
    phases of the partitioned one."""
    candidates = list(candidates)
    need = k
    top: list[int] = []
    rounds = 0
    first = True
    while candidates and need > 0:
        if len(candidates) <= need:
            top.extend(candidates)
            break
        if first and pivot_scores is not None:
            order = np.argsort(-np.asarray(pivot_scores)[candidates])
            pivot = candidates[order[min(need + pivot_eps - 1, len(candidates) - 1)]]
        else:
            pivot = candidates[rng.integers(len(candidates))]
        first = False
        rounds += 1
        others = [i for i in candidates if i != pivot]
        wins = cmp.batch([(i, pivot) for i in others])
        better = [i for i, w in zip(others, wins) if w]
        worse = [i for i, w in zip(others, wins) if not w]
        if len(better) + 1 == need:      # pivot is exactly rank `need`
            top.extend(better + [pivot])
            break
        if len(better) >= need:
            candidates = better
        else:
            top.extend(better + [pivot])
            need -= len(better) + 1
            candidates = worse
    return top, rounds


def sem_topk_quickselect(records, langex, k, model, *, pivot_scores=None,
                         pivot_eps: int = 2, seed: int = 0
                         ) -> tuple[list[int], dict]:
    """Returns (ordered indices of the top-k, stats).

    ``pivot_scores`` (e.g. embedding similarity to the criteria) enables the
    lossless §3.4 pivot optimization; None -> random pivots (gold algorithm).
    """
    with accounting.track("sem_topk") as st:
        cmp = _Comparator(records, langex, model)
        rng = np.random.default_rng(seed)
        top, rounds = _quickselect(cmp, list(range(len(records))), k, rng,
                                   pivot_scores=pivot_scores,
                                   pivot_eps=pivot_eps)
        ordered = _order_topk(cmp, top[:k] if len(top) >= k else top)
        st.details.update(rounds=rounds, pivot_guided=pivot_scores is not None)
        return ordered[:k], st.as_dict()


def sem_topk_partitioned(records, langex, k, model, partitions, *,
                         pivot_scores=None, pivot_eps: int = 2, seed: int = 0,
                         fragment_pool=None) -> tuple[list[int], dict]:
    """Partition-parallel quickselect with a lossless global merge.

    Each partition (a list of global record indices) runs quickselect for
    its own top-``k`` — fragments share ONE :class:`_Comparator`, so any
    pair judged twice (within a partition, then again during the merge) is
    answered from the cache.  The merge quickselects the union of partition
    winners: every true top-``k`` record beats its partition peers, so it is
    its partition's local winner and reaches the merge — under a consistent
    comparator the result is identical to the single-partition run's.
    """
    from repro.core.plan.parallel import run_fragments

    with accounting.track("sem_topk") as st:
        cmp = _Comparator(records, langex, model)

        def select(pi, part):
            def task():
                with accounting.track(f"fragment[{pi}]") as fst:
                    top, rounds = _quickselect(
                        cmp, list(part), min(k, len(part)),
                        np.random.default_rng((seed, pi)),
                        pivot_scores=pivot_scores, pivot_eps=pivot_eps)
                    fst.details.update(partition=pi, rows=len(part))
                    return top, rounds
            return task

        results = run_fragments(fragment_pool,
                                [select(pi, p) for pi, p in enumerate(partitions)])
        merged = [i for top, _ in results for i in top]
        top, merge_rounds = _quickselect(
            cmp, merged, min(k, len(merged)),
            np.random.default_rng((seed, len(partitions))),
            pivot_scores=pivot_scores, pivot_eps=pivot_eps)
        ordered = _order_topk(cmp, top[:k] if len(top) >= k else top)
        st.details.update(
            rounds=sum(r for _, r in results) + merge_rounds,
            merge_rounds=merge_rounds, merge_candidates=len(merged),
            n_partitions=len(partitions),
            pivot_guided=pivot_scores is not None)
        return ordered[:k], st.as_dict()


def sem_topk_quadratic(records, langex, k, model) -> tuple[list[int], dict]:
    """All-pairs comparisons, Copeland win-count ranking (Table 7 baseline)."""
    with accounting.track("sem_topk_quadratic") as st:
        cmp = _Comparator(records, langex, model)
        n = len(records)
        pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
        wins_flat = cmp.batch(pairs)
        wins = np.zeros(n)
        for (i, j), w in zip(pairs, wins_flat):
            wins[i if w else j] += 1
        order = np.argsort(-wins, kind="stable")
        return list(order[:k]), st.as_dict()


def sem_topk_heap(records, langex, k, model) -> tuple[list[int], dict]:
    """Sequential bounded min-heap (Table 7 baseline: fewer calls, no batching)."""
    import heapq

    with accounting.track("sem_topk_heap") as st:
        cmp = _Comparator(records, langex, model)

        class Item:
            __slots__ = ("i",)

            def __init__(self, i):
                self.i = i

            def __lt__(self, other):  # min-heap root = worst of the kept k
                return not cmp.batch([(self.i, other.i)])[0]

        heap: list[Item] = []
        for i in range(len(records)):
            if len(heap) < k:
                heapq.heappush(heap, Item(i))
            elif cmp.batch([(i, heap[0].i)])[0]:
                heapq.heapreplace(heap, Item(i))
        idx = [it.i for it in heap]
        ordered = _order_topk(cmp, idx)
        return ordered[:k], st.as_dict()
