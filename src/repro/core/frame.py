"""SemFrame: the LOTUS DataFrame-style public API (§4).

A SemFrame is a list of dict records plus a bound `Session` (oracle model,
optional proxy model, embedder).  Operators take a langex and optional
accuracy targets; passing targets engages the optimizer (cascades / proxy
plans / learned thresholds), omitting them runs the gold algorithm —
model-data independence in one switch.

    sess = Session(oracle=..., proxy=..., embedder=...)
    sf = SemFrame(records, sess)
    hits = sf.sem_filter("the {claim} is supported",
                         recall_target=0.9, precision_target=0.9, delta=0.2)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.backends.base import CountedEmbedder, CountedModel
from repro.core.langex import as_langex
from repro.core.operators import agg as _agg
from repro.core.operators import filter as _filter
from repro.core.operators import groupby as _groupby
from repro.core.operators import join as _join
from repro.core.operators import mapex as _mapex
from repro.core.operators import search as _search
from repro.core.operators import topk as _topk


@dataclasses.dataclass
class Session:
    oracle: Any
    proxy: Any | None = None
    embedder: Any | None = None
    default_delta: float = 0.2
    sample_size: int = 100
    seed: int = 0

    def __post_init__(self):
        self.oracle = CountedModel(self.oracle, "oracle")
        if self.proxy is not None:
            self.proxy = CountedModel(self.proxy, "proxy")
        if self.embedder is not None:
            self.embedder = CountedEmbedder(self.embedder)


class SemFrame:
    def __init__(self, records: Sequence[dict], session: Session,
                 stats_log: list | None = None):
        self.records = list(records)
        self.session = session
        self.stats_log = stats_log if stats_log is not None else []

    # -- plumbing ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self.records)

    def __getitem__(self, i):
        return self.records[i]

    @property
    def columns(self) -> set:
        return set(self.records[0].keys()) if self.records else set()

    def _child(self, records) -> "SemFrame":
        return SemFrame(records, self.session, self.stats_log)

    def _log(self, stats: dict) -> dict:
        self.stats_log.append(stats)
        return stats

    def last_stats(self) -> dict:
        return self.stats_log[-1] if self.stats_log else {}

    # -- sem_filter -------------------------------------------------------
    def sem_filter(self, langex, *, recall_target: float | None = None,
                   precision_target: float | None = None,
                   delta: float | None = None) -> "SemFrame":
        as_langex(langex).validate(self.columns)
        s = self.session
        if recall_target is None and precision_target is None:
            mask, stats = _filter.sem_filter_gold(self.records, langex, s.oracle)
        else:
            if s.proxy is None:
                raise ValueError("optimized sem_filter needs a proxy model in the Session")
            mask, stats = _filter.sem_filter_cascade(
                self.records, langex, s.oracle, s.proxy,
                recall_target=recall_target or 0.9,
                precision_target=precision_target or 0.9,
                delta=delta if delta is not None else s.default_delta,
                sample_size=s.sample_size, seed=s.seed)
        self._log(stats)
        return self._child([t for t, m in zip(self.records, mask) if m])

    # -- sem_join ---------------------------------------------------------
    def sem_join(self, other: "SemFrame | Sequence[dict]", langex, *,
                 recall_target: float | None = None,
                 precision_target: float | None = None,
                 delta: float | None = None, project_fn: Callable | None = None,
                 force_plan: str | None = None) -> "SemFrame":
        right = other.records if isinstance(other, SemFrame) else list(other)
        lx = as_langex(langex)
        lx.validate(self.columns, set(right[0].keys()) if right else set())
        s = self.session
        if recall_target is None and precision_target is None:
            mask, stats = _join.sem_join_gold(self.records, right, langex, s.oracle)
        else:
            if s.embedder is None:
                raise ValueError("optimized sem_join needs an embedder in the Session")
            mask, stats = _join.sem_join_cascade(
                self.records, right, langex, s.oracle, s.embedder,
                project_fn=project_fn,
                recall_target=recall_target or 0.9,
                precision_target=precision_target or 0.9,
                delta=delta if delta is not None else s.default_delta,
                sample_size=s.sample_size, seed=s.seed, force_plan=force_plan)
        self._log(stats)
        out = []
        n1, n2 = mask.shape
        for i in range(n1):
            for j in range(n2):
                if mask[i, j]:
                    out.append({**self.records[i],
                                **{f"right_{k}": v for k, v in right[j].items()}})
        return self._child(out)

    # -- sem_topk ---------------------------------------------------------
    def sem_topk(self, langex, k: int, *, algorithm: str = "quickselect",
                 pivot_query: str | None = None, group_by: str | None = None
                 ) -> "SemFrame":
        s = self.session
        if group_by is not None:
            groups: dict = {}
            for t in self.records:
                groups.setdefault(t[group_by], []).append(t)
            out = []
            for _, recs in sorted(groups.items(), key=lambda kv: str(kv[0])):
                sub = self._child(recs).sem_topk(langex, k, algorithm=algorithm,
                                                 pivot_query=pivot_query)
                out.extend(sub.records)
            return self._child(out)

        pivot_scores = None
        if pivot_query is not None and s.embedder is not None:
            lx = as_langex(langex)
            texts = [lx.render(t) for t in self.records]
            emb = s.embedder.embed(texts)
            qv = s.embedder.embed([pivot_query])[0]
            pivot_scores = emb @ qv
        fn = {"quickselect": _topk.sem_topk_quickselect,
              "quadratic": _topk.sem_topk_quadratic,
              "heap": _topk.sem_topk_heap}[algorithm]
        if algorithm == "quickselect":
            idx, stats = fn(self.records, langex, k, s.oracle,
                            pivot_scores=pivot_scores, seed=s.seed)
        else:
            idx, stats = fn(self.records, langex, k, s.oracle)
        self._log(stats)
        return self._child([self.records[i] for i in idx])

    # -- sem_agg ----------------------------------------------------------
    def sem_agg(self, langex, *, fanout: int = 8, group_by: str | None = None,
                partitioner=None):
        s = self.session
        if group_by is not None:
            out = {}
            for t in self.records:
                out.setdefault(t[group_by], []).append(t)
            return {g: self._child(recs).sem_agg(langex, fanout=fanout,
                                                 partitioner=partitioner)
                    for g, recs in out.items()}
        answer, stats = _agg.sem_agg_hierarchical(self.records, langex, s.oracle,
                                                  fanout=fanout, partitioner=partitioner)
        self._log(stats)
        return answer

    # -- sem_group_by -----------------------------------------------------
    def sem_group_by(self, langex, C: int, *, accuracy_target: float | None = None,
                     delta: float | None = None) -> "SemFrame":
        s = self.session
        if s.embedder is None:
            raise ValueError("sem_group_by needs an embedder in the Session")
        if accuracy_target is None:
            res = _groupby.sem_group_by_gold(self.records, langex, C,
                                             s.oracle, s.embedder, seed=s.seed)
        else:
            res = _groupby.sem_group_by_cascade(
                self.records, langex, C, s.oracle, s.embedder,
                accuracy_target=accuracy_target,
                delta=delta if delta is not None else s.default_delta,
                sample_size=s.sample_size, seed=s.seed)
        self._log(res.stats)
        out = [{**t, "group": int(g), "group_label": res.labels[int(g)]}
               for t, g in zip(self.records, res.assignment)]
        return self._child(out)

    # -- sem_map / sem_extract ---------------------------------------------
    def sem_map(self, langex, *, out_column: str = "mapped") -> "SemFrame":
        texts, stats = _mapex.sem_map(self.records, langex, self.session.oracle)
        self._log(stats)
        return self._child([{**t, out_column: x} for t, x in zip(self.records, texts)])

    def sem_extract(self, langex, *, source_field: str,
                    out_column: str = "extracted") -> "SemFrame":
        texts, stats = _mapex.sem_extract(self.records, langex, self.session.oracle,
                                          source_field=source_field)
        self._log(stats)
        return self._child([{**t, out_column: x} for t, x in zip(self.records, texts)])

    # -- similarity family --------------------------------------------------
    def sem_index(self, column: str, *, path: str | None = None):
        return _search.sem_index([str(t[column]) for t in self.records],
                                 self.session.embedder, path=path)

    def sem_search(self, column: str, query: str, *, k: int = 10,
                   n_rerank: int = 0, rerank_langex=None, index=None) -> "SemFrame":
        s = self.session
        index = index or self.sem_index(column)
        hits, stats = _search.sem_search(
            index, query, s.embedder, k=k, n_rerank=n_rerank,
            rerank_model=s.oracle if n_rerank else None,
            records=self.records, rerank_langex=rerank_langex)
        self._log(stats)
        return self._child([self.records[i] for i in hits])

    def sem_sim_join(self, other: "SemFrame | Sequence[dict]", left_col: str,
                     right_col: str, *, k: int = 1) -> "SemFrame":
        right = other.records if isinstance(other, SemFrame) else list(other)
        index = _search.sem_index([str(t[right_col]) for t in right],
                                  self.session.embedder)
        scores, idx, stats = _search.sem_sim_join(
            [str(t[left_col]) for t in self.records], index,
            self.session.embedder, k=k)
        self._log(stats)
        out = []
        for i, t in enumerate(self.records):
            for rank in range(idx.shape[1]):
                j = int(idx[i, rank])
                out.append({**t, **{f"right_{kk}": v for kk, v in right[j].items()},
                            "sim_score": float(scores[i, rank])})
        return self._child(out)
