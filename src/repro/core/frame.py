"""SemFrame: the LOTUS DataFrame-style public API (§4).

A SemFrame is a list of dict records plus a bound `Session` (oracle model,
optional proxy model, embedder).  Operators take a langex and optional
accuracy targets; passing targets engages the optimizer (cascades / proxy
plans / learned thresholds), omitting them runs the gold algorithm —
model-data independence in one switch.

    sess = Session(oracle=..., proxy=..., embedder=...)
    sf = SemFrame(records, sess)
    hits = sf.sem_filter("the {claim} is supported",
                         recall_target=0.9, precision_target=0.9, delta=0.2)

Execution is layered frame -> plan -> executor -> engine: every ``sem_*``
call builds a logical plan node (``repro.core.plan.nodes``).  The default
eager path auto-collects the node immediately through ``PlanExecutor`` with
no rewrites and no cache — call-for-call identical to classic eager
semantics.  ``sf.lazy()`` instead accumulates the whole pipeline as a DAG;
``collect()`` runs the rule-based optimizer (filter reordering/pushdown, map
fusion, sim-join prefilters) and executes with prompt-dedup batching:

    out = (sf.lazy()
             .sem_filter("the {claim} is checkable")
             .sem_join(labels, "the {claim} matches the {label:right}")
             .collect())
    print(sf.lazy().sem_filter(...).explain())
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from repro.core import accounting
from repro.core.backends.base import CountedEmbedder, CountedModel
from repro.core.langex import as_langex
from repro.core.operators import search as _search
from repro.core.plan import nodes as PN
from repro.core.plan.adaptive import (AdaptivePlanExecutor, AdaptivePolicy,
                                      adaptive_default)
from repro.core.plan.execute import PartitionedExecutor, PlanExecutor
from repro.core.plan.optimize import PlanOptimizer, explain_plan, total_cost


@dataclasses.dataclass
class Session:
    oracle: Any
    proxy: Any | None = None
    embedder: Any | None = None
    default_delta: float = 0.2
    sample_size: int = 100
    seed: int = 0

    def __post_init__(self):
        self.oracle = CountedModel(self.oracle, "oracle")
        if self.proxy is not None:
            self.proxy = CountedModel(self.proxy, "proxy")
        if self.embedder is not None:
            self.embedder = CountedEmbedder(self.embedder)


class SemFrame:
    def __init__(self, records: Sequence[dict], session: Session,
                 stats_log: list | None = None):
        self.records = list(records)
        self.session = session
        self.stats_log = stats_log if stats_log is not None else []

    # -- plumbing ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self.records)

    def __getitem__(self, i):
        return self.records[i]

    @property
    def columns(self) -> set:
        return set(self.records[0].keys()) if self.records else set()

    def _child(self, records) -> "SemFrame":
        return SemFrame(records, self.session, self.stats_log)

    def last_stats(self) -> dict:
        return self.stats_log[-1] if self.stats_log else {}

    def lazy(self) -> "LazySemFrame":
        """Switch to lazy plan building: sem_* calls accumulate a logical DAG
        that ``collect()`` optimizes and executes (``explain()`` to inspect)."""
        return LazySemFrame(PN.Scan(self.records), self.session, self.stats_log)

    def _execute(self, node: PN.LogicalNode) -> list[dict]:
        """Eager auto-collect: run one plan node, no rewrites, no cache."""
        return PlanExecutor(self.session, stats_log=self.stats_log).run(node)

    def _scan(self) -> PN.Scan:
        return PN.Scan(self.records)

    # -- sem_filter -------------------------------------------------------
    def sem_filter(self, langex, *, recall_target: float | None = None,
                   precision_target: float | None = None,
                   delta: float | None = None) -> "SemFrame":
        as_langex(langex).validate(self.columns)
        node = PN.Filter(self._scan(), langex, recall_target=recall_target,
                         precision_target=precision_target, delta=delta)
        return self._child(self._execute(node))

    # -- sem_join ---------------------------------------------------------
    def sem_join(self, other: "SemFrame | Sequence[dict]", langex, *,
                 recall_target: float | None = None,
                 precision_target: float | None = None,
                 delta: float | None = None, project_fn: Callable | None = None,
                 force_plan: str | None = None,
                 strategy: str | None = None) -> "SemFrame":
        right = other.records if isinstance(other, SemFrame) else list(other)
        lx = as_langex(langex)
        lx.validate(self.columns, set(right[0].keys()) if right else set())
        node = PN.Join(self._scan(), PN.Scan(right), langex,
                       recall_target=recall_target,
                       precision_target=precision_target, delta=delta,
                       project_fn=project_fn, force_plan=force_plan,
                       strategy=strategy)
        return self._child(self._execute(node))

    # -- sem_topk ---------------------------------------------------------
    def sem_topk(self, langex, k: int, *, algorithm: str = "quickselect",
                 pivot_query: str | None = None, group_by: str | None = None
                 ) -> "SemFrame":
        node = PN.TopK(self._scan(), langex, k, algorithm=algorithm,
                       pivot_query=pivot_query, group_by=group_by)
        return self._child(self._execute(node))

    # -- sem_agg ----------------------------------------------------------
    def sem_agg(self, langex, *, fanout: int = 8, group_by: str | None = None,
                partitioner=None):
        node = PN.Agg(self._scan(), langex, fanout=fanout, group_by=group_by,
                      partitioner=partitioner)
        rows = self._execute(node)
        if group_by is not None:
            return {row[group_by]: row["aggregate"] for row in rows}
        return rows[0]["aggregate"]

    # -- sem_group_by -----------------------------------------------------
    def sem_group_by(self, langex, C: int, *, accuracy_target: float | None = None,
                     delta: float | None = None) -> "SemFrame":
        node = PN.GroupBy(self._scan(), langex, C,
                          accuracy_target=accuracy_target, delta=delta)
        return self._child(self._execute(node))

    # -- sem_map / sem_extract ---------------------------------------------
    def sem_map(self, langex, *, out_column: str = "mapped") -> "SemFrame":
        node = PN.Map(self._scan(), langex, out_column=out_column)
        return self._child(self._execute(node))

    def sem_extract(self, langex, *, source_field: str,
                    out_column: str = "extracted") -> "SemFrame":
        node = PN.Extract(self._scan(), langex, source_field=source_field,
                          out_column=out_column)
        return self._child(self._execute(node))

    # -- similarity family --------------------------------------------------
    def sem_index(self, column: str, *, path: str | None = None,
                  index: str = "exact", **index_kw):
        """Build a retrieval index over a column ("exact" | "ivf" | "auto");
        ``index_kw`` (n_clusters, nprobe, recall_target, ...) tunes IVF."""
        return _search.sem_index([str(t[column]) for t in self.records],
                                 self.session.embedder, path=path,
                                 index=index, **index_kw)

    def sem_search(self, column: str, query: str, *, k: int = 10,
                   n_rerank: int = 0, rerank_langex=None, index=None,
                   index_kind: str = "exact", nprobe: int | None = None,
                   quantize: str | None = None) -> "SemFrame":
        """Eager search defaults to the exact index (classic semantics);
        pass ``index_kind="ivf"`` (or "auto") to opt into ANN retrieval,
        and ``quantize="int8"`` for int8 IVF tiles + exact rerank.  The
        lazy path's optimizer makes both choices cost-based instead."""
        node = PN.Search(self._scan(), column, query, k=k, n_rerank=n_rerank,
                         rerank_langex=rerank_langex, index=index,
                         index_kind=index_kind, nprobe=nprobe,
                         quantize=quantize)
        return self._child(self._execute(node))

    def sem_sim_join(self, other: "SemFrame | Sequence[dict]", left_col: str,
                     right_col: str, *, k: int = 1, index_kind: str = "exact",
                     nprobe: int | None = None, quantize: str | None = None
                     ) -> "SemFrame":
        right = other.records if isinstance(other, SemFrame) else list(other)
        node = PN.SimJoin(self._scan(), PN.Scan(right), left_col, right_col,
                          k=k, index_kind=index_kind, nprobe=nprobe,
                          quantize=quantize)
        return self._child(self._execute(node))


class LazySemFrame:
    """A logical plan under construction; same sem_* surface as SemFrame but
    nothing executes until ``collect()``.

    ``collect(optimize=True)`` runs the rewrite passes and executes with the
    ``BatchedModelCache`` (prompt dedup across all pipeline stages);
    ``collect(optimize=False)`` executes the plan as written with no cache —
    record- and stats-identical to the eager path.  ``explain()`` returns the
    before/after plan trees plus the applied rewrites.
    """

    def __init__(self, plan: PN.LogicalNode, session: Session,
                 stats_log: list | None = None):
        self.plan = plan
        self.session = session
        self.stats_log = stats_log if stats_log is not None else []
        self.last_rewrites: list = []
        self._exec_pair: tuple | None = None  # (opt_kw, optimizer, executor)

    # -- plumbing ---------------------------------------------------------
    @property
    def columns(self) -> set:
        return self.plan.columns()

    def _child(self, plan: PN.LogicalNode) -> "LazySemFrame":
        return LazySemFrame(plan, self.session, self.stats_log)

    def _right_plan(self, other) -> PN.LogicalNode:
        if isinstance(other, LazySemFrame):
            return other.plan
        if isinstance(other, SemFrame):
            return PN.Scan(other.records)
        return PN.Scan(list(other))

    # -- operators (plan builders) ----------------------------------------
    def sem_filter(self, langex, *, recall_target: float | None = None,
                   precision_target: float | None = None,
                   delta: float | None = None) -> "LazySemFrame":
        as_langex(langex).validate(self.columns)
        return self._child(PN.Filter(self.plan, langex,
                                     recall_target=recall_target,
                                     precision_target=precision_target,
                                     delta=delta))

    def sem_join(self, other, langex, *, recall_target: float | None = None,
                 precision_target: float | None = None,
                 delta: float | None = None, project_fn: Callable | None = None,
                 force_plan: str | None = None,
                 strategy: str | None = None) -> "LazySemFrame":
        right = self._right_plan(other)
        as_langex(langex).validate(self.columns, right.columns())
        return self._child(PN.Join(self.plan, right, langex,
                                   recall_target=recall_target,
                                   precision_target=precision_target,
                                   delta=delta, project_fn=project_fn,
                                   force_plan=force_plan,
                                   strategy=strategy))

    def sem_topk(self, langex, k: int, *, algorithm: str = "quickselect",
                 pivot_query: str | None = None,
                 group_by: str | None = None) -> "LazySemFrame":
        return self._child(PN.TopK(self.plan, langex, k, algorithm=algorithm,
                                   pivot_query=pivot_query, group_by=group_by))

    def sem_agg(self, langex, *, fanout: int = 8, group_by: str | None = None,
                partitioner=None) -> "LazySemFrame":
        return self._child(PN.Agg(self.plan, langex, fanout=fanout,
                                  group_by=group_by, partitioner=partitioner))

    def sem_group_by(self, langex, C: int, *,
                     accuracy_target: float | None = None,
                     delta: float | None = None) -> "LazySemFrame":
        return self._child(PN.GroupBy(self.plan, langex, C,
                                      accuracy_target=accuracy_target,
                                      delta=delta))

    def sem_map(self, langex, *, out_column: str = "mapped") -> "LazySemFrame":
        return self._child(PN.Map(self.plan, langex, out_column=out_column))

    def sem_extract(self, langex, *, source_field: str,
                    out_column: str = "extracted") -> "LazySemFrame":
        return self._child(PN.Extract(self.plan, langex,
                                      source_field=source_field,
                                      out_column=out_column))

    def sem_search(self, column: str, query: str, *, k: int = 10,
                   n_rerank: int = 0, rerank_langex=None, index=None,
                   index_kind: str = "auto", nprobe: int | None = None,
                   quantize: str | None = None) -> "LazySemFrame":
        return self._child(PN.Search(self.plan, column, query, k=k,
                                     n_rerank=n_rerank,
                                     rerank_langex=rerank_langex, index=index,
                                     index_kind=index_kind, nprobe=nprobe,
                                     quantize=quantize))

    def sem_sim_join(self, other, left_col: str, right_col: str, *,
                     k: int = 1, index_kind: str = "auto",
                     nprobe: int | None = None, quantize: str | None = None
                     ) -> "LazySemFrame":
        return self._child(PN.SimJoin(self.plan, self._right_plan(other),
                                      left_col, right_col, k=k,
                                      index_kind=index_kind, nprobe=nprobe,
                                      quantize=quantize))

    # -- optimize / execute ------------------------------------------------
    def _optimizer_and_executor(self, **opt_kw):
        """One (optimizer, executor) pair per frame+options: explain() and a
        later collect() share the BatchedModelCache, so selectivity probes
        are paid once, not once per call.

        ``n_partitions=`` opts into partition planning (fragments run
        serially unless ``fragment_workers`` > 1 adds a private pool);
        results are identical either way — partitioned execution preserves
        single-partition outputs by construction."""
        key = tuple(sorted(opt_kw.items()))
        if self._exec_pair is not None and self._exec_pair[0] == key:
            return self._exec_pair[1], self._exec_pair[2]
        if self._exec_pair is not None:  # new options: release the old
            self._exec_pair[2].close(wait=False)  # executor's fragment pool
        opt_kw = dict(opt_kw)
        fragment_workers = opt_kw.pop("fragment_workers", 0)
        # adaptive=True (or adaptive_policy=...) swaps in the mid-query
        # re-optimizing executor; the REPRO_ADAPTIVE env flips the default
        policy = opt_kw.pop("adaptive_policy", None)
        adaptive = opt_kw.pop("adaptive", None)
        if adaptive is None:
            adaptive = policy is not None or adaptive_default()
        matviews = opt_kw.pop("matviews", None)
        # the executor's "auto" index builds (join sim-prefilter) must obey
        # the same retrieval knobs the optimizer plans with; the stats store
        # feeds both the executor (observation) and optimizer (costing)
        exec_kw = {k: opt_kw[k]
                   for k in ("recall_target", "index_min_corpus",
                             "stats_store")
                   if k in opt_kw}
        if adaptive:
            executor = AdaptivePlanExecutor(
                self.session, stats_log=self.stats_log, use_cache=True,
                fragment_workers=fragment_workers, matviews=matviews,
                policy=policy if isinstance(policy, AdaptivePolicy) else None,
                **exec_kw)
        else:
            executor = PartitionedExecutor(
                self.session, stats_log=self.stats_log, use_cache=True,
                fragment_workers=fragment_workers, matviews=matviews,
                **exec_kw)
        optimizer = PlanOptimizer(self.session, oracle=executor.oracle,
                                  proxy=executor.proxy,
                                  seed=self.session.seed, **opt_kw)
        if adaptive:
            executor.optimizer = optimizer
        self._exec_pair = (key, optimizer, executor)
        return optimizer, executor

    def collect(self, *, optimize: bool = True, **opt_kw) -> SemFrame:
        if not optimize:
            records = PlanExecutor(self.session,
                                   stats_log=self.stats_log).run(self.plan)
            self.last_rewrites = []
            return SemFrame(records, self.session, self.stats_log)
        optimizer, executor = self._optimizer_and_executor(**opt_kw)
        # probe calls (selectivity sampling) are real model traffic: account
        # for them as their own pipeline stage — they flow through the
        # executor's cache, so execution re-uses every probed label
        with accounting.track("plan_optimize") as st:
            plan = optimizer.optimize(self.plan)
        st.details.update(rewrites=[str(r) for r in optimizer.applied])
        self.stats_log.append(st.as_dict())
        self.last_rewrites = optimizer.applied
        records = executor.run(plan)
        return SemFrame(records, self.session, self.stats_log)

    def explain(self, *, optimize: bool = True, **opt_kw) -> str:
        store = opt_kw.get("stats_store")
        out = ["== logical plan (as written) ==",
               explain_plan(self.plan, stats_store=store),
               f"-- estimated oracle calls: {total_cost(self.plan):.0f}"]
        if optimize:
            optimizer, _ = self._optimizer_and_executor(**opt_kw)
            with accounting.track("plan_explain") as st:
                plan = optimizer.optimize(self.plan)
            if st.lm_calls or st.cache_hits:  # probes are real model traffic
                self.stats_log.append(st.as_dict())
            out += ["", "== optimized plan ==",
                    explain_plan(plan, stats_store=store),
                    f"-- estimated oracle calls: {total_cost(plan):.0f}",
                    "", "== applied rewrites =="]
            out += [f" * {r}" for r in optimizer.applied] or [" (none)"]
        return "\n".join(out)
