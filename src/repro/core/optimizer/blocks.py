"""Block-prompted join oracles and transitivity-based verdict inference.

The gold join judges one candidate pair per prompt; Trummer's semantic-join
operators show that packing B pairs into one *structured* prompt and
propagating verdicts through an equivalence predicate's transitivity cuts
the oracle bill by orders of magnitude.  This module holds the three pieces
``sem_join_block`` composes:

  * :func:`build_block_prompt` / :func:`parse_block_response` — a numbered
    multi-pair prompt with a strict output contract (exactly one
    ``<number>: YES|NO`` line per candidate pair, in order) and a parser
    that returns ``None`` on *any* miscount, duplicate, gap, or unparseable
    verdict — a partial parse is never trusted;
  * :class:`BlockJudge` — the parse-validate-retry loop: all block prompts
    of a wave go to ``oracle.generate`` in one call (so the micro-batch
    dispatcher fuses them with concurrent sessions' blocks), malformed
    blocks are retried once with a stricter-format preamble, and blocks
    that still fail fall back to pairwise ``oracle.predicate`` judging —
    verdicts are never silently dropped or misaligned;
  * :class:`MatchInference` — union-find over confirmed matches of an
    equivalence predicate, with enemy edges between classes confirmed
    disjoint, so the verdict of a pair implied by transitivity is inferred
    without prompting (the oracle bill scales with match classes, not
    pairs);
  * :func:`detect_equivalence` — a conservative structural test on the
    calibration sample: positives must form consistent classes (no labeled
    negative inside a positive-connected component) across enough
    overlapping evidence before transitivity is trusted.
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

DEFAULT_BLOCK_SIZE = 16

_VERDICT_RE = re.compile(r"^\s*(\d+)\s*[.:)\-]\s*(yes|no|true|false|match|"
                         r"nomatch|no match)\b", re.IGNORECASE)
_TRUE_WORDS = ("yes", "true", "match")

_BLOCK_HEADER = (
    "You will judge several candidate pairs at once. Each numbered "
    "candidate pair below is an instance of the claim:\n  {template}\n")
_BLOCK_FOOTER = (
    "\nAnswer with exactly {n} lines, one per numbered candidate pair, in "
    "order. Each line must be '<number>: YES' if the claim holds for that "
    "pair or '<number>: NO' if it does not. No other text.\nAnswers:")
_STRICT_PREFIX = (
    "IMPORTANT: your previous answer could not be parsed. Follow the output "
    "format exactly — {n} lines, '<number>: YES' or '<number>: NO', "
    "nothing else.\n")


def blocking_k(n2: int) -> int:
    """Default per-left-row candidate block width from the right-side
    cardinality: wide enough that an embedding proxy with reasonable
    correlation covers the true matches, narrow enough that the candidate
    set stays O(n1*k) instead of O(n1*n2)."""
    return max(8, math.ceil(0.05 * max(int(n2), 1)))


def build_block_prompt(lx, left, right, pairs, *, strict: bool = False) -> str:
    """One structured prompt over ``pairs`` ([(i, j)] into left/right)."""
    lines = [_BLOCK_HEADER.format(template=lx.template)]
    if strict:
        lines.insert(0, _STRICT_PREFIX.format(n=len(pairs)))
    for k, (i, j) in enumerate(pairs, start=1):
        lines.append(f"{k}. {lx.render(left[i], right[j])}")
    lines.append(_BLOCK_FOOTER.format(n=len(pairs)))
    return "\n".join(lines)


def parse_block_response(text: str, n: int) -> list[bool] | None:
    """Parse a block response into ``n`` ordered verdicts.

    Returns ``None`` (the caller retries / falls back pairwise) when the
    response is truncated, has the wrong verdict count, repeats or skips a
    pair number, or contains an unparseable verdict line — a partial or
    ambiguous parse must never be silently aligned with the pairs."""
    if not text:
        return None
    verdicts: dict[int, bool] = {}
    for line in str(text).splitlines():
        if not line.strip():
            continue
        m = _VERDICT_RE.match(line)
        if m is None:
            continue  # chatter around the answers is tolerated; gaps are not
        k = int(m.group(1))
        if k < 1 or k > n or k in verdicts:
            return None  # out-of-range or duplicate pair id: misaligned
        verdicts[k] = m.group(2).lower() in _TRUE_WORDS
    if len(verdicts) != n:
        return None      # truncated or over-produced: wrong verdict count
    return [verdicts[k] for k in range(1, n + 1)]


@dataclasses.dataclass
class BlockJudgeStats:
    block_prompts: int = 0         # structured multi-pair prompts issued
    block_retries: int = 0         # blocks re-prompted with the strict form
    block_fallbacks: int = 0       # blocks that fell back to pairwise judging
    pairs_block_judged: int = 0    # pairs decided by a parsed block verdict
    pairs_fallback_judged: int = 0  # pairs decided by the pairwise fallback

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class BlockJudge:
    """Judge candidate pairs through block prompts with validate-retry and
    a pairwise fallback.  ``pair_prompt_fn(pairs) -> prompts`` renders the
    pairwise fallback prompts (the gold join's own prompt shape, so the
    fallback is exactly a pairwise judgment)."""

    def __init__(self, oracle, lx, left, right, pair_prompt_fn, *,
                 block_size: int = DEFAULT_BLOCK_SIZE, max_retries: int = 1):
        if block_size < 1:
            raise ValueError(f"block_size={block_size} (expected >= 1)")
        self.oracle = oracle
        self.lx = lx
        self.left = left
        self.right = right
        self.pair_prompt_fn = pair_prompt_fn
        self.block_size = int(block_size)
        self.max_retries = int(max_retries)
        self.stats = BlockJudgeStats()

    def judge_pairs(self, pairs) -> np.ndarray:
        """Verdicts for ``pairs`` in order; every pair gets exactly one."""
        pairs = [(int(i), int(j)) for i, j in pairs]
        out = np.zeros(len(pairs), bool)
        if not pairs:
            return out
        blocks = [(s, pairs[s:s + self.block_size])
                  for s in range(0, len(pairs), self.block_size)]
        pending = blocks
        for attempt in range(self.max_retries + 1):
            if not pending:
                break
            strict = attempt > 0
            prompts = [build_block_prompt(self.lx, self.left, self.right,
                                          blk, strict=strict)
                       for _, blk in pending]
            # one generate call per wave: the dispatcher fuses these block
            # prompts with blocks from concurrent sessions
            responses = self.oracle.generate(prompts)
            self.stats.block_prompts += len(prompts)
            if strict:
                self.stats.block_retries += len(prompts)
            failed = []
            for (start, blk), resp in zip(pending, responses):
                verdicts = parse_block_response(resp, len(blk))
                if verdicts is None:
                    failed.append((start, blk))
                    continue
                out[start:start + len(blk)] = verdicts
                self.stats.pairs_block_judged += len(blk)
            pending = failed
        if pending:
            # still-malformed blocks: judge every pair individually so no
            # verdict is dropped or misaligned
            flat = [(start, k, p) for start, blk in pending
                    for k, p in enumerate(blk)]
            passed, _ = self.oracle.predicate(
                self.pair_prompt_fn([p for _, _, p in flat]))
            for (start, k, _), v in zip(flat, np.asarray(passed, bool)):
                out[start + k] = bool(v)
            self.stats.block_fallbacks += len(pending)
            self.stats.pairs_fallback_judged += len(flat)
        return out


class MatchInference:
    """Transitivity closure for an equivalence join predicate.

    Union-find over the ``n_left + n_right`` records: a confirmed match
    unions the pair's classes, a confirmed non-match marks the two classes
    enemies.  ``implied(i, j)`` then answers without prompting whenever the
    verdict follows: True when both sides share a class, False when their
    classes are known-disjoint, None otherwise."""

    def __init__(self, n_left: int, n_right: int):
        self.n_left = int(n_left)
        self._parent = list(range(self.n_left + int(n_right)))
        self._rank = [0] * len(self._parent)
        self._enemies: dict[int, set[int]] = {}
        self.observed = 0
        self.inferred = 0

    def _find(self, x: int) -> int:
        root = x
        while self._parent[root] != root:
            root = self._parent[root]
        while self._parent[x] != root:       # path compression
            self._parent[x], x = root, self._parent[x]
        return root

    def _enemy_roots(self, root: int) -> set[int]:
        """Current enemy roots of ``root`` (re-normalized through unions)."""
        raw = self._enemies.get(root)
        if not raw:
            return set()
        norm = {self._find(e) for e in raw}
        norm.discard(root)
        self._enemies[root] = norm
        return norm

    def _union(self, a: int, b: int) -> None:
        ra, rb = self._find(a), self._find(b)
        if ra == rb:
            return
        if self._rank[ra] < self._rank[rb]:
            ra, rb = rb, ra
        self._parent[rb] = ra
        if self._rank[ra] == self._rank[rb]:
            self._rank[ra] += 1
        merged = self._enemy_roots(rb) | self._enemy_roots(ra)
        self._enemies.pop(rb, None)
        merged.discard(ra)
        if merged:
            self._enemies[ra] = merged
            for e in merged:
                self._enemies.setdefault(e, set()).add(ra)

    def implied(self, i: int, j: int) -> bool | None:
        ri, rj = self._find(int(i)), self._find(self.n_left + int(j))
        if ri == rj:
            return True
        if rj in self._enemy_roots(ri):
            return False
        return None

    def observe(self, i: int, j: int, verdict: bool) -> None:
        """Fold one oracle-judged pair into the closure."""
        a, b = int(i), self.n_left + int(j)
        self.observed += 1
        if verdict:
            self._union(a, b)
        else:
            ra, rb = self._find(a), self._find(b)
            if ra != rb:
                self._enemies.setdefault(ra, set()).add(rb)
                self._enemies.setdefault(rb, set()).add(ra)

    def resolve(self, i: int, j: int) -> bool | None:
        """``implied`` plus bookkeeping: counts an inference when the
        verdict came for free."""
        v = self.implied(i, j)
        if v is not None:
            self.inferred += 1
        return v

    def implied_matrix(self) -> np.ndarray:
        """Dense ``[n_left, n_right]`` grid of pairs implied *True* by the
        closure.  Two records imply a match iff they share a union-find
        root; singleton records (never unioned) imply nothing.  This is how
        the block join recovers *blocking misses*: a pair the candidate
        retrieval never surfaced still joins when transitivity settles it."""
        n_right = len(self._parent) - self.n_left
        lroots = np.fromiter((self._find(i) for i in range(self.n_left)),
                             dtype=np.int64, count=self.n_left)
        rroots = np.fromiter(
            (self._find(self.n_left + j) for j in range(n_right)),
            dtype=np.int64, count=n_right)
        return lroots[:, None] == rroots[None, :]

    def n_classes(self) -> int:
        """Distinct classes among records touched by at least one union."""
        roots = {self._find(x) for x in range(len(self._parent))
                 if self._parent[x] != x or self._rank[x] > 0}
        return len(roots)


def detect_equivalence(pairs, labels, *, min_evidence: int = 4) -> bool:
    """Conservative structural test for an equivalence predicate on the
    labeled calibration sample: positive matches must form consistent
    classes — no labeled *negative* pair may connect two records that the
    positive closure says are equivalent — and the sample must hold at
    least ``min_evidence`` overlapping pairs (pairs sharing a record with
    another labeled pair), otherwise there is no structure to test and
    transitivity stays off."""
    pairs = [(int(i), int(j)) for i, j in pairs]
    labels = np.asarray(labels, bool)
    if len(pairs) != len(labels):
        raise ValueError("pairs/labels length mismatch")
    left_seen: dict[int, int] = {}
    right_seen: dict[int, int] = {}
    for i, j in pairs:
        left_seen[i] = left_seen.get(i, 0) + 1
        right_seen[j] = right_seen.get(j, 0) + 1
    evidence = sum(1 for i, j in pairs
                   if left_seen[i] > 1 or right_seen[j] > 1)
    if evidence < min_evidence:
        return False
    n_left = max((i for i, _ in pairs), default=-1) + 1
    n_right = max((j for _, j in pairs), default=-1) + 1
    inf = MatchInference(n_left, n_right)
    for (i, j), v in zip(pairs, labels):
        if v:
            inf.observe(i, j, True)
    violations = sum(1 for (i, j), v in zip(pairs, labels)
                     if not v and inf.implied(i, j) is True)
    return violations == 0
