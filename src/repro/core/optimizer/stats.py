"""Statistical machinery for correct optimizations (§2.2, §3.1).

Follows SUPG (Kang et al., VLDB 2020 [37,38]) adapted to the paper's setting:
cascade thresholds on calibrated proxy scores with *both* a recall target
(RT, tau_minus) and a precision target (PT, tau_plus), each at failure budget
delta/2 (multiple-failure-mode correction of Algorithm 1), plus a Bonferroni
correction over the candidate-threshold grid (multiple hypothesis testing).

Estimators are self-normalized (Hajek) importance-weighted ratio estimators
with delta-method CLT standard errors:

    R(tau) = E[w o 1(A >= tau)] / E[w o]          (recall)
    P(tau) = E[w o 1(A >= tau)] / E[w 1(A >= tau)] (precision)

with w_j = 1 / (N p_j) for a with-replacement sample drawn from p.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
from scipy import stats as sps

DEFAULT_GRID = 64
# Correction for data-dependent threshold selection: recall(tau)/precision(tau)
# are monotone families over a fine grid, so adjacent tests are ~perfectly
# correlated; a full Bonferroni over 64 grid points is far too conservative.
# We charge a fixed effective-test count (validated empirically over repeated
# trials in tests/test_guarantees.py and benchmarks/fig9, mirroring the
# paper's own empirical Fig 9d validation).
EFFECTIVE_TESTS = 8
# Finite-sample (Wilson count) guard on ratio LCBs.  True = our default,
# strictly more conservative than the paper's CLT-only bounds (which can
# certify recall=1 from 2 heavy-weight positive observations when the
# empirical ratio variance collapses).  benchmarks/table3 flips it to
# reproduce the paper's operating point at extreme skew.
FINITE_SAMPLE_GUARD = True


def defensive_importance_probs(scores: np.ndarray, *, mix: float = 0.1,
                               power: float = 0.5) -> np.ndarray:
    """Draw probabilities p_i ∝ (1-mix)·A_i^power/Σ + mix·uniform.

    power=0.5 is SUPG's sqrt weighting (filters).  Joins use a sharper
    power: with quantile-calibrated scores the positive base rate over the
    N1*N2 pair space can be <<1%, and sqrt weighting would put only ~2
    positives in a 300-draw sample — the estimators stay *safe* (degenerate
    thresholds fall back to oracle-everything) but the plans get expensive;
    top-heavy sampling keeps them informative.  The Hajek weights absorb any
    proposal, so unbiasedness is unaffected."""
    s = np.power(np.clip(scores, 1e-9, None), power)
    p = (1.0 - mix) * s / s.sum() + mix / len(scores)
    return p / p.sum()


def importance_sample(rng: np.random.Generator, probs: np.ndarray, n: int) -> np.ndarray:
    """With-replacement sample of indices."""
    return rng.choice(len(probs), size=n, replace=True, p=probs)


@dataclasses.dataclass
class Sample:
    idx: np.ndarray        # sampled indices (with replacement) [s]
    probs: np.ndarray      # full-population draw probabilities [N]
    labels: np.ndarray     # oracle labels on sampled indices [s] (bool)
    scores: np.ndarray     # proxy scores on sampled indices [s]

    @property
    def weights(self) -> np.ndarray:
        n = len(self.probs)
        return 1.0 / (n * self.probs[self.idx])


def _wilson_lcb(p_hat: float, n_eff: float, alpha: float) -> float:
    """Wilson score lower bound — finite-sample guard for tiny effective n."""
    if n_eff <= 0:
        return 0.0
    z = sps.norm.ppf(1.0 - alpha)
    z2 = z * z
    centre = p_hat + z2 / (2 * n_eff)
    margin = z * math.sqrt(max(p_hat * (1 - p_hat) / n_eff + z2 / (4 * n_eff * n_eff), 0.0))
    return float((centre - margin) / (1 + z2 / n_eff))


def _ratio_lcb(num: np.ndarray, den: np.ndarray, alpha: float) -> float:
    """Lower confidence bound for E[num]/E[den] at level alpha.

    Delta-method CLT bound combined (min) with a Wilson bound at the Kish
    effective sample size of the denominator: when only a handful of heavy-
    weight positives are observed and ALL sit above the candidate threshold,
    the empirical ratio variance collapses to zero and the pure delta method
    would certify recall=1 from 2 observations — the Wilson term keeps the
    bound honest in that rare-positive regime (extreme-skew joins)."""
    s = len(num)
    mu_n, mu_d = num.mean(), den.mean()
    if mu_d <= 0:
        return 0.0
    r = mu_n / mu_d
    var_n = num.var(ddof=1) if s > 1 else 0.0
    var_d = den.var(ddof=1) if s > 1 else 0.0
    cov = np.cov(num, den, ddof=1)[0, 1] if s > 1 else 0.0
    var_r = max((var_n - 2 * r * cov + r * r * var_d) / (mu_d * mu_d), 0.0) / s
    z = sps.norm.ppf(1.0 - alpha)
    delta_lcb = r - z * math.sqrt(var_r)
    if not FINITE_SAMPLE_GUARD:
        return float(delta_lcb)
    n_obs = float(np.count_nonzero(den))  # observed relevant draws
    return float(min(delta_lcb, _wilson_lcb(min(r, 1.0), n_obs, alpha)))


def _candidate_grid(scores: np.ndarray, grid: int) -> np.ndarray:
    qs = np.unique(np.quantile(scores, np.linspace(0.0, 1.0, grid)))
    return qs


def rt_threshold(sample: Sample, gamma_r: float, delta: float,
                 *, grid: int = DEFAULT_GRID) -> float:
    """tau_minus: largest tau with LCB(recall(tau)) >= gamma_r w.p. 1-delta.

    Tuples with A < tau_minus are dropped by the cascade; everything else is
    either auto-accepted or oracle-labeled, so recall loss comes only from
    the dropped region. Fallback: -inf (drop nothing)."""
    w, o, a = sample.weights, sample.labels.astype(float), sample.scores
    cands = _candidate_grid(a, grid)
    alpha = delta / EFFECTIVE_TESTS
    best = -np.inf
    den = w * o
    if den.sum() <= 0:
        return -np.inf  # no positives observed: keep everything
    for tau in cands:
        num = w * o * (a >= tau)
        if _ratio_lcb(num, den, alpha) >= gamma_r:
            best = max(best, float(tau))
    return best


def pt_threshold(sample: Sample, gamma_p: float, delta: float,
                 *, grid: int = DEFAULT_GRID) -> float:
    """tau_plus: smallest tau with LCB(precision(tau)) >= gamma_p w.p. 1-delta.

    Tuples with A >= tau_plus are accepted without oracle confirmation; the
    oracle-confirmed region has precision 1 wrt the gold algorithm, so the
    output precision is bounded below by precision(tau_plus).
    Fallback: +inf (auto-accept nothing)."""
    w, o, a = sample.weights, sample.labels.astype(float), sample.scores
    cands = _candidate_grid(a, grid)
    alpha = delta / EFFECTIVE_TESTS
    best = np.inf
    for tau in cands:
        sel = (a >= tau).astype(float)
        if sel.sum() == 0:
            continue
        num = w * o * sel
        den = w * sel
        if _ratio_lcb(num, den, alpha) >= gamma_p:
            best = min(best, float(tau))
    return best


def shared_sample_indices(n: int, sample_size: int, seed: int,
                          scores: np.ndarray | None = None
                          ) -> tuple[np.ndarray, np.ndarray]:
    """One importance sample shared by every selectivity estimate in a plan.

    With proxy ``scores`` the draw is the defensive SUPG proposal; without, it
    is uniform.  Returns (idx [s] with replacement, probs [n]) so estimates
    stay Hajek-unbiased under either proposal.  Sharing one sample across all
    filters in a chain (rather than one per filter) is what lets the plan
    optimizer rank k predicates with a single oracle-labeled subset.
    """
    rng = np.random.default_rng(seed)
    if scores is not None:
        probs = defensive_importance_probs(np.asarray(scores, float))
    else:
        probs = np.full(n, 1.0 / n)
    s = min(sample_size, n)
    return importance_sample(rng, probs, s), probs


def estimate_selectivity(idx: np.ndarray, probs: np.ndarray,
                         labels: np.ndarray) -> float:
    """Hajek (self-normalized) selectivity estimate E[o] from a weighted
    sample: sum(w*o)/sum(w), clipped to (0, 1) open so downstream cost
    ranking never divides by zero."""
    w = 1.0 / (len(probs) * probs[idx])
    o = np.asarray(labels, float)
    est = float(np.sum(w * o) / max(np.sum(w), 1e-12))
    return float(np.clip(est, 1e-3, 1.0 - 1e-3))


def accuracy_threshold(scores: np.ndarray, correct: np.ndarray, gamma: float,
                       delta: float, *, grid: int = DEFAULT_GRID) -> float:
    """PT-style threshold on *classification accuracy* (sem_group_by §3.3):
    smallest tau such that accuracy among {A >= tau} >= gamma w.p. 1-delta,
    from a uniform sample. Fallback +inf (everything to the oracle)."""
    cands = _candidate_grid(scores, grid)
    alpha = delta / EFFECTIVE_TESTS
    best = np.inf
    c = correct.astype(float)
    for tau in cands:
        sel = scores >= tau
        n = int(sel.sum())
        if n == 0:
            continue
        acc = c[sel].mean()
        se = math.sqrt(max(acc * (1 - acc), 1e-12) / n)
        if acc - sps.norm.ppf(1 - alpha) * se >= gamma:
            best = min(best, float(tau))
    return best
