"""Algorithm 1 (SEM-FILTER) of the paper: proxy-oracle cascades with
statistical accuracy guarantees, plus the shared machinery reused by
sem_join (per-plan thresholds + cost-based plan choice) and sem_group_by.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.optimizer import stats
from repro.index.quantile import quantile_calibrate


@dataclasses.dataclass
class CascadeResult:
    passed: np.ndarray          # bool [N] — the optimized operator's output set
    tau_plus: float
    tau_minus: float
    oracle_calls: int           # unique oracle invocations (sample + mid region)
    sample_size: int
    auto_accepted: int
    auto_rejected: int
    oracle_region: int
    judged: np.ndarray | None = None  # bool [N] — rows decided by an oracle
                                      # label (mid region); auto-decisions
                                      # are ~judged (the audit population)


def run_cascade(proxy_scores: np.ndarray,
                oracle_fn: Callable[[np.ndarray], np.ndarray], *,
                recall_target: float, precision_target: float, delta: float,
                sample_size: int = 100, seed: int = 0,
                calibrate: bool = True) -> CascadeResult:
    """Algorithm 1. ``oracle_fn(indices) -> bool labels`` is the gold model
    M(t, l); ``proxy_scores`` are A(t) (calibrated to quantiles here unless
    already calibrated).

    The returned set satisfies recall >= recall_target AND precision >=
    precision_target w.r.t. the gold-algorithm output, each w.p. >= 1-delta/2
    (union bound: both w.p. >= 1-delta).
    """
    n = len(proxy_scores)
    a = quantile_calibrate(proxy_scores) if calibrate else np.asarray(proxy_scores, float)
    rng = np.random.default_rng(seed)
    s = min(sample_size, n)

    # -- sample + oracle labels -----------------------------------------
    probs = stats.defensive_importance_probs(a)
    idx = stats.importance_sample(rng, probs, s)
    uniq = np.unique(idx)
    labels_uniq = np.asarray(oracle_fn(uniq), bool)
    label_of = dict(zip(uniq.tolist(), labels_uniq.tolist()))
    sample = stats.Sample(idx=idx, probs=probs,
                          labels=np.asarray([label_of[i] for i in idx], bool),
                          scores=a[idx])

    # -- learn decision rule ---------------------------------------------
    tau_plus = stats.pt_threshold(sample, precision_target, delta / 2)
    tau_minus = stats.rt_threshold(sample, recall_target, delta / 2)
    tau_plus = max(tau_plus, tau_minus)

    # -- evaluate every tuple ---------------------------------------------
    passed = np.zeros(n, bool)
    auto = a >= tau_plus
    passed[auto] = True
    mid = (~auto) & (a >= tau_minus)
    # sampled tuples already have oracle labels — reuse, don't re-call
    known = np.zeros(n, bool)
    known[uniq] = True
    for i in uniq:
        if mid[i]:
            passed[i] = label_of[i]
    need = np.flatnonzero(mid & ~known)
    if len(need):
        passed[need] = np.asarray(oracle_fn(need), bool)

    return CascadeResult(
        passed=passed, tau_plus=float(tau_plus), tau_minus=float(tau_minus),
        oracle_calls=len(uniq) + len(need), sample_size=s,
        auto_accepted=int(auto.sum()), auto_rejected=int((a < tau_minus).sum()),
        oracle_region=int(mid.sum()), judged=mid.copy(),
    )


@dataclasses.dataclass
class PlanEstimate:
    name: str
    tau_plus: float
    tau_minus: float
    est_oracle_calls: int      # mid-region size (to evaluate) + sample already spent
    extra_lm_calls: int        # e.g. projection map calls for project-sim-filter
    scores: np.ndarray
    sample: stats.Sample
    label_of: dict

    @property
    def total_cost(self) -> int:
        return self.est_oracle_calls + self.extra_lm_calls


def estimate_plan(name: str, scores: np.ndarray, sample: stats.Sample,
                  label_of: dict, *, recall_target: float, precision_target: float,
                  delta: float, extra_lm_calls: int = 0) -> PlanEstimate:
    """Learn thresholds for one candidate plan and cost it (§3.2: the join
    optimizer learns (tau+, tau-) for each proxy and takes the cheaper plan)."""
    tau_plus = stats.pt_threshold(sample, precision_target, delta / 2)
    tau_minus = stats.rt_threshold(sample, recall_target, delta / 2)
    tau_plus = max(tau_plus, tau_minus)
    mid = (scores < tau_plus) & (scores >= tau_minus)
    return PlanEstimate(name=name, tau_plus=float(tau_plus), tau_minus=float(tau_minus),
                        est_oracle_calls=int(mid.sum()), extra_lm_calls=extra_lm_calls,
                        scores=scores, sample=sample, label_of=label_of)


@dataclasses.dataclass
class BlockCalibration:
    """Outcome of block-judging a calibration sample with a pairwise-gold
    agreement check (the guarantee machinery's bridge to block verdicts:
    thresholds are only calibrated on block labels that demonstrably track
    the pairwise oracle on this predicate)."""

    labels: np.ndarray         # bool [S] — final labels (block or pairwise)
    agreement: float           # block-vs-pairwise agreement on checked pairs
    checked: int               # pairs re-judged pairwise for the check
    blocks_rejudged: int       # calibration blocks whose agreement fell
                               # below the floor (all labels replaced)
    block_prompts: int
    block_fallbacks: int


def block_labeled_sample(pairs, block_judge, pairwise_fn, *, rng,
                         check_fraction: float = 0.25,
                         agreement_floor: float = 0.9) -> BlockCalibration:
    """Label a calibration sample of candidate ``pairs`` with block prompts,
    verifying each calibration block against pairwise gold.

    Every block contributes ``ceil(check_fraction * |block|)`` uniformly
    sampled pairs that are re-judged pairwise; a block whose checked labels
    agree below ``agreement_floor`` has *all* its labels replaced by
    pairwise judgments (the block oracle is not trusted for thresholds on
    that region).  ``pairwise_fn(pairs) -> bool array`` is the gold pairwise
    judge (it may serve cached labels)."""
    pairs = [(int(i), int(j)) for i, j in pairs]
    labels = np.asarray(block_judge.judge_pairs(pairs), bool).copy()
    bs = block_judge.block_size
    agree = checked = rejudged = 0
    for s in range(0, len(pairs), bs):
        blk = pairs[s:s + bs]
        n_check = min(len(blk), max(1, int(np.ceil(check_fraction * len(blk)))))
        pick = rng.choice(len(blk), size=n_check, replace=False)
        gold = np.asarray(pairwise_fn([blk[int(p)] for p in pick]), bool)
        ok = int((labels[s + pick] == gold).sum())
        agree += ok
        checked += n_check
        if ok / n_check < agreement_floor:
            # the block oracle disagrees with pairwise gold here: replace
            # the whole calibration block with pairwise labels
            labels[s:s + len(blk)] = np.asarray(pairwise_fn(blk), bool)
            rejudged += 1
    return BlockCalibration(
        labels=labels, agreement=(agree / checked if checked else 1.0),
        checked=checked, blocks_rejudged=rejudged,
        block_prompts=block_judge.stats.block_prompts,
        block_fallbacks=block_judge.stats.block_fallbacks)


def execute_plan(plan: PlanEstimate, oracle_fn: Callable[[np.ndarray], np.ndarray]) -> CascadeResult:
    """Run the cascade decision rule of an already-estimated plan."""
    a = plan.scores
    n = len(a)
    passed = np.zeros(n, bool)
    auto = a >= plan.tau_plus
    passed[auto] = True
    mid = (~auto) & (a >= plan.tau_minus)
    known = np.asarray(sorted(plan.label_of), int)
    for i in known:
        if mid[i]:
            passed[i] = plan.label_of[int(i)]
    known_mask = np.zeros(n, bool)
    if len(known):
        known_mask[known] = True
    need = np.flatnonzero(mid & ~known_mask)
    if len(need):
        passed[need] = np.asarray(oracle_fn(need), bool)
    return CascadeResult(passed=passed, tau_plus=plan.tau_plus, tau_minus=plan.tau_minus,
                         oracle_calls=len(known) + len(need), sample_size=len(plan.sample.idx),
                         auto_accepted=int(auto.sum()),
                         auto_rejected=int((a < plan.tau_minus).sum()),
                         oracle_region=int(mid.sum()), judged=mid)
