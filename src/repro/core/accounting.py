"""Per-operator cost accounting: oracle/proxy LM calls, embedding calls.

Every backend call is routed through the active ``OpStats`` so benchmarks can
report the paper's '# LM calls' columns exactly.

Two nesting levels:

  * ``track(operator)`` — one OpStats per operator invocation; nested
    operators roll up into their parent (unchanged single-query behavior).
  * ``session_scope(name)`` — a long-lived roll-up that accumulates every
    ``record()`` on this thread across *all* operator blocks, used by the
    serving gateway to report per-session totals while many sessions run
    concurrently (accounting state is thread-local, and each serve session
    executes on one worker thread).

Partition fragments are the one place a single operator's model calls span
threads: the partitioned executor captures the coordinating thread's
(operator, session) stats with ``capture()`` and re-installs them on each
fragment worker with ``activate()``, so per-partition calls roll up into the
same operator block and the same serve session.  Because several fragments
may then add into one shared OpStats concurrently, all cross-thread adds
(``record()`` and the ``track()`` roll-up) serialize on one module lock —
they are rare (per *batch*, not per prompt), so contention is noise.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time

from repro.obs import trace as _trace

_ctx = threading.local()
_add_lock = threading.Lock()  # guards adds into potentially shared OpStats


@dataclasses.dataclass
class OpStats:
    operator: str = ""
    oracle_calls: int = 0
    proxy_calls: int = 0
    embed_calls: int = 0
    compare_calls: int = 0
    generate_calls: int = 0
    audit_calls: int = 0   # gold re-judgments by the GuaranteeAuditor — a
                           # dedicated kind so query bills are bit-identical
                           # with auditing on or off
    cache_hits: int = 0    # prompts served by BatchedModelCache, not a model
    wall_s: float = 0.0
    details: dict = dataclasses.field(default_factory=dict)

    _KINDS = ("oracle", "proxy", "embed", "compare", "generate", "audit",
              "cache_hit")

    def add(self, kind: str, n: int) -> None:
        attr = "cache_hits" if kind == "cache_hit" else f"{kind}_calls"
        setattr(self, attr, getattr(self, attr) + n)

    @property
    def lm_calls(self) -> int:
        # every LM call is attributed to its wrapping role (oracle/proxy);
        # compare/generate are kept as per-kind breakdown columns of the same
        # traffic, so summing them here would double-count
        return self.oracle_calls + self.proxy_calls

    def as_dict(self) -> dict:
        return {
            "operator": self.operator, "oracle_calls": self.oracle_calls,
            "proxy_calls": self.proxy_calls, "embed_calls": self.embed_calls,
            "compare_calls": self.compare_calls, "generate_calls": self.generate_calls,
            "audit_calls": self.audit_calls, "cache_hits": self.cache_hits,
            "lm_calls": self.lm_calls, "wall_s": round(self.wall_s, 4), **self.details,
        }


def current() -> OpStats | None:
    return getattr(_ctx, "stats", None)


def current_session() -> OpStats | None:
    return getattr(_ctx, "session_stats", None)


def record(kind: str, n: int) -> None:
    st = current()
    sess = current_session()
    if st is None and sess is None:
        return
    with _add_lock:
        if st is not None:
            st.add(kind, n)
        if sess is not None:
            sess.add(kind, n)


def capture() -> tuple:
    """Snapshot this thread's accounting context (operator + session stats
    + trace context + active auditor) for re-installation on a fragment
    worker thread."""
    from repro.obs import audit as _audit
    return (current(), current_session(), _trace.capture(), _audit.capture())


@contextlib.contextmanager
def activate(ctx: tuple):
    """Install a captured context on the current thread (fragment workers);
    restores the thread's own context on exit, so pooled threads never leak
    one session's stats into the next."""
    from repro.obs import audit as _audit
    prev = (current(), current_session())
    _ctx.stats, _ctx.session_stats = ctx[0], ctx[1]
    trace_ctx = ctx[2] if len(ctx) > 2 else (None, None)
    auditor = ctx[3] if len(ctx) > 3 else None
    try:
        with _trace.activate_ctx(trace_ctx), _audit.activate_ctx(auditor):
            yield
    finally:
        _ctx.stats, _ctx.session_stats = prev


@contextlib.contextmanager
def track(operator: str):
    prev = current()
    st = OpStats(operator=operator)
    _ctx.stats = st
    t0 = time.monotonic()
    span_cm = _trace.span(
        operator,
        kind="fragment" if operator.startswith("fragment[") else "operator")
    sp = span_cm.__enter__()
    try:
        yield st
    finally:
        st.wall_s = time.monotonic() - t0
        sp.set(**st.as_dict())
        span_cm.__exit__(None, None, None)
        _ctx.stats = prev
        if prev is not None:  # nested operators roll up into the parent
            with _add_lock:   # the parent may be shared across fragments
                for kind in OpStats._KINDS:
                    prev.add(kind,
                             getattr(st, "cache_hits" if kind == "cache_hit"
                                     else f"{kind}_calls"))
                # numeric detail keys (scanned_bytes, rerank rows, ...)
                # merge additively instead of vanishing with the child
                for k, v in st.details.items():
                    if isinstance(v, (int, float)) and not isinstance(v, bool):
                        base = prev.details.get(k, 0)
                        if isinstance(base, (int, float)) \
                                and not isinstance(base, bool):
                            prev.details[k] = base + v
                    elif k not in prev.details:
                        prev.details[k] = v


@contextlib.contextmanager
def session_scope(name: str):
    """Accumulate every ``record()`` on this thread into one session-level
    OpStats, across any number of ``track()`` operator blocks.  ``track()``
    roll-ups bypass ``record()``, so each backend call lands in the session
    stats exactly once.  Scopes nest by shadowing (innermost wins)."""
    prev = current_session()
    st = OpStats(operator=f"session/{name}")
    _ctx.session_stats = st
    t0 = time.monotonic()
    try:
        yield st
    finally:
        st.wall_s = time.monotonic() - t0
        _ctx.session_stats = prev
