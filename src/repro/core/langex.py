"""Parameterized natural-language expressions ("langex", §2.1 of the paper).

A langex is a natural-language template over tuple attributes, e.g.

    "The {abstract} is about machine learning"                (sem_filter)
    "The paper {abstract:left} uses the {dataset:right}."     (sem_join)
    "the topic of each {paper}"                               (sem_group_by)

``Langex.render`` substitutes attribute values from one tuple (or a left/right
pair for joins).  Prompt *framing* (instructions, output-token contract) is
owned by the operators, not the langex — the langex is pure user intent.
"""
from __future__ import annotations

import dataclasses
import re

_FIELD_RE = re.compile(r"{([^{}:]+)(?::(left|right))?}")


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    side: str | None  # None | "left" | "right"


@dataclasses.dataclass(frozen=True)
class Langex:
    template: str
    # declared predicate structure: an equivalence predicate ("same entity",
    # "refer to the same X") is symmetric + transitive, so the block-join
    # path may propagate verdicts through transitivity without prompting.
    # Default False: undeclared predicates are only trusted after the
    # calibration-sample structure test (optimizer.blocks.detect_equivalence)
    equivalence: bool = False

    @property
    def fields(self) -> list[Field]:
        return [Field(m.group(1).strip(), m.group(2)) for m in _FIELD_RE.finditer(self.template)]

    @property
    def is_binary(self) -> bool:
        sides = {f.side for f in self.fields}
        return "left" in sides or "right" in sides

    def validate(self, columns, right_columns=None) -> None:
        for f in self.fields:
            cols = right_columns if f.side == "right" else columns
            if cols is not None and f.name not in cols:
                raise KeyError(f"langex field {{{f.name}}} not in columns {sorted(cols)}")

    def render(self, tup: dict, right: dict | None = None) -> str:
        def sub(m: re.Match) -> str:
            name, side = m.group(1).strip(), m.group(2)
            src = right if side == "right" else tup
            if src is None:
                raise ValueError(f"langex field {{{name}:{side}}} needs a right tuple")
            return str(src[name])

        return _FIELD_RE.sub(sub, self.template)


def as_langex(l: "str | Langex") -> Langex:
    return l if isinstance(l, Langex) else Langex(l)
