"""Synthetic bulk-semantic-processing workloads mirroring the paper's four
applications (FEVER fact-checking, BioDEX multilabel join, SciFact/HellaSwag
ranking, ArXiv topic analysis), built over SimulatedWorld truth tables.
"""
from __future__ import annotations

import numpy as np

from repro.core.backends.simulated import (SimConfig, SimulatedEmbedder,
                                           SimulatedModel, SimulatedWorld, tag)


def make_filter_world(n: int, *, positive_rate: float = 0.4,
                      proxy_alpha: float = 2.0, seed: int = 0,
                      cfg: SimConfig | None = None):
    """FEVER-like: claims, truth = supported/not. Returns (records, world,
    oracle, proxy, embedder)."""
    cfg = cfg or SimConfig(proxy_alpha=proxy_alpha)
    world = SimulatedWorld(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    records = []
    for i in range(n):
        rid = f"claim{i}"
        world.filter_truth[rid] = bool(rng.random() < positive_rate)
        records.append({"id": rid, "claim": f"claim text {i} {tag(rid)}"})
    oracle = SimulatedModel(world, "oracle")
    proxy = SimulatedModel(world, "proxy", alpha=proxy_alpha)
    return records, world, oracle, proxy, SimulatedEmbedder(world)


def add_phrase_predicate(world: SimulatedWorld, records: list[dict], phrase: str,
                         rate: float, *, seed: int = 0) -> None:
    """Attach an independent named predicate to an existing corpus: prompts
    containing ``phrase`` are true for each record w.p. ``rate`` (fixed per
    record).  Multiple phrases on one corpus give the plan optimizer filter
    chains with genuinely different selectivities."""
    import zlib
    rng = np.random.default_rng((seed, zlib.crc32(phrase.encode())))
    world.phrase_truth[phrase] = {t["id"]: bool(rng.random() < rate)
                                  for t in records}


def make_join_world(n_left: int, n_right: int, *, labels_per_left: int = 2,
                    sim_correlation: float = 0.8, seed: int = 0,
                    cfg: SimConfig | None = None):
    """BioDEX-like extreme multilabel: left articles x right labels; each
    article truly matches `labels_per_left` labels.  ``sim_correlation``
    controls whether raw article/label embeddings correlate with matches
    (the sim-filter regime) — at low correlation only the projected proxy
    works (the project-sim-filter regime, paper Table 5)."""
    cfg = cfg or SimConfig(sim_correlation=sim_correlation)
    world = SimulatedWorld(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    right = []
    for j in range(n_right):
        rid = f"label{j}"
        world.class_of[rid] = j % 8 if sim_correlation > 0 else j
        right.append({"id": rid, "reaction": f"reaction {j} {tag(rid)}"})
    left = []
    for i in range(n_left):
        lid = f"art{i}"
        matches = rng.choice(n_right, size=min(labels_per_left, n_right), replace=False)
        for j in matches:
            world.join_truth[(lid, f"label{j}")] = True
        # the article's latent topic matches its first true label's topic iff
        # similarity correlates with the predicate
        world.class_of[lid] = world.class_of[f"label{int(matches[0])}"] \
            if sim_correlation > 0 else 10_000 + i
        world.right_key_of[lid] = f"label{int(matches[0])}"
        left.append({"id": lid, "abstract": f"patient article {i} {tag(lid)}"})
    oracle = SimulatedModel(world, "oracle")
    proxy = SimulatedModel(world, "proxy")
    return left, right, world, oracle, proxy, SimulatedEmbedder(world)


def make_entity_world(n_left: int, n_right: int, n_classes: int, *,
                      sim_correlation: float = 0.85, seed: int = 0,
                      cfg: SimConfig | None = None):
    """Entity-resolution-like join with *equivalence* structure: every left
    and right record belongs to one of ``n_classes`` latent entities, and
    the join predicate is "same entity" — so matches are complete bipartite
    within a class and transitivity holds exactly (the regime where
    block-join verdict inference pays).  Embeddings correlate with the
    entity via ``sim_correlation``.  Returns
    (left, right, world, oracle, proxy, embedder)."""
    cfg = cfg or SimConfig(sim_correlation=sim_correlation)
    world = SimulatedWorld(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    right = []
    r_class = rng.integers(0, n_classes, size=n_right)
    for j in range(n_right):
        rid = f"ent{j}"
        world.class_of[rid] = int(r_class[j])
        right.append({"id": rid, "entity": f"entity record {j} {tag(rid)}"})
    left = []
    for i in range(n_left):
        lid = f"mention{i}"
        c = int(rng.integers(0, n_classes))
        world.class_of[lid] = c
        mates = [j for j in range(n_right) if int(r_class[j]) == c]
        for j in mates:
            world.join_truth[(lid, f"ent{j}")] = True
        if mates:
            world.right_key_of[lid] = f"ent{mates[0]}"
        left.append({"id": lid, "mention": f"mention {i} {tag(lid)}"})
    oracle = SimulatedModel(world, "oracle")
    proxy = SimulatedModel(world, "proxy")
    return left, right, world, oracle, proxy, SimulatedEmbedder(world)


def make_rank_world(n: int, *, compare_noise: float = 0.08, seed: int = 0,
                    topic_for_query: bool = True):
    """HellaSwag-bench-like: items with scalar ground-truth values; noisy
    pairwise comparisons; embedding similarity correlates with value so the
    §3.4 pivot optimization has signal."""
    cfg = SimConfig(compare_noise=compare_noise, sim_correlation=0.9)
    world = SimulatedWorld(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    records = []
    vals = rng.uniform(0, 1, n)
    for i in range(n):
        rid = f"doc{i}"
        world.rank_value[rid] = float(vals[i])
        # topic 0 center direction scaled by value -> similarity ~ value
        world.class_of[rid] = 0 if topic_for_query else i % 7
        records.append({"id": rid, "abstract": f"paper {i} accuracy {vals[i]:.3f} {tag(rid)}"})
    model = SimulatedModel(world, "oracle")
    embedder = SimulatedEmbedder(world)

    # pivot scores: similarity to query direction, correlated with value
    base = world.topic_center(0)
    noise = rng.normal(size=(n, cfg.dim)) * 0.2
    sim_scores = (vals[:, None] * base[None, :] + noise) @ base
    return records, world, model, embedder, np.asarray(sim_scores)


def make_topic_world(n: int, n_topics: int, *, label_noise: float = 0.1,
                     choose_acc: float = 0.95, sim_correlation: float = 0.85,
                     seed: int = 0):
    """ArXiv-like corpus with latent topics (sem_group_by ground truth)."""
    cfg = SimConfig(label_noise=label_noise, choose_acc=choose_acc,
                    sim_correlation=sim_correlation)
    world = SimulatedWorld(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    records = []
    for i in range(n):
        rid = f"paper{i}"
        world.class_of[rid] = int(rng.integers(n_topics))
        records.append({"id": rid, "paper": f"arxiv paper {i} {tag(rid)}"})
    model = SimulatedModel(world, "oracle")
    return records, world, model, SimulatedEmbedder(world)
