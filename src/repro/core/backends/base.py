"""Model-backend protocol consumed by the semantic operators.

The paper's world model M (oracle), proxy A, and embedder are all expressed
through this interface; `repro.engine.InferenceEngine` provides the real-model
implementation and `simulated.SimulatedBackend` the ground-truth-plus-noise
implementation used to validate the statistical machinery.
"""
from __future__ import annotations

from typing import Protocol, Sequence

import numpy as np

from repro.core import accounting


class PredicateModel(Protocol):
    def predicate(self, prompts: Sequence[str]) -> tuple[np.ndarray, np.ndarray]:
        """-> (bool [n], score [n] in [0,1]: P(True))."""


class GenerativeModel(PredicateModel, Protocol):
    def generate(self, prompts: Sequence[str]) -> list[str]: ...
    def compare(self, prompts: Sequence[str]) -> np.ndarray:
        """-> bool [n]: option A preferred."""
    def choose(self, prompts: Sequence[str], n_options: int) -> np.ndarray:
        """-> int [n] in [0, n_options)."""


class EmbeddingModel(Protocol):
    def embed(self, texts: Sequence[str]) -> np.ndarray:
        """-> unit vectors [n, d]."""


# ---------------------------------------------------------------------------
# Accounting wrappers — every operator talks to models through these.
# ---------------------------------------------------------------------------


class CountedModel:
    """Wraps a model, attributing calls to the active operator's OpStats.

    Every call kind is attributed to the wrapping role (oracle/proxy) so
    role-level counts cover generative ops too; generate/compare additionally
    keep their per-kind breakdown columns."""

    def __init__(self, model, role: str):
        assert role in ("oracle", "proxy", "audit")
        self._m = model
        self.role = role

    def predicate(self, prompts):
        accounting.record(self.role, len(prompts))
        return self._m.predicate(prompts)

    def generate(self, prompts):
        accounting.record(self.role, len(prompts))
        accounting.record("generate", len(prompts))
        return self._m.generate(prompts)

    def compare(self, prompts):
        accounting.record(self.role, len(prompts))
        accounting.record("compare", len(prompts))
        return self._m.compare(prompts)

    def choose(self, prompts, n_options):
        accounting.record(self.role, len(prompts))
        return self._m.choose(prompts, n_options)


class CountedEmbedder:
    def __init__(self, embedder):
        self._e = embedder

    @property
    def dim(self):
        return self._e.dim

    @property
    def index_key(self):
        """Identity of the backend model (index-registry sharing key)."""
        from repro.index.backend import embedder_key
        return embedder_key(self._e)

    def embed(self, texts):
        accounting.record("embed", len(texts))
        return self._e.embed(texts)
