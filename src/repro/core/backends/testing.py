"""Instrumented backend wrappers for tests and benchmarks.

``CountingBackend`` wraps any ``GenerativeModel`` and records every batch
that actually reaches it (arrival order, prompt counts), with an optional
content-keyed slow-down for exercising scheduling/cancellation paths.
Thread-safe: the serving gateway calls it from dispatcher threads.
"""
from __future__ import annotations

import threading
import time


class CountingBackend:
    def __init__(self, model, *, slow_marker: str | None = None,
                 slow_s: float = 0.0):
        self._m = model
        self.slow_marker = slow_marker
        self.slow_s = slow_s
        self.lock = threading.Lock()
        self.batches: list[list[str]] = []      # arrival order
        self.first_prompt = threading.Event()

    def _note(self, prompts) -> None:
        with self.lock:
            self.batches.append(list(prompts))
        self.first_prompt.set()
        if self.slow_marker and any(self.slow_marker in p for p in prompts):
            time.sleep(self.slow_s)

    @property
    def n_prompts(self) -> int:
        with self.lock:
            return sum(len(b) for b in self.batches)

    def saw(self, marker: str) -> bool:
        with self.lock:
            return any(marker in p for b in self.batches for p in b)

    # -- GenerativeModel protocol -----------------------------------------
    def predicate(self, prompts):
        self._note(prompts)
        return self._m.predicate(prompts)

    def generate(self, prompts):
        self._note(prompts)
        return self._m.generate(prompts)

    def compare(self, prompts):
        self._note(prompts)
        return self._m.compare(prompts)

    def choose(self, prompts, n_options):
        self._note(prompts)
        return self._m.choose(prompts, n_options)
