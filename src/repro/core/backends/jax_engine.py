"""Backend adapter: semantic operators over the real JAX serving stack.

Wires `repro.engine.InferenceEngine` (oracle / proxy LLMs served with
continuous batching + single-token predicate scoring) and
`repro.embed.Embedder` into the SemFrame Session — the full production
dataflow of the paper (vLLM + E5 in the original; our TPU-native substrate
here).  Used with randomly-initialized weights in integration tests: the
*plumbing* (prompt construction, log-prob proxy scores, cascade routing,
batched inference) is identical to a trained deployment.
"""
from __future__ import annotations


from repro.configs import ModelConfig, get_smoke
from repro.core.frame import Session
from repro.data.tokenizer import TOKENIZER
from repro.embed.encoder import E5_SMALL, Embedder
from repro.engine.engine import InferenceEngine


class EngineModel:
    """GenerativeModel protocol over an InferenceEngine."""

    def __init__(self, engine: InferenceEngine, *, max_new_tokens: int = 24):
        self.engine = engine
        self.max_new_tokens = max_new_tokens

    def predicate(self, prompts):
        return self.engine.predicate(list(prompts))

    def generate(self, prompts):
        return self.engine.generate(list(prompts), max_new_tokens=self.max_new_tokens)

    def compare(self, prompts):
        return self.engine.compare(list(prompts))

    def choose(self, prompts, n_options):
        return self.engine.choose(list(prompts), n_options)


def make_session(oracle_cfg: ModelConfig | None = None,
                 proxy_cfg: ModelConfig | None = None, *,
                 max_seq: int = 512, seed: int = 0, **session_kw) -> Session:
    """Build a full-JAX Session: oracle + proxy engines + encoder embedder.

    Defaults mirror the paper's pipeline shape at smoke scale: a larger
    oracle (llama-family) and a smaller proxy (the Llama-8B/TinyLlama role).
    """
    oracle_cfg = oracle_cfg or get_smoke("llama3.2-3b").with_(
        vocab_size=TOKENIZER.vocab_size, num_layers=4, d_model=128, d_ff=256)
    proxy_cfg = proxy_cfg or get_smoke("llama3.2-3b").with_(
        vocab_size=TOKENIZER.vocab_size, num_layers=2, d_model=64, d_ff=128)
    oracle = EngineModel(InferenceEngine(oracle_cfg, max_seq=max_seq, seed=seed))
    proxy = EngineModel(InferenceEngine(proxy_cfg, max_seq=max_seq, seed=seed + 1))
    embedder = Embedder(E5_SMALL.with_(num_layers=2, d_model=64, num_heads=4,
                                       num_kv_heads=4, d_ff=128), seed=seed + 2)
    return Session(oracle=oracle, proxy=proxy, embedder=embedder, **session_kw)
