"""Simulated oracle/proxy/embedder over synthetic worlds with known ground
truth and *controllable* noise.

No pretrained weights ship in this offline environment, so task accuracy on
FEVER/BioDEX is not reproducible — but the paper's contribution (gold
algorithms + cascade optimizations with statistical guarantees) is a claim
about *model-access patterns and statistics*, which this backend validates
exactly: the oracle realizes the gold algorithm's labels, proxies have
configurable quality (score separation alpha), embeddings have configurable
similarity/predicate correlation (the sim-filter vs project-sim-filter
regimes of §3.2), and comparisons flip with value-gap-dependent noise.

Records embed an id marker ("<rec:xyz>") in their text; the backend parses
ids out of rendered prompts to consult the world's truth tables, exactly as
a real model would read the tuple content.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re

import numpy as np

ID_RE = re.compile(r"<rec:([\w\-]+)>")


def _hash_rng(*parts) -> np.random.Generator:
    h = hashlib.blake2b("|".join(str(p) for p in parts).encode(), digest_size=8)
    return np.random.default_rng(int.from_bytes(h.digest(), "little"))


def _unit(v: np.ndarray) -> np.ndarray:
    return v / max(np.linalg.norm(v), 1e-9)


@dataclasses.dataclass
class SimConfig:
    dim: int = 32
    oracle_flip: float = 0.0       # oracle == gold algorithm by default
    proxy_alpha: float = 2.0       # proxy score separation (quality)
    proxy_seed: int = 7
    compare_noise: float = 0.1     # logistic noise scale on rank comparisons
    sim_correlation: float = 0.8   # emb-similarity vs join-truth correlation
    label_noise: float = 0.1       # candidate-label corruption (group-by)
    choose_acc: float = 0.95       # oracle classifier accuracy


class SimulatedWorld:
    """Truth tables the simulated models consult."""

    def __init__(self, cfg: SimConfig | None = None, seed: int = 0):
        self.cfg = cfg or SimConfig()
        self.seed = seed
        self.filter_truth: dict[str, bool] = {}
        # per-predicate truth: phrase (matched against the rendered prompt)
        # -> {record id -> bool}; lets one corpus carry several filters with
        # different selectivities (plan-optimizer workloads)
        self.phrase_truth: dict[str, dict[str, bool]] = {}
        self.join_truth: dict[tuple[str, str], bool] = {}
        self.rank_value: dict[str, float] = {}
        self.class_of: dict[str, int] = {}
        self.right_key_of: dict[str, str] = {}   # left id -> matching right id
        self.topic_centers: np.ndarray | None = None

    def topic_center(self, c: int) -> np.ndarray:
        if self.topic_centers is None or c >= len(self.topic_centers):
            n = max(c + 1, 8)
            rng = _hash_rng("topics", self.seed)
            self.topic_centers = np.stack([_unit(rng.normal(size=self.cfg.dim))
                                           for _ in range(n)])
        return self.topic_centers[c]


def tag(rid: str) -> str:
    return f"<rec:{rid}>"


class SimulatedModel:
    """PredicateModel + GenerativeModel against a SimulatedWorld.

    role='oracle' realizes the gold algorithm; role='proxy' is the cheap
    scorer with cfg.proxy_alpha quality."""

    def __init__(self, world: SimulatedWorld, role: str = "oracle", *,
                 alpha: float | None = None, flip: float | None = None,
                 seed: int = 1):
        self.w = world
        self.role = role
        self.alpha = alpha if alpha is not None else (
            1e9 if role == "oracle" else world.cfg.proxy_alpha)
        self.flip = flip if flip is not None else (
            world.cfg.oracle_flip if role == "oracle" else 0.0)
        self.seed = seed

    # -- truth lookup -----------------------------------------------------
    def _ids(self, prompt: str) -> list[str]:
        return ID_RE.findall(prompt)

    def _class_of(self, rid: str) -> int | None:
        if rid in self.w.class_of:
            return self.w.class_of[rid]
        if rid.startswith("label") and rid[5:].isdigit():
            return int(rid[5:])
        return None

    def _truth(self, prompt: str) -> bool:
        ids = self._ids(prompt)
        if len(ids) >= 2:
            for i in range(len(ids) - 1):
                if self.w.join_truth.get((ids[i], ids[i + 1])) or \
                   self.w.join_truth.get((ids[i + 1], ids[i])):
                    return True
            return False
        if ids:
            for phrase, table in self.w.phrase_truth.items():
                if phrase in prompt and ids[0] in table:
                    return bool(table[ids[0]])
            return bool(self.w.filter_truth.get(ids[0], False))
        return False

    # -- PredicateModel ----------------------------------------------------
    def predicate(self, prompts):
        out_b, out_s = [], []
        for p in prompts:
            t = self._truth(p)
            rng = _hash_rng("pred", self.role, self.seed, p)
            if self.flip and rng.random() < self.flip:
                t = not t
            logit = self.alpha * (1.0 if t else -1.0) + rng.normal()
            score = 1.0 / (1.0 + np.exp(-np.clip(logit, -30, 30)))
            out_b.append(score > 0.5)
            out_s.append(score)
        return np.asarray(out_b, bool), np.asarray(out_s, np.float32)

    # -- comparisons (sem_topk) --------------------------------------------
    def compare(self, prompts):
        out = []
        for p in prompts:
            ids = self._ids(p)
            va = self.w.rank_value.get(ids[0], 0.0) if ids else 0.0
            vb = self.w.rank_value.get(ids[1], 0.0) if len(ids) > 1 else 0.0
            rng = _hash_rng("cmp", self.seed, p)
            noise = self.w.cfg.compare_noise
            pa = 1.0 / (1.0 + np.exp(-np.clip((va - vb) / max(noise, 1e-6), -60, 60)))
            out.append(rng.random() < pa)
        return np.asarray(out, bool)

    def _block_verdicts(self, prompt: str) -> str:
        """Answer a numbered multi-pair join block prompt: one
        '<number>: YES/NO' line per numbered candidate-pair line, judged
        from join_truth with per-line flip noise."""
        lines_out = []
        for line in prompt.splitlines():
            m = re.match(r"\s*(\d+)\.\s", line)
            if not m:
                continue
            ids = ID_RE.findall(line)
            t = False
            for a in range(len(ids) - 1):
                if self.w.join_truth.get((ids[a], ids[a + 1])) or \
                   self.w.join_truth.get((ids[a + 1], ids[a])):
                    t = True
                    break
            rng = _hash_rng("blk", self.role, self.seed, line)
            if self.flip and rng.random() < self.flip:
                t = not t
            lines_out.append(f"{m.group(1)}: {'YES' if t else 'NO'}")
        return "\n".join(lines_out)

    # -- generation ---------------------------------------------------------
    def generate(self, prompts):
        out = []
        for p in prompts:
            ids = self._ids(p)
            rng = _hash_rng("gen", self.seed, p)
            if "numbered candidate pair" in p:
                out.append(self._block_verdicts(p))
            elif "category label" in p and ids:
                cls = [self._class_of(i) for i in ids]
                cls = [c for c in cls if c is not None]
                c = int(np.bincount(cls).argmax()) if cls else 0
                if rng.random() < self.w.cfg.label_noise:
                    c = int(rng.integers(0, max(self.w.class_of.values()) + 1))
                out.append(f"topic-{c} {tag(f'label{c}')}")
            elif "combined answer" in p:
                # aggregation: echo a canonical reduction over member ids,
                # preserving tags so deeper reduce levels keep provenance
                mids = sorted(set(ids))
                cls = [self._class_of(i) for i in mids]
                cls = [c for c in cls if c is not None]
                if cls and "category label" not in p:
                    c = int(np.bincount(cls).argmax())
                    out.append(f"topic-{c} {tag(f'label{c}')}")
                else:
                    out.append("summary(" + ",".join(tag(i) for i in mids[:8]) + ")")
            elif "missing right-hand field" in p and ids:
                # ungrounded projection: emit the true right key's tag (noisy)
                rid = self.w.right_key_of.get(ids[0])
                if rid is None or rng.random() < self.w.cfg.label_noise:
                    cands = list(self.w.right_key_of.values()) or ["none"]
                    rid = cands[int(rng.integers(len(cands)))]
                out.append(f"predicted {tag(rid)}")
            else:
                out.append("ok " + " ".join(tag(i) for i in ids[:2]))
        return out

    def choose(self, prompts, n_options):
        """Classification against the categories *shown in the prompt*: the
        answer is the index of the listed category whose latent class matches
        the item's class (as a real model would pick among the options)."""
        out = []
        for p in prompts:
            rng = _hash_rng("choose", self.seed, p)
            item_id = None
            cats: list[tuple[int, str]] = []
            for line in p.splitlines():
                m = re.match(r"\s*(\d+)\.\s", line)
                ids = ID_RE.findall(line)
                if m and ids:
                    cats.append((int(m.group(1)), ids[0]))
                elif ids and item_id is None and not m:
                    item_id = ids[0]
            c = 0
            if item_id is not None and cats:
                want = self._class_of(item_id)
                match = [i for i, cid in cats if self._class_of(cid) == want]
                c = match[0] if match else int(rng.integers(n_options))
            if rng.random() > self.w.cfg.choose_acc:
                c = int(rng.integers(n_options))
            out.append(min(c, n_options - 1))
        return np.asarray(out, int)


class SimulatedEmbedder:
    """Deterministic text -> unit vector with topic structure.

    Texts containing a record tag embed near their record's topic center
    (or the record-specific latent for join keys), with correlation
    cfg.sim_correlation; unknown text hashes to a random direction."""

    def __init__(self, world: SimulatedWorld, *, seed: int = 3):
        self.w = world
        self.seed = seed
        self._latent: dict[str, np.ndarray] = {}

    @property
    def dim(self) -> int:
        return self.w.cfg.dim

    def _class(self, rid: str) -> int | None:
        if rid in self.w.class_of:
            return self.w.class_of[rid]
        if rid.startswith("label") and rid[5:].isdigit():
            return int(rid[5:])        # canonical label ids carry their class
        return None

    def latent(self, rid: str) -> np.ndarray:
        if rid not in self._latent:
            cls = self._class(rid)
            if cls is not None:
                base = self.w.topic_center(cls)
                rng = _hash_rng("lat", self.seed, rid)
                corr = self.w.cfg.sim_correlation
                v = corr * base + (1 - corr) * rng.normal(size=self.dim) * 0.5
            else:
                v = _hash_rng("lat", self.seed, rid).normal(size=self.dim)
            self._latent[rid] = _unit(v)
        return self._latent[rid]

    def embed(self, texts):
        out = []
        for t in texts:
            ids = ID_RE.findall(t)
            if ids:
                v = np.mean([self.latent(i) for i in ids], axis=0)
                out.append(_unit(v))
            else:
                out.append(_unit(_hash_rng("txt", self.seed, t).normal(size=self.dim)))
        return np.stack(out).astype(np.float32)
