"""Semantic materialized views: multi-query subplan sharing.

The dispatcher dedups per *prompt*; the :class:`IndexRegistry` dedups per
*index build*.  This registry extends the same idea to whole subplans: a
plan fingerprint normalizes an operator subtree's semantic payload
(predicate templates + knobs) down to its leaves (a content hash for Scan,
``table@version`` for StreamScan), so two concurrent sessions running the
same filter over the same corpus version detect the overlap, latch exactly
one computation, and the rest serve from the materialization.

Fingerprints are *transparent* through Partition/Exchange wrappers — the IR
contract says fragmentation never changes results, so a partitioned and an
unpartitioned session over the same subplan share one view.  Anything whose
semantics can't be hashed (user callables, pinned index objects) poisons
its subtree to None and never materializes.

Same win-or-wait protocol as the index registry: losers poll the winner's
latch and run their session's ``wait_hook`` between polls so cancellation /
deadline checks still fire while blocked on someone else's computation.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict

from repro.core.plan import nodes as N

# operators worth materializing: deterministic given their fingerprint
# (model calls ride the seeded sample / cache machinery, so the same
# fingerprint implies the same rows)
_MATERIALIZABLE = {"Filter", "Join", "SimJoin", "Search", "TopK", "Agg",
                   "GroupBy", "Map", "FusedMap", "Extract"}

# annotations that never change results (cost/layout hints): two plans that
# differ only here must share a view
_SKIP_FIELDS = {"selectivity", "shards", "index_auto"}

_SCAN_SAMPLE_CAP = 20_000  # rows hashed in full below this


def _scan_token(records) -> str:
    """Content hash of a Scan's rows.  Above the cap, a head/tail/stride
    sample plus the count — cheap, and a collision additionally needs equal
    length and equal sampled rows."""
    h = hashlib.sha1()
    n = len(records)
    h.update(str(n).encode())
    if n <= _SCAN_SAMPLE_CAP:
        rows = records
    else:
        stride = max(n // 512, 1)
        rows = list(records[:64]) + list(records[-64:]) \
            + [records[i] for i in range(64, n - 64, stride)]
    for row in rows:
        h.update(b"\x1e")
        h.update(repr(sorted(row.items())).encode())
    return f"scan:{h.hexdigest()[:20]}"


def _node_token(node) -> str | None:
    """This node's own contribution to the fingerprint, or None when its
    semantics aren't hashable (poisons the subtree)."""
    cls = type(node).__name__
    if cls == "Scan":
        return _scan_token(node.records)
    if cls == "StreamScan":
        v = node.version if node.version is not None else node.table.version
        return f"stream:{node.table.table_id}@v{v}"
    if cls not in _MATERIALIZABLE:
        return None
    import dataclasses
    parts = [cls]
    for f in dataclasses.fields(node):
        v = getattr(node, f.name)
        if f.name in _SKIP_FIELDS or v is None or isinstance(v, N.LogicalNode):
            continue
        template = getattr(v, "template", None)
        if template is not None:  # a Langex: its semantics are the template
            parts.append(f"{f.name}={template}")
        elif isinstance(v, (tuple, list)) \
                and any(getattr(x, "template", None) for x in v):
            parts.append(f"{f.name}=" + "|".join(
                str(getattr(x, "template", x)) for x in v))
        elif callable(v) or f.name == "index":
            return None  # user code / pinned index object: unshareable
        else:
            parts.append(f"{f.name}={v!r}")
    return "\x1f".join(parts)


def plan_fingerprint(node, memo: dict | None = None) -> str | None:
    """Stable fingerprint of a subplan's semantics, or None when any node in
    it is unshareable.  Partition/Exchange are transparent (same key with
    and without fragmentation); ``memo`` (id -> fp) amortizes re-walks."""
    if isinstance(node, (N.Partition, N.Exchange)):
        return plan_fingerprint(node.child, memo)
    if memo is not None and id(node) in memo:
        return memo[id(node)]
    tok = _node_token(node)
    fp = None
    if tok is not None:
        child_fps = [plan_fingerprint(c, memo) for c in node.children()]
        if all(f is not None for f in child_fps):
            fp = hashlib.sha1(
                "\x1d".join([tok] + child_fps).encode()).hexdigest()[:20]
    if memo is not None:
        memo[id(node)] = fp
    return fp


class MatViewRegistry:
    """Process-wide materialized subplan results, LRU-bounded.

    ``get_or_compute`` is the whole protocol: the first session to ask for
    a key computes it (the build latch makes it exactly one, however many
    sessions race); everyone else blocks on the latch — running their
    ``wait_hook`` so cancellation still fires — and serves the rows.  A
    failed winner releases the latch without installing, so losers re-race
    instead of caching the exception.
    """

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._views: OrderedDict[str, list[dict]] = OrderedDict()
        self._building: dict[str, threading.Event] = {}
        self.builds = 0
        self.hits = 0
        self.evictions = 0
        self.rows_served = 0

    def key_for(self, node, memo: dict | None = None) -> str | None:
        """Materialization key for a plan node: None for leaves (a scan
        costs nothing to re-run) and unshareable subtrees."""
        inner = N.plain(node)
        if type(inner).__name__ not in _MATERIALIZABLE:
            return None
        return plan_fingerprint(node, memo)

    def get_or_compute(self, key: str, compute, *, wait_hook=None):
        """Returns ``(rows, hit)``; rows are a fresh list so callers never
        alias the stored materialization."""
        while True:
            with self._lock:
                if key in self._views:
                    self._views.move_to_end(key)
                    rows = self._views[key]
                    self.hits += 1
                    self.rows_served += len(rows)
                    return list(rows), True
                latch = self._building.get(key)
                if latch is None:
                    latch = self._building[key] = threading.Event()
                    break  # this caller is the winner
            # loser: poll so the session's cancellation hook keeps firing
            while not latch.wait(0.02):
                if wait_hook is not None:
                    wait_hook(None)
        try:
            rows = list(compute())
            with self._lock:
                self._views[key] = rows
                self._views.move_to_end(key)
                self.builds += 1
                while len(self._views) > self.capacity:
                    self._views.popitem(last=False)
                    self.evictions += 1
            return list(rows), False
        finally:
            with self._lock:
                self._building.pop(key, None)
            latch.set()

    def metrics(self) -> dict:
        with self._lock:
            return {"matview_builds": self.builds,
                    "matview_hits": self.hits,
                    "matview_evictions": self.evictions,
                    "matviews_resident": len(self._views),
                    "matview_rows_served": self.rows_served}

    def clear(self) -> None:
        with self._lock:
            self._views.clear()
            self.builds = self.hits = self.evictions = self.rows_served = 0
