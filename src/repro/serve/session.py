"""ServeSession: one submitted semantic pipeline moving through the gateway.

A session is created by ``Gateway.submit()`` with a logical plan, waits in
the admission queue (FIFO within its tenant, round-robin across tenants),
executes on one worker thread, and resolves to its output records.  The
handle doubles as a future: ``result()`` blocks, ``cancel()`` requests
cooperative cancellation (honored between pipeline stages via the executor's
``stage_hook`` yield points, and immediately for still-queued sessions), and
``deadline_s`` bounds the *end-to-end* wall clock from submission — a
session that waits out its deadline in the queue expires without ever
touching a model.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

from repro.core.accounting import OpStats

PENDING = "pending"
RUNNING = "running"
DONE = "done"
FAILED = "failed"
CANCELLED = "cancelled"
EXPIRED = "expired"


class SessionCancelled(RuntimeError):
    pass


class SessionDeadlineExceeded(RuntimeError):
    pass


@dataclasses.dataclass
class ServeSession:
    sid: str
    plan: Any
    tenant: str = "default"
    optimize: bool = True
    deadline_s: float | None = None
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    status: str = PENDING
    records: list | None = None
    error: BaseException | None = None
    stats: OpStats | None = None          # per-session accounting roll-up
    stats_log: list = dataclasses.field(default_factory=list)
    # mid-query re-plan decisions (adaptive executor), as plain dicts
    replans: list = dataclasses.field(default_factory=list)
    started_at: float | None = None
    finished_at: float | None = None
    _cancel: threading.Event = dataclasses.field(default_factory=threading.Event)
    _done: threading.Event = dataclasses.field(default_factory=threading.Event)

    # -- control -----------------------------------------------------------
    def cancel(self) -> None:
        self._cancel.set()

    @property
    def cancel_requested(self) -> bool:
        return self._cancel.is_set()

    def check(self) -> None:
        """Raise if this session should stop — the stage_hook yield point."""
        if self._cancel.is_set():
            raise SessionCancelled(self.sid)
        if self.deadline_s is not None and \
                time.monotonic() - self.submitted_at > self.deadline_s:
            raise SessionDeadlineExceeded(self.sid)

    # -- future protocol ---------------------------------------------------
    def wait(self, timeout: float | None = None) -> bool:
        return self._done.wait(timeout)

    def result(self, timeout: float | None = None) -> list[dict]:
        if not self._done.wait(timeout):
            raise TimeoutError(f"session {self.sid} still {self.status}")
        if self.status == DONE:
            return self.records
        if self.error is not None:
            raise self.error
        raise RuntimeError(f"session {self.sid} ended as {self.status}")

    # -- bookkeeping (gateway side) ----------------------------------------
    def finish(self, status: str, *, records: list | None = None,
               error: BaseException | None = None) -> None:
        self.status = status
        self.records = records
        self.error = error
        self.finished_at = time.monotonic()
        self._done.set()

    @property
    def latency_s(self) -> float | None:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def summary(self) -> dict:
        out = {"sid": self.sid, "tenant": self.tenant, "status": self.status,
               "rows": len(self.records) if self.records is not None else None,
               "latency_s": self.latency_s}
        if self.replans:
            out["replans"] = len(self.replans)
        if self.stats is not None:
            out["stats"] = self.stats.as_dict()
        return out
