"""MicroBatchDispatcher: cross-query fusion of model calls.

Every in-flight session executes its plan on its own worker thread, but all
of their oracle/proxy/embed traffic funnels through one dispatcher.  Calls of
the same (role, kind, extra) shape are parked in a bucket; a background
thread flushes a bucket when its oldest entry has waited ``window_s`` or its
unique-prompt count reaches ``max_batch``, deduplicates prompts across the
parked calls, consults the shared semantic store, and issues **one** fused
backend call for the remainder.  Over the real-engine path the fused batch
lands on ``InferenceEngine``'s ``ContinuousBatchScheduler`` as a single
admission wave — decode slots stay full instead of draining per query.

Accounting stays per-session even though the backend call happens on the
dispatcher thread: the dispatcher computes, for each parked call, how many
unique prompts it *owned* (was first to request and went to the backend) and
how many were shared/cached, and the caller-side ``DispatchedModel`` records
those on its own thread — where the session's OpStats live.

``DispatchedModel`` / ``DispatchedEmbedder`` are protocol-compatible with
``GenerativeModel`` / ``EmbeddingModel``, so executors and the plan
optimizer use them as drop-in handles.
"""
from __future__ import annotations

import threading
import time
from typing import Sequence

import numpy as np

from repro.core import accounting
from repro.obs import trace as _trace


class DispatchError(RuntimeError):
    """A fused backend call failed; raised in every waiting caller."""


class _ParkedCall:
    __slots__ = ("prompts", "tag", "event", "rows", "owned", "shared", "error")

    def __init__(self, prompts: list[str], tag: str | None):
        self.prompts = prompts
        self.tag = tag                     # session id, for cross-query stats
        self.event = threading.Event()
        self.rows: list | None = None
        self.owned = 0                     # unique prompts this call paid for
        self.shared = 0                    # prompts answered by store/another call
        self.error: BaseException | None = None


class MicroBatchDispatcher:
    def __init__(self, *, oracle, proxy=None, embedder=None, store=None,
                 window_s: float = 0.002, max_batch: int = 64, tracer=None):
        self._backends = {"oracle": oracle, "proxy": proxy, "embed": embedder}
        self._background: set[str] = set()   # roles flushed lazily (audit)
        self._store = store
        # fused batches run on the dispatcher thread, outside any session's
        # trace context: batch spans root on the tracer handle directly
        self._tracer = tracer
        self.window_s = window_s
        self.max_batch = max_batch
        self._cv = threading.Condition()
        self._buckets: dict[tuple, list[_ParkedCall]] = {}
        self._bucket_t0: dict[tuple, float] = {}
        self._closed = False
        # metrics
        self.fused_batches = 0
        self.fused_calls = 0               # parked calls absorbed into batches
        self.backend_prompts = 0           # unique prompts sent to backends
        self.requested_prompts = 0         # prompts submitted by callers
        self.cross_shared = 0              # in-window LM dupes across sessions
        self.cross_shared_embed = 0        # same, embed traffic (kept apart:
                                           # embeds never do a counted store
                                           # consult, so mixing them into the
                                           # LM hit-rate would break the rate)
        # background (audit) traffic is counted apart so query-path fusion
        # rates are identical with auditing on or off
        self.audit_batches = 0
        self.audit_backend_prompts = 0
        self.audit_requested_prompts = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="microbatch-dispatcher")
        self._thread.start()

    def add_backend(self, role: str, backend, *,
                    background: bool = False) -> None:
        """Register an extra role (the gateway adds ``audit``).
        ``background=True`` gives the role a stretched flush window
        (``window_s * 8``) so its buckets yield the dispatch thread to
        latency-sensitive query traffic and fuse into wider batches."""
        with self._cv:
            self._backends[role] = backend
            if background:
                self._background.add(role)
            else:
                self._background.discard(role)

    # -- caller side -------------------------------------------------------
    def submit(self, role: str, kind: str, prompts: Sequence[str], *,
               extra: tuple = (), tag: str | None = None) -> _ParkedCall:
        """Park one call and block until the fused batch answers it."""
        if self._backends.get(role) is None:
            raise ValueError(f"dispatcher has no backend for role {role!r}")
        call = _ParkedCall(list(prompts), tag)
        key = (role, kind, extra)
        with self._cv:
            if self._closed:
                raise DispatchError("dispatcher is closed")
            bucket = self._buckets.setdefault(key, [])
            if not bucket:
                self._bucket_t0[key] = time.monotonic()
            bucket.append(call)
            self._cv.notify_all()
        call.event.wait()
        if call.error is not None:
            raise DispatchError(str(call.error)) from call.error
        return call

    # -- dispatcher thread -------------------------------------------------
    def _window_for(self, key: tuple) -> float:
        return self.window_s * (8 if key[0] in self._background
                                else 1)

    def _ready_key(self) -> tuple | None:
        """A bucket whose window elapsed or whose unique count hit max_batch
        (caller must hold the lock)."""
        now = time.monotonic()
        for key, bucket in self._buckets.items():
            if not bucket:
                continue
            if now - self._bucket_t0[key] >= self._window_for(key):
                return key
            uniq = len({p for c in bucket for p in c.prompts})
            if uniq >= self.max_batch:
                return key
        return None

    def _next_deadline(self) -> float | None:
        if not any(self._buckets.values()):
            return None
        return min(self._bucket_t0[k] + self._window_for(k)
                   for k, b in self._buckets.items() if b)

    def _loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    if self._closed and not any(self._buckets.values()):
                        return
                    key = self._ready_key()
                    if key is not None or self._closed:
                        break
                    deadline = self._next_deadline()
                    self._cv.wait(timeout=None if deadline is None
                                  else max(deadline - time.monotonic(), 1e-4))
                if key is None:   # closing: flush whatever is parked
                    key = next(k for k, b in self._buckets.items() if b)
                calls = self._buckets.pop(key)
                self._bucket_t0.pop(key, None)
            self._execute(key, calls)

    def _invoke(self, role: str, kind: str, extra: tuple,
                prompts: list[str]) -> list:
        m = self._backends[role]
        if kind == "predicate":
            passed, scores = m.predicate(prompts)
            return list(zip(np.asarray(passed).tolist(),
                            np.asarray(scores).tolist()))
        if kind == "generate":
            return list(m.generate(prompts))
        if kind == "compare":
            return np.asarray(m.compare(prompts)).tolist()
        if kind == "choose":
            return np.asarray(m.choose(prompts, extra[0])).tolist()
        if kind == "embed":
            return list(np.asarray(m.embed(prompts)))
        raise ValueError(f"unknown call kind {kind!r}")

    def _execute(self, key: tuple, calls: list[_ParkedCall]) -> None:
        role, kind, extra = key
        with _trace.span_in(self._tracer, f"dispatch/{role}.{kind}",
                            "dispatch_batch", role=role, call_kind=kind) as sp:
            self._execute_batch(key, calls, sp)

    def _execute_batch(self, key: tuple, calls: list[_ParkedCall],
                       sp) -> None:
        role, kind, extra = key
        try:
            # dedup across all parked calls; first requester owns the prompt
            owner_of: dict[str, _ParkedCall] = {}
            order: list[str] = []
            for c in calls:
                for p in c.prompts:
                    if p not in owner_of:
                        owner_of[p] = c
                        order.append(p)
            rows: dict[str, object] = {}
            todo = order
            # background (audit) roles bypass the store entirely: a cached
            # gold answer would mask exactly the drift the audit exists to
            # detect, and audit answers must never warm query-visible state
            use_store = self._store is not None \
                and role not in self._background
            if use_store:
                keys = [(role, kind, *extra, p) for p in order]
                # second-chance lookup (uncounted): the session-side caches
                # already did the counted consult before parking the call
                found = self._store.get_many(keys, count=False)
                todo = []
                for p, (hit, row) in zip(order, found):
                    if hit:
                        rows[p] = row
                        owner_of[p] = None  # nobody pays: it's a cache hit
                    else:
                        todo.append(p)
            if todo:
                answered = self._invoke(role, kind, extra, todo)
                for p, row in zip(todo, answered):
                    rows[p] = row
                if use_store:
                    self._store.put_many(
                        [(role, kind, *extra, p) for p in todo], answered,
                        owners=[owner_of[p].tag for p in todo])
            # batch fusion width + dedup/store effect, on the batch span
            sp.set(fused_calls=len(calls), unique_prompts=len(order),
                   backend_prompts=len(todo),
                   store_hits=len(order) - len(todo),
                   sessions=len({c.tag for c in calls}))
            prompt_sets = [set(c.prompts) for c in calls]
            with self._cv:
                if role in self._background:
                    self.audit_batches += 1
                    self.audit_backend_prompts += len(todo)
                    self.audit_requested_prompts += sum(
                        len(c.prompts) for c in calls)
                else:
                    self.fused_batches += 1
                    self.fused_calls += len(calls)
                    self.backend_prompts += len(todo)
                    self.requested_prompts += sum(
                        len(c.prompts) for c in calls)
                if len({c.tag for c in calls}) > 1:
                    for p in order:
                        sharers = {c.tag for c, ps in zip(calls, prompt_sets)
                                   if p in ps}
                        n = max(len(sharers) - 1, 0)
                        if role == "embed":
                            self.cross_shared_embed += n
                        else:
                            self.cross_shared += n
            for c in calls:
                c.rows = [rows[p] for p in c.prompts]
                c.owned = sum(1 for p in set(c.prompts) if owner_of.get(p) is c)
                c.shared = len(c.prompts) - c.owned
                c.event.set()
        except BaseException as exc:  # propagate to every waiting caller
            for c in calls:
                c.error = exc
                c.event.set()

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout=5.0)

    def stats(self) -> dict:
        with self._cv:
            return {
                "fused_batches": self.fused_batches,
                "fused_calls": self.fused_calls,
                "backend_prompts": self.backend_prompts,
                "requested_prompts": self.requested_prompts,
                "cross_shared": self.cross_shared,
                "cross_shared_embed": self.cross_shared_embed,
                "coalesce_ratio": (self.fused_calls / self.fused_batches
                                   if self.fused_batches else 0.0),
                "audit_batches": self.audit_batches,
                "audit_backend_prompts": self.audit_backend_prompts,
                "audit_requested_prompts": self.audit_requested_prompts,
            }


class DispatchedModel:
    """GenerativeModel handle that routes through the dispatcher and records
    per-session accounting on the calling thread (where the session's
    OpStats context lives)."""

    def __init__(self, dispatcher: MicroBatchDispatcher, role: str, *,
                 tag: str | None = None):
        self._d = dispatcher
        self.role = role
        self.tag = tag

    def _submit(self, kind: str, prompts, extra: tuple = ()):
        call = self._d.submit(self.role, kind, prompts, extra=extra,
                              tag=self.tag)
        accounting.record(self.role, call.owned)
        if kind in ("generate", "compare"):
            accounting.record(kind, call.owned)
        accounting.record("cache_hit", call.shared)
        return call.rows

    def predicate(self, prompts):
        rows = self._submit("predicate", prompts)
        return (np.asarray([r[0] for r in rows], bool),
                np.asarray([r[1] for r in rows], np.float32))

    def generate(self, prompts):
        return list(self._submit("generate", prompts))

    def compare(self, prompts):
        return np.asarray(self._submit("compare", prompts), bool)

    def choose(self, prompts, n_options):
        return np.asarray(self._submit("choose", prompts, (n_options,)), int)


class DispatchedEmbedder:
    def __init__(self, dispatcher: MicroBatchDispatcher, *, tag: str | None = None):
        self._d = dispatcher
        self.tag = tag

    @property
    def dim(self):
        return self._d._backends["embed"].dim

    @property
    def index_key(self):
        """Identity of the shared backend embedder, not this per-session
        handle — serve sessions must land on the same registry key."""
        from repro.index.backend import embedder_key
        return embedder_key(self._d._backends["embed"])

    def embed(self, texts):
        call = self._d.submit("embed", "embed", texts, tag=self.tag)
        accounting.record("embed", call.owned)
        accounting.record("cache_hit", call.shared)
        return np.stack([np.asarray(r) for r in call.rows])
