"""Concurrent semantic-query serving layer (the system half of the paper).

PR 1 made one pipeline cheap (plan IR + optimizer + batched executor); this
package makes *many concurrent* pipelines cheap by sharing work across them:

  * ``store``    — :class:`SharedSemanticCache`, the process-wide semantic
                   answer store (TTL, LRU capacity, per-role namespaces,
                   optional JSON-lines persistence across runs);
  * ``dispatch`` — :class:`MicroBatchDispatcher`, cross-query micro-batching:
                   oracle/proxy/embed calls from all in-flight executors are
                   coalesced (deduplicated) into fused backend batches on a
                   short time/size window;
  * ``session``  — :class:`ServeSession`, the future-style handle with
                   deadlines, cooperative cancellation, and per-session
                   OpStats roll-ups;
  * ``gateway``  — :class:`Gateway`, multi-tenant admission (bounded queue,
                   FIFO-with-fairness) plus the worker pool that executes
                   plans through the shared runtime;
  * ``metrics``  — gateway-level throughput / latency tails / cross-query
                   cache hit rate;
  * ``index_registry`` — :class:`IndexRegistry`, process-wide retrieval-index
                   sharing: concurrent sessions over the same corpus trigger
                   exactly one embed+build (exact or IVF); streaming corpora
                   use versioned keys (``get_or_update``) so an append
                   embeds/indexes only the delta rows;
  * ``matview``  — :class:`MatViewRegistry`, multi-query subplan sharing:
                   concurrent sessions whose plans contain the same
                   fingerprinted subtree (normalized predicate + corpus
                   version) latch exactly one computation and serve the rest
                   from the materialization (``Gateway(matview=True)``).

Streaming corpora (``repro.stream.CorpusTable``) plug in through
``Gateway.subscribe(pipeline)``: a continuous query re-executed on every
table commit, with the shared cache keeping re-executions delta-only.

    gw = Gateway(session, max_inflight=4, cache_ttl_s=600)
    handles = [gw.submit(sf.lazy().sem_filter(...)) for sf in frames]
    rows = [h.result() for h in handles]
    sub = gw.subscribe(table.lazy(session).sem_filter(...))
    print(gw.snapshot())
"""
from repro.serve.dispatch import (DispatchedEmbedder, DispatchedModel,
                                  DispatchError, MicroBatchDispatcher)
from repro.serve.gateway import AdmissionError, Gateway
from repro.serve.index_registry import IndexRegistry
from repro.serve.matview import MatViewRegistry, plan_fingerprint
from repro.serve.metrics import GatewayMetrics
from repro.serve.session import (ServeSession, SessionCancelled,
                                 SessionDeadlineExceeded)
from repro.serve.store import SharedSemanticCache

__all__ = [
    "AdmissionError", "DispatchError", "DispatchedEmbedder",
    "DispatchedModel", "Gateway", "GatewayMetrics", "IndexRegistry",
    "MatViewRegistry", "MicroBatchDispatcher", "ServeSession",
    "SessionCancelled", "SessionDeadlineExceeded", "SharedSemanticCache",
    "plan_fingerprint",
]
