"""IndexRegistry: process-wide retrieval-index sharing across serve sessions.

Every embedding-backed operator in a plan (sem_search, sem_sim_join, the
join sim-prefilter, topk pivot selection) needs an index over some corpus.
Without sharing, N concurrent gateway sessions over the same corpus embed
and build N times.  The registry keys built indexes by
``(corpus-fingerprint, embedder identity, kind, build params)`` — the
build params include the device-shard layout (``shards``), so a sharded
build and an unsharded build of the same corpus are distinct entries and a
session never receives an index laid out for a mesh it isn't using —
``repro.index.backend.corpus_fingerprint`` unwraps the per-session
accounting/dispatch wrappers so sessions land on the same key — and
guarantees *exactly one build per key* under concurrency: losers of the
build race block on the winner's per-key latch instead of re-building.

Streaming corpora get a second, *versioned* protocol: ``get_or_update``
keys a :class:`~repro.stream.table.CorpusTable` by its stable table id (not
a content fingerprint, which an append would invalidate) and remembers the
version each cached index covers.  An appends-only delta re-uses the base
index and applies only the new rows through the caller's ``updater``
(embed + ``index.add``); updates/deletes fall back to a rebuild — and a
request pinned *behind* the cached version builds fresh without caching,
so a session that pinned an old snapshot never sees rows from the future.

LRU capacity bounds a long-lived gateway's memory; eviction releases the
evicted key's embedder pin AND any stale build latch (waiters re-race
instead of deadlocking), so a long-lived gateway doesn't leak pinned
embedders.  ``metrics()`` reports builds / shared hits / delta updates /
evictions for benchmarks and the gateway snapshot.

``repro.serve.matview`` applies the same win-or-wait latch protocol one
level up — to whole materialized subplans keyed by plan fingerprint — so
the sharing ladder is prompt (dispatcher) -> index build (here) -> subplan
(MatViewRegistry).
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from repro.index.backend import (RetrievalBackend, corpus_fingerprint,
                                 embedder_key)
from repro.obs import trace as _trace


class IndexRegistry:
    def __init__(self, *, capacity: int = 32):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._indexes: OrderedDict[str, RetrievalBackend] = OrderedDict()
        # keys embed the backend embedder's id(); pinning the embedder (the
        # wrapper chain holds the backend) for the entry's lifetime stops a
        # GC'd embedder's address being reused by a *different* model, which
        # would silently alias its key onto a stale index
        self._pins: dict[str, object] = {}
        self._versions: dict[str, int] = {}   # stream keys: covered version
        self._building: dict[str, threading.Event] = {}
        self.builds = 0
        self.hits = 0
        self.updates = 0          # delta applications onto a cached index
        self.delta_rows = 0       # rows embedded+indexed by those updates
        self.stale_misses = 0     # pinned-version requests behind the cache
        self.evictions = 0

    @staticmethod
    def key_for(texts, embedder, *, kind: str, params: dict | None = None) -> str:
        extras = "|".join(f"{k}={v}" for k, v in sorted((params or {}).items()))
        return f"{corpus_fingerprint(texts, embedder)}:{kind}:{extras}"

    @staticmethod
    def stream_key_for(table, embedder, *, kind: str,
                       params: dict | None = None) -> str:
        extras = "|".join(f"{k}={v}" for k, v in sorted((params or {}).items()))
        return (f"stream:{table.table_id}:{embedder_key(embedder)}"
                f":{kind}:{extras}")

    # -- shared plumbing ---------------------------------------------------
    def _evict_excess(self) -> None:
        """LRU-evict past capacity (lock held): the index, its embedder pin,
        its stream version, and any stale build latch (released, so waiters
        re-race the build instead of blocking on a dead key)."""
        while len(self._indexes) > self.capacity:
            old_key, _ = self._indexes.popitem(last=False)
            self._pins.pop(old_key, None)
            self._versions.pop(old_key, None)
            latch = self._building.pop(old_key, None)
            if latch is not None:
                latch.set()
            self.evictions += 1

    def _win_or_wait(self, key: str, target: int | None = None):
        """Return (hit_index, None) on a cache hit, (base, latch) after
        winning the build/update race (base = cached-but-outdated index or
        None), or (None, "stale") when the cache is ahead of a pinned
        version.  Loops while a loser, waiting on the winner's latch."""
        while True:
            with self._lock:
                idx = self._indexes.get(key)
                if idx is not None:
                    have = self._versions.get(key)
                    if target is None or have == target:
                        self._indexes.move_to_end(key)
                        self.hits += 1
                        return idx, None
                    if have is not None and have > target:
                        self.stale_misses += 1
                        return None, "stale"
                latch = self._building.get(key)
                if latch is None:               # we won the race
                    self._building[key] = threading.Event()
                    return idx, self._building[key]
            latch.wait()                        # loser: winner is working

    def _install(self, key: str, index: RetrievalBackend, embedder,
                 version: int | None = None) -> None:
        with self._lock:
            self._indexes[key] = index
            self._indexes.move_to_end(key)
            self._pins[key] = embedder
            if version is not None:
                self._versions[key] = version
            self._evict_excess()

    def _release(self, key: str, latch: threading.Event) -> None:
        with self._lock:
            self._building.pop(key, None)
        latch.set()

    # -- frozen-corpus protocol (content-fingerprint keys) -----------------
    def get_or_build(self, texts, embedder, *, kind: str, builder,
                     params: dict | None = None) -> RetrievalBackend:
        """Return the shared index for this corpus+embedder+config, building
        it at most once process-wide (concurrent callers wait on the
        winner's latch)."""
        key = self.key_for(texts, embedder, kind=kind, params=params)
        idx, latch = self._win_or_wait(key)
        if latch is None:
            return idx
        try:
            # build races are won once per key: the span measures the single
            # process-wide build this session actually paid for
            with _trace.span(f"index_build/{kind}", kind="index_build",
                             corpus_rows=len(texts)) as sp:
                built = builder()
                sp.set(index_kind=built.kind)
            with self._lock:
                self.builds += 1
            self._install(key, built, embedder)
            return built
        finally:
            self._release(key, latch)

    # -- streaming protocol (table-id keys, versioned) ---------------------
    def get_or_update(self, table, embedder, *, kind: str, builder,
                      updater=None, params: dict | None = None,
                      version: int | None = None) -> RetrievalBackend:
        """Index over ``table``'s snapshot at ``version`` (default: current).

        ``builder(records)`` builds from a full snapshot; ``updater(index,
        added_records)`` applies an appends-only delta in place.  Exactly
        one builder/updater runs per key under concurrency; an index cached
        *ahead* of a pinned version is never served for it (fresh uncached
        build instead)."""
        target = table.version if version is None else version
        key = self.stream_key_for(table, embedder, kind=kind, params=params)
        idx, latch = self._win_or_wait(key, target)
        if latch is None:
            return idx
        if latch == "stale":  # pinned behind the cache: correctness first
            return builder(table.snapshot(target))
        try:
            with self._lock:
                # re-read: eviction may have raced us between win and update
                # — and force-released our latch, letting a re-racer install
                # a fresh index.  Whatever is resident NOW is the truth; our
                # pre-win ``idx`` may be stale.
                cur = self._indexes.get(key)
                have = self._versions.get(key)
            if cur is not idx:
                idx = cur
                if idx is not None and have == target:
                    return idx                  # finally releases the latch
                if idx is not None and have is not None and have > target:
                    # a re-racer installed a NEWER version while our latch
                    # was force-released: pinned-behind, build fresh uncached
                    with self._lock:
                        self.stale_misses += 1
                    return builder(table.snapshot(target))
            if have is None:
                idx = None
            if idx is None:
                with _trace.span(f"index_build/{kind}", kind="index_build",
                                 table=table.table_id, version=target):
                    built = builder(table.snapshot(target))
                with self._lock:
                    self.builds += 1
            else:
                delta = table.delta(have, target)
                if delta.appends_only and not delta.added:
                    built = idx                 # net no-op commits
                elif delta.appends_only and updater is not None:
                    with _trace.span(f"index_update/{kind}",
                                     kind="index_build",
                                     table=table.table_id, version=target,
                                     delta_rows=len(delta.added)):
                        updater(idx, [r for _, r in delta.added])
                    built = idx
                    with self._lock:
                        self.updates += 1
                        self.delta_rows += len(delta.added)
                else:                           # updates/deletes: rebuild
                    with _trace.span(f"index_build/{kind}",
                                     kind="index_build",
                                     table=table.table_id, version=target):
                        built = builder(table.snapshot(target))
                    with self._lock:
                        self.builds += 1
            self._install(key, built, embedder, version=target)
            return built
        finally:
            self._release(key, latch)

    def metrics(self) -> dict:
        with self._lock:
            return {"index_builds": self.builds, "index_hits": self.hits,
                    "index_updates": self.updates,
                    "index_delta_rows": self.delta_rows,
                    "index_stale_misses": self.stale_misses,
                    "index_evictions": self.evictions,
                    "indexes_resident": len(self._indexes)}

    def clear(self) -> None:
        with self._lock:
            self._indexes.clear()
            self._pins.clear()
            self._versions.clear()
            for latch in self._building.values():
                latch.set()                     # release any stuck waiters
            self._building.clear()
