"""IndexRegistry: process-wide retrieval-index sharing across serve sessions.

Every embedding-backed operator in a plan (sem_search, sem_sim_join, the
join sim-prefilter, topk pivot selection) needs an index over some corpus.
Without sharing, N concurrent gateway sessions over the same corpus embed
and build N times.  The registry keys built indexes by
``(corpus-fingerprint, embedder identity, kind, build params)`` —
``repro.index.backend.corpus_fingerprint`` unwraps the per-session
accounting/dispatch wrappers so sessions land on the same key — and
guarantees *exactly one build per key* under concurrency: losers of the
build race block on the winner's per-key latch instead of re-building.

LRU capacity bounds a long-lived gateway's memory; ``metrics()`` reports
builds / shared hits / evictions so benchmarks and the gateway snapshot can
attribute cross-session index reuse.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from repro.index.backend import RetrievalBackend, corpus_fingerprint


class IndexRegistry:
    def __init__(self, *, capacity: int = 32):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._indexes: OrderedDict[str, RetrievalBackend] = OrderedDict()
        # keys embed the backend embedder's id(); pinning the embedder (the
        # wrapper chain holds the backend) for the entry's lifetime stops a
        # GC'd embedder's address being reused by a *different* model, which
        # would silently alias its key onto a stale index
        self._pins: dict[str, object] = {}
        self._building: dict[str, threading.Event] = {}
        self.builds = 0
        self.hits = 0
        self.evictions = 0

    @staticmethod
    def key_for(texts, embedder, *, kind: str, params: dict | None = None) -> str:
        extras = "|".join(f"{k}={v}" for k, v in sorted((params or {}).items()))
        return f"{corpus_fingerprint(texts, embedder)}:{kind}:{extras}"

    def get_or_build(self, texts, embedder, *, kind: str, builder,
                     params: dict | None = None) -> RetrievalBackend:
        """Return the shared index for this corpus+embedder+config, building
        it at most once process-wide (concurrent callers wait on the
        winner's latch)."""
        key = self.key_for(texts, embedder, kind=kind, params=params)
        while True:
            with self._lock:
                idx = self._indexes.get(key)
                if idx is not None:
                    self._indexes.move_to_end(key)
                    self.hits += 1
                    return idx
                latch = self._building.get(key)
                if latch is None:           # we won the build race
                    latch = self._building[key] = threading.Event()
                    break
            latch.wait()                    # loser: winner is building

        try:
            built = builder()
            with self._lock:
                self._indexes[key] = built
                self._pins[key] = embedder
                self.builds += 1
                while len(self._indexes) > self.capacity:
                    old_key, _ = self._indexes.popitem(last=False)
                    self._pins.pop(old_key, None)
                    self.evictions += 1
            return built
        finally:
            with self._lock:
                self._building.pop(key, None)
            latch.set()

    def metrics(self) -> dict:
        with self._lock:
            return {"index_builds": self.builds, "index_hits": self.hits,
                    "index_evictions": self.evictions,
                    "indexes_resident": len(self._indexes)}

    def clear(self) -> None:
        with self._lock:
            self._indexes.clear()
            self._pins.clear()
