"""Gateway-level serving metrics: throughput, latency tails, cache sharing.

One ``GatewayMetrics`` per gateway, fed by the worker threads as sessions
resolve; ``snapshot()`` folds in the shared store's and dispatcher's own
counters to report the serving headline numbers — sessions/s, p50/p95/p99
end-to-end latency, and the cross-query cache hit rate (the fraction of all
prompt lookups answered by another session's work, in-window or from the
shared store).

Latency percentiles come from a fixed-bucket log-scale histogram over the
gateway's *whole* life, not a sliding sample window: a ``deque(maxlen=N)``
silently biases the tail toward the most recent sessions once a long-lived
gateway wraps, while the histogram is O(buckets) memory with a bounded
relative error (each bucket spans ~7.5%, so a reported percentile is within
half a bucket of the true latency).
"""
from __future__ import annotations

import math
import threading
import time


class LatencyHistogram:
    """Log-scale fixed-bucket histogram over [LO, HI) seconds with under/
    overflow buckets; ``percentile()`` returns the geometric midpoint of the
    bucket holding the requested rank."""

    LO = 1e-4          # 100 µs
    HI = 1e4           # ~2.8 h
    PER_DECADE = 32    # bucket width ratio 10**(1/32) ≈ 1.075

    def __init__(self):
        self._n = int(math.ceil(math.log10(self.HI / self.LO)
                                * self.PER_DECADE))
        # [underflow] + self._n log buckets + [overflow]
        self.counts = [0] * (self._n + 2)
        self.total = 0
        self.sum = 0.0     # total observed seconds (Prometheus _sum export)
        self._log_lo = math.log10(self.LO)

    def _bucket(self, x: float) -> int:
        if x < self.LO:
            return 0
        if x >= self.HI:
            return self._n + 1
        return 1 + int((math.log10(x) - self._log_lo) * self.PER_DECADE)

    def record(self, x: float) -> None:
        self.counts[self._bucket(float(x))] += 1
        self.total += 1
        self.sum += float(x)

    def cumulative_leq(self, bounds) -> list[int]:
        """Samples at or below each bound (ascending), re-bucketed onto the
        coarse export bounds: the fine bucket containing a bound contributes
        whole, so each cumulative count is exact to within one fine bucket
        (~7.5% relative on the boundary) — the price of exporting a live
        log-scale histogram without a second per-bound counter array."""
        out = []
        for b in bounds:
            idx = self._bucket(float(b))
            out.append(int(sum(self.counts[: idx + 1])))
        return out

    def percentile(self, q: float) -> float | None:
        if not self.total:
            return None
        rank = q / 100.0 * (self.total - 1)
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc > rank:
                if i == 0:
                    return self.LO
                if i == self._n + 1:
                    return self.HI
                lo = 10 ** (self._log_lo + (i - 1) / self.PER_DECADE)
                hi = lo * 10 ** (1 / self.PER_DECADE)
                return math.sqrt(lo * hi)  # geometric midpoint
        return self.HI  # pragma: no cover - acc always exceeds rank

    def __len__(self) -> int:
        return self.total


class GatewayMetrics:
    def __init__(self):
        self._lock = threading.Lock()
        self.started_at = time.monotonic()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self.expired = 0
        self.rejected = 0
        self.rows_out = 0
        self.subscriptions = 0    # continuous queries registered
        self.emissions = 0        # continuous-query results emitted
        self.emission_errors = 0
        self.fragments_run = 0    # partition fragments executed
        self.partitioned_ops = 0  # operators that ran fragment-parallel
        self.replans = 0          # mid-query re-plan decisions (adaptive)
        # fast-join accounting (block-prompted sem_join path)
        self.join_candidate_pairs = 0   # pairs surviving IVF blocking
        self.join_pairs_pruned = 0      # verdicts inferred via transitivity
        self.join_block_prompts = 0     # multi-pair block prompts issued
        self.join_block_fallbacks = 0   # blocks that fell back pairwise
        self.violations = 0       # guarantee-audit CI violations (alerts)
        self.violations_by_kind: dict[str, int] = {}
        # O(1)-memory, unbiased over the gateway's whole life (see module
        # docstring); field name kept from the deque era
        self.latencies = LatencyHistogram()
        # per-tenant SLO series: admission, deadline hits, latency tails
        self._tenants: dict[str, dict] = {}

    def _tenant(self, tenant: str) -> dict:
        """Lock held."""
        t = self._tenants.get(tenant)
        if t is None:
            t = self._tenants[tenant] = {
                "submitted": 0, "completed": 0, "failed": 0, "cancelled": 0,
                "deadline_hits": 0, "rejected": 0, "rows_out": 0,
                "latencies": LatencyHistogram()}
        return t

    def on_submit(self, *, tenant: str | None = None) -> None:
        with self._lock:
            self.submitted += 1
            if tenant is not None:
                self._tenant(tenant)["submitted"] += 1

    def on_reject(self, *, tenant: str | None = None) -> None:
        with self._lock:
            self.rejected += 1
            if tenant is not None:
                self._tenant(tenant)["rejected"] += 1

    def on_violation(self, kind: str) -> None:
        """Guarantee-audit alert counter (the violation's full payload goes
        to the auditor's event deque; this is the pageable number)."""
        with self._lock:
            self.violations += 1
            self.violations_by_kind[kind] = \
                self.violations_by_kind.get(kind, 0) + 1

    def on_subscribe(self) -> None:
        with self._lock:
            self.subscriptions += 1

    def on_emit(self, *, error: bool = False) -> None:
        with self._lock:
            self.emissions += 1
            if error:
                self.emission_errors += 1

    def on_replans(self, n: int) -> None:
        """Per-session re-plan roll-up (adaptive executor decisions)."""
        if not n:
            return
        with self._lock:
            self.replans += n

    def on_join_stats(self, details: dict) -> None:
        """Per-join roll-up from a session's stats-log entry (the worker
        scans entries carrying ``candidate_pairs`` after the session
        resolves — the same collect-on-demand treatment the search and
        fragment counters get)."""
        with self._lock:
            self.join_candidate_pairs += int(details.get("candidate_pairs", 0))
            self.join_pairs_pruned += \
                int(details.get("pairs_pruned_by_inference", 0))
            self.join_block_prompts += int(details.get("block_prompts", 0))
            self.join_block_fallbacks += int(details.get("block_fallbacks", 0))

    def on_fragments(self, n_fragments: int, n_ops: int) -> None:
        """Per-session partition-fragment roll-up (reported by the worker
        after the session's executor finishes)."""
        if not n_fragments and not n_ops:
            return
        with self._lock:
            self.fragments_run += n_fragments
            self.partitioned_ops += n_ops

    def on_finish(self, status: str, latency_s: float | None,
                  n_rows: int | None, *, tenant: str | None = None) -> None:
        with self._lock:
            if status == "done":
                self.completed += 1
                self.rows_out += n_rows or 0
            elif status == "cancelled":
                self.cancelled += 1
            elif status == "expired":
                self.expired += 1
            else:
                self.failed += 1
            if latency_s is not None:
                self.latencies.record(latency_s)
            if tenant is not None:
                t = self._tenant(tenant)
                key = {"done": "completed", "cancelled": "cancelled",
                       "expired": "deadline_hits"}.get(status, "failed")
                t[key] += 1
                if status == "done":
                    t["rows_out"] += n_rows or 0
                if latency_s is not None:
                    t["latencies"].record(latency_s)

    def tenant_snapshot(self) -> dict:
        """Per-tenant SLO numbers: admission, deadline hits, p50/p95/p99."""
        with self._lock:
            out = {}
            for tenant, t in sorted(self._tenants.items()):
                lat = t["latencies"]
                out[tenant] = {k: v for k, v in t.items()
                               if k != "latencies"}
                out[tenant].update(
                    p50_latency_s=lat.percentile(50) if len(lat) else None,
                    p95_latency_s=lat.percentile(95) if len(lat) else None,
                    p99_latency_s=lat.percentile(99) if len(lat) else None)
            return out

    def collect(self, registry, *, store=None, dispatcher=None) -> None:
        """Write the gateway's serving series into a ``MetricsRegistry``
        (collect-on-demand: the authoritative counters live here, the
        registry is rebuilt per scrape).  Includes the per-tenant SLO
        series, the violation alert counters, and — when given — the shared
        semantic cache's and dispatcher's own numbers."""
        from repro.obs.metrics import DEFAULT_BUCKETS
        with self._lock:
            sessions = registry.counter(
                "repro_gateway_sessions_total",
                "sessions by terminal status", ("status",))
            for status, v in (("completed", self.completed),
                              ("failed", self.failed),
                              ("cancelled", self.cancelled),
                              ("expired", self.expired),
                              ("rejected", self.rejected)):
                sessions.set_total(v, status=status)
            registry.counter("repro_gateway_submitted_total",
                             "sessions admitted").set_total(self.submitted)
            registry.counter("repro_gateway_rows_out_total",
                             "result rows returned").set_total(self.rows_out)
            registry.counter("repro_gateway_replans_total",
                             "adaptive mid-query replans"
                             ).set_total(self.replans)
            registry.counter("repro_gateway_fragments_total",
                             "partition fragments executed"
                             ).set_total(self.fragments_run)
            registry.counter("repro_join_candidate_pairs_total",
                             "join pairs surviving the blocking stage"
                             ).set_total(self.join_candidate_pairs)
            registry.counter("repro_join_pairs_pruned_total",
                             "join verdicts inferred via transitivity"
                             ).set_total(self.join_pairs_pruned)
            blocks = registry.counter(
                "repro_join_block_prompts_total",
                "multi-pair block prompts by outcome", ("outcome",))
            blocks.set_total(
                self.join_block_prompts - self.join_block_fallbacks,
                outcome="ok")
            blocks.set_total(self.join_block_fallbacks, outcome="fallback")
            stream = registry.counter(
                "repro_gateway_emissions_total",
                "continuous-query emissions", ("outcome",))
            stream.set_total(self.emissions - self.emission_errors,
                             outcome="ok")
            stream.set_total(self.emission_errors, outcome="error")
            registry.counter("repro_gateway_subscriptions_total",
                             "continuous queries registered"
                             ).set_total(self.subscriptions)
            viol = registry.counter("repro_gateway_violations_total",
                                    "guarantee-audit alerts", ("kind",))
            for kind, v in sorted(self.violations_by_kind.items()):
                viol.set_total(v, kind=kind)
            lat = registry.histogram("repro_gateway_latency_seconds",
                                     "end-to-end session latency",
                                     buckets=DEFAULT_BUCKETS)
            lat.observe_buckets(
                self.latencies.cumulative_leq(DEFAULT_BUCKETS),
                self.latencies.total, self.latencies.sum)
            if self._tenants:
                t_sessions = registry.counter(
                    "repro_tenant_sessions_total",
                    "per-tenant sessions by terminal status",
                    ("tenant", "status"))
                t_lat = registry.histogram(
                    "repro_tenant_latency_seconds",
                    "per-tenant end-to-end latency", ("tenant",),
                    buckets=DEFAULT_BUCKETS)
                t_p = registry.gauge(
                    "repro_tenant_latency_quantile_seconds",
                    "per-tenant latency percentile (log-bucket midpoint)",
                    ("tenant", "quantile"))
                for tenant, t in sorted(self._tenants.items()):
                    for status in ("submitted", "completed", "failed",
                                   "cancelled", "deadline_hits", "rejected"):
                        t_sessions.set_total(t[status], tenant=tenant,
                                             status=status)
                    h = t["latencies"]
                    t_lat.observe_buckets(h.cumulative_leq(DEFAULT_BUCKETS),
                                          h.total, h.sum, tenant=tenant)
                    for q in (50, 95, 99):
                        p = h.percentile(q) if len(h) else None
                        if p is not None:
                            t_p.set(p, tenant=tenant, quantile=f"p{q}")
        if store is not None:
            cs = store.stats()
            cache = registry.counter("repro_cache_events_total",
                                     "shared semantic cache events",
                                     ("event",))
            for event in ("hits", "misses", "cross_hits", "evictions",
                          "expirations", "invalidations"):
                cache.set_total(cs.get(event, 0), event=event)
            registry.gauge("repro_cache_entries",
                           "live cache entries").set(cs["entries"])
        if dispatcher is not None:
            ds = dispatcher.stats()
            disp = registry.counter("repro_dispatch_prompts_total",
                                    "dispatcher prompt flow", ("stage",))
            disp.set_total(ds["requested_prompts"], stage="requested")
            disp.set_total(ds["backend_prompts"], stage="backend")
            disp.set_total(ds.get("audit_requested_prompts", 0),
                           stage="audit_requested")
            disp.set_total(ds.get("audit_backend_prompts", 0),
                           stage="audit_backend")
            registry.counter("repro_dispatch_batches_total",
                             "fused query batches"
                             ).set_total(ds["fused_batches"])
            registry.gauge("repro_dispatch_coalesce_ratio",
                           "parked calls per fused batch"
                           ).set(ds["coalesce_ratio"])

    def snapshot(self, *, store=None, dispatcher=None, tracer=None) -> dict:
        with self._lock:
            elapsed = max(time.monotonic() - self.started_at, 1e-9)
            lat = self.latencies
            out = {
                "submitted": self.submitted, "completed": self.completed,
                "failed": self.failed, "cancelled": self.cancelled,
                "expired": self.expired, "rejected": self.rejected,
                "rows_out": self.rows_out,
                "subscriptions": self.subscriptions,
                "emissions": self.emissions,
                "emission_errors": self.emission_errors,
                "fragments_run": self.fragments_run,
                "partitioned_ops": self.partitioned_ops,
                "replans": self.replans,
                "join_candidate_pairs": self.join_candidate_pairs,
                "join_pairs_pruned": self.join_pairs_pruned,
                "join_block_prompts": self.join_block_prompts,
                "join_block_fallbacks": self.join_block_fallbacks,
                "violations": self.violations,
                "elapsed_s": round(elapsed, 4),
                "throughput_rps": round(self.completed / elapsed, 4),
                "p50_latency_s": round(lat.percentile(50), 4)
                if len(lat) else None,
                "p95_latency_s": round(lat.percentile(95), 4)
                if len(lat) else None,
                "p99_latency_s": round(lat.percentile(99), 4)
                if len(lat) else None,
            }
        if tracer is not None:
            # span-derived per-stage wall/count/call breakdown (inclusive
            # wall per span kind/name; see Tracer.stage_summary)
            out["stages"] = tracer.stage_summary()
        if store is not None:
            out["cache"] = store.stats()
        if dispatcher is not None:
            out["dispatch"] = dispatcher.stats()
        if store is not None and dispatcher is not None:
            # cross-query sharing happens two ways: a hit on a store entry
            # another session wrote, or an in-window dupe fused by the
            # dispatcher; both are prompts this query never paid for
            cache, disp = out["cache"], out["dispatch"]
            total = cache["hits"] + cache["misses"]
            out["cross_query_hit_rate"] = (
                (cache["cross_hits"] + disp["cross_shared"]) / total
                if total else 0.0)
        elif store is not None:
            out["cross_query_hit_rate"] = out["cache"]["cross_query_hit_rate"]
        return out
