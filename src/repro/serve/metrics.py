"""Gateway-level serving metrics: throughput, latency tails, cache sharing.

One ``GatewayMetrics`` per gateway, fed by the worker threads as sessions
resolve; ``snapshot()`` folds in the shared store's and dispatcher's own
counters to report the serving headline numbers — sessions/s, p50/p95
end-to-end latency, and the cross-query cache hit rate (the fraction of all
prompt lookups answered by another session's work, in-window or from the
shared store).
"""
from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np


class GatewayMetrics:
    def __init__(self):
        self._lock = threading.Lock()
        self.started_at = time.monotonic()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self.expired = 0
        self.rejected = 0
        self.rows_out = 0
        self.subscriptions = 0    # continuous queries registered
        self.emissions = 0        # continuous-query results emitted
        self.emission_errors = 0
        self.fragments_run = 0    # partition fragments executed
        self.partitioned_ops = 0  # operators that ran fragment-parallel
        # percentiles are computed over a sliding window so a long-lived
        # gateway's metrics stay O(1) in memory
        self.latencies: deque[float] = deque(maxlen=4096)

    def on_submit(self) -> None:
        with self._lock:
            self.submitted += 1

    def on_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def on_subscribe(self) -> None:
        with self._lock:
            self.subscriptions += 1

    def on_emit(self, *, error: bool = False) -> None:
        with self._lock:
            self.emissions += 1
            if error:
                self.emission_errors += 1

    def on_fragments(self, n_fragments: int, n_ops: int) -> None:
        """Per-session partition-fragment roll-up (reported by the worker
        after the session's executor finishes)."""
        if not n_fragments and not n_ops:
            return
        with self._lock:
            self.fragments_run += n_fragments
            self.partitioned_ops += n_ops

    def on_finish(self, status: str, latency_s: float | None,
                  n_rows: int | None) -> None:
        with self._lock:
            if status == "done":
                self.completed += 1
                self.rows_out += n_rows or 0
            elif status == "cancelled":
                self.cancelled += 1
            elif status == "expired":
                self.expired += 1
            else:
                self.failed += 1
            if latency_s is not None:
                self.latencies.append(latency_s)

    def snapshot(self, *, store=None, dispatcher=None) -> dict:
        with self._lock:
            elapsed = max(time.monotonic() - self.started_at, 1e-9)
            lat = np.asarray(self.latencies, float)
            out = {
                "submitted": self.submitted, "completed": self.completed,
                "failed": self.failed, "cancelled": self.cancelled,
                "expired": self.expired, "rejected": self.rejected,
                "rows_out": self.rows_out,
                "subscriptions": self.subscriptions,
                "emissions": self.emissions,
                "emission_errors": self.emission_errors,
                "fragments_run": self.fragments_run,
                "partitioned_ops": self.partitioned_ops,
                "elapsed_s": round(elapsed, 4),
                "throughput_rps": round(self.completed / elapsed, 4),
                "p50_latency_s": round(float(np.percentile(lat, 50)), 4)
                if lat.size else None,
                "p95_latency_s": round(float(np.percentile(lat, 95)), 4)
                if lat.size else None,
            }
        if store is not None:
            out["cache"] = store.stats()
        if dispatcher is not None:
            out["dispatch"] = dispatcher.stats()
        if store is not None and dispatcher is not None:
            # cross-query sharing happens two ways: a hit on a store entry
            # another session wrote, or an in-window dupe fused by the
            # dispatcher; both are prompts this query never paid for
            cache, disp = out["cache"], out["dispatch"]
            total = cache["hits"] + cache["misses"]
            out["cross_query_hit_rate"] = (
                (cache["cross_hits"] + disp["cross_shared"]) / total
                if total else 0.0)
        elif store is not None:
            out["cross_query_hit_rate"] = out["cache"]["cross_query_hit_rate"]
        return out
