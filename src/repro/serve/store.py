"""SharedSemanticCache: the process-wide semantic answer store.

One store serves every in-flight session (and, with ``persist_path``, every
future run): keys are ``(namespace, kind, *extra, prompt)`` tuples — the same
shape ``BatchedModelCache`` uses — values are the JSON-safe per-prompt rows
the model wrappers produce (predicate ``[bool, score]``, generate ``str``,
compare ``bool``, choose ``int``).  A repeated predicate across two queries,
or across two gateway processes sharing a persistence file, is answered once.

Semantics:
  * **namespaces** — the first key element (model role: oracle/proxy/embed)
    partitions the key space, so an oracle answer never leaks to the proxy;
  * **TTL** — entries older than ``ttl_s`` count as misses and are dropped
    (clock injectable for tests);
  * **capacity** — LRU eviction beyond ``capacity`` entries;
  * **persistence** — optional append-only JSON-lines file, replayed on
    construction (last write wins; expired rows skipped).  Namespaces whose
    rows are not JSON-friendly (embeddings) stay memory-only via
    ``persist_namespaces``; ``close()`` compacts the log (rewrites live
    entries only) once dead lines — overwrites, evictions, expiries —
    outnumber live ones, so the file stays bounded across runs;
  * **attribution** — each entry remembers the session that wrote it, so a
    hit by a *different* session is counted as a cross-query hit (the number
    the gateway reports as ``cross_query_hit_rate``).

Thread-safe; every method takes the one internal lock.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import OrderedDict
from typing import Iterable, Sequence

LM_NAMESPACES = frozenset({"oracle", "proxy"})


class SharedSemanticCache:
    def __init__(self, *, capacity: int = 100_000, ttl_s: float | None = None,
                 persist_path: str | None = None,
                 persist_namespaces: Iterable[str] = LM_NAMESPACES,
                 clock=time.monotonic):
        self.capacity = capacity
        self.ttl_s = ttl_s
        self.persist_path = persist_path
        self.persist_namespaces = frozenset(persist_namespaces)
        self.clock = clock
        self._lock = threading.Lock()
        # key -> (row, written_at, owner)
        self._data: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.cross_hits = 0      # hits on entries another session wrote
        self.evictions = 0
        self.expirations = 0
        self.loaded = 0
        self.compactions = 0
        self.invalidations = 0   # entries purged by guarantee recalibration
        self._file_lines = 0      # lines in the log, live + dead
        self._fh = None
        if persist_path:
            self._load(persist_path)
            self._fh = open(persist_path, "a", encoding="utf-8")

    # -- persistence -------------------------------------------------------
    def _load(self, path: str) -> None:
        if not os.path.exists(path):
            return
        now = self.clock()
        with open(path, encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                self._file_lines += 1
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue          # torn tail write; ignore
                key = tuple(rec["k"])
                age = max(0.0, time.time() - rec.get("t", time.time()))
                if self.ttl_s is not None and age >= self.ttl_s:
                    continue
                # replayed entries restart their TTL clock minus recorded age
                self._data[key] = (rec["v"], now - age, rec.get("o"))
                self._data.move_to_end(key)
                self.loaded += 1
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    def _append(self, key: tuple, row, owner) -> None:
        if self._fh is None or key[0] not in self.persist_namespaces:
            return
        self._fh.write(json.dumps({"k": list(key), "v": row, "o": owner,
                                   "t": time.time()}) + "\n")
        self._file_lines += 1

    def _live_persistable(self) -> int:
        """Entries a compacted log would keep (lock held): persistable
        namespace, not expired."""
        now = self.clock()
        return sum(1 for k, ent in self._data.items()
                   if k[0] in self.persist_namespaces
                   and (self.ttl_s is None or now - ent[1] < self.ttl_s))

    def compact(self) -> int:
        """Rewrite the persistence log to live entries only (the append-only
        log accumulates a dead line for every overwrite, eviction, and TTL
        expiry — across long runs dead lines dominate and the file grows
        without bound).  Atomic replace; returns the number of lines
        dropped."""
        with self._lock:
            if self._fh is None or not self.persist_path:
                return 0
            self._fh.flush()
            self._fh.close()
            now_m, now_w = self.clock(), time.time()
            tmp = self.persist_path + ".compact"
            kept = 0
            with open(tmp, "w", encoding="utf-8") as fh:
                for key, (row, written, owner) in self._data.items():
                    if key[0] not in self.persist_namespaces:
                        continue
                    if self.ttl_s is not None and now_m - written >= self.ttl_s:
                        continue
                    # recorded wall time preserves the entry's age for the
                    # TTL replay on the next load
                    fh.write(json.dumps(
                        {"k": list(key), "v": row, "o": owner,
                         "t": now_w - max(0.0, now_m - written)}) + "\n")
                    kept += 1
            os.replace(tmp, self.persist_path)
            dropped = self._file_lines - kept
            self._file_lines = kept
            self.compactions += 1
            self._fh = open(self.persist_path, "a", encoding="utf-8")
            return dropped

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            open_file = self._fh is not None
            live = self._live_persistable() if open_file else 0
            dead = self._file_lines - live
        if open_file and dead > live:   # dead records dominate: rewrite
            self.compact()
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None

    # -- store protocol (used by BatchedModelCache and the dispatcher) -----
    def get_many(self, keys: Sequence[tuple], *, requester: str | None = None,
                 count: bool = True) -> list[tuple]:
        """-> [(found, row)] per key; expired entries are dropped and count
        as misses.  ``count=False`` is the dispatcher's second-chance lookup,
        which must not re-count prompts the session-side cache already
        counted."""
        out = []
        now = self.clock()
        with self._lock:
            for key in keys:
                ent = self._data.get(key)
                if ent is not None and self.ttl_s is not None \
                        and now - ent[1] >= self.ttl_s:
                    del self._data[key]
                    self.expirations += 1
                    ent = None
                if ent is None:
                    if count:
                        self.misses += 1
                    out.append((False, None))
                else:
                    self._data.move_to_end(key)
                    if count:
                        self.hits += 1
                        if requester is not None and ent[2] != requester:
                            self.cross_hits += 1
                    out.append((True, ent[0]))
        return out

    def put_many(self, keys: Sequence[tuple], rows: Sequence, *,
                 owner: str | None = None,
                 owners: Sequence[str | None] | None = None) -> None:
        now = self.clock()
        if owners is None:
            owners = [owner] * len(keys)
        with self._lock:
            for key, row, own in zip(keys, rows, owners):
                prev = self._data.get(key)
                if prev is not None and prev[0] == row:
                    # freshen recency/TTL, keep the original owner, and skip
                    # the persistence append (no duplicate JSONL rows when
                    # session-side caches re-put dispatcher-answered prompts)
                    self._data[key] = (row, now, prev[2])
                    self._data.move_to_end(key)
                    continue
                self._data[key] = (row, now, own)
                self._data.move_to_end(key)
                self._append(key, row, own)
                if len(self._data) > self.capacity:
                    self._data.popitem(last=False)
                    self.evictions += 1

    def invalidate(self, *, namespaces: Iterable[str] | None = None,
                   contains: str | None = None) -> int:
        """Drop cached answers matching a namespace set and/or a prompt
        substring.  The guarantee auditor's recalibration path: when a
        violation shows a predicate's cached oracle/proxy answers were
        earned under drifted model behavior, purging them forces the next
        query touching that predicate to re-score, re-label, and re-learn
        its cascade thresholds fresh.

        ``contains`` matches against the prompt (the last key element) —
        callers pass the predicate template's longest literal segment, which
        appears verbatim in every rendered prompt.  In-memory only: a
        persisted log still replays the stale rows in the *next* process
        (each entry is one overwrite away from correct there, and the purge
        is re-applied on the next violation); returns entries dropped."""
        ns = None if namespaces is None else frozenset(namespaces)
        with self._lock:
            victims = [
                k for k in self._data
                if (ns is None or k[0] in ns)
                and (contains is None or contains in str(k[-1]))]
            for k in victims:
                del self._data[k]
            self.invalidations += len(victims)
        return len(victims)

    def get(self, key: tuple, *, requester: str | None = None) -> tuple:
        return self.get_many([key], requester=requester)[0]

    def put(self, key: tuple, row, *, owner: str | None = None) -> None:
        self.put_many([key], [row], owner=owner)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            ent = self._data.get(key)
            if ent is None:
                return False
            return self.ttl_s is None or self.clock() - ent[1] < self.ttl_s

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._data), "hits": self.hits,
                "misses": self.misses, "cross_hits": self.cross_hits,
                "hit_rate": self.hits / total if total else 0.0,
                "cross_query_hit_rate": self.cross_hits / total if total else 0.0,
                "evictions": self.evictions, "expirations": self.expirations,
                "loaded": self.loaded, "persist_lines": self._file_lines,
                "compactions": self.compactions,
                "invalidations": self.invalidations,
            }
