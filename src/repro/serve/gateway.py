"""Gateway: multi-tenant admission + scheduling for concurrent semantic
pipelines over one shared runtime.

``submit()`` turns a lazy pipeline (``LazySemFrame`` or a raw plan node)
into a :class:`ServeSession` and parks it in the admission queue — FIFO
within a tenant, round-robin across tenants, so one chatty tenant cannot
starve the rest.  ``max_inflight`` worker threads pull sessions and execute
their plans through :class:`PlanExecutor` with three serving-specific
handles injected:

  * oracle/proxy: ``BatchedModelCache`` (per-session dedup, counted consult
    of the shared store) over ``DispatchedModel`` (cross-query micro-batch
    fusion in the :class:`MicroBatchDispatcher`);
  * embedder: ``DispatchedEmbedder`` (fused + store-backed, memory-only);
  * ``stage_hook``: the session's cancellation/deadline check, honored at
    every plan-node boundary.

A bounded queue (``max_pending``) sheds load with :class:`AdmissionError`
instead of building unbounded backlog; per-session accounting rolls up via
``accounting.session_scope`` so each session reports its own OpStats even
though backend calls are fused across sessions.

Partitioned execution: with ``n_partitions`` set (or passed through
``optimizer_kw``), each session's optimizer cuts big operators into
Exchange-bounded fragments and its :class:`PartitionedExecutor` schedules
them on the gateway's shared *fragment pool* — a second thread pool sized
``fragment_workers``, deliberately separate from the session workers so a
session waiting on its own fragments can never deadlock the pool that must
run them.  Fragment model calls carry the session's accounting context
(``accounting.capture``/``activate``), so per-partition work still rolls up
into the right ``session_scope``, and per-session fragment counts feed
``GatewayMetrics`` (``fragments_run`` / ``partitioned_ops``).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import uuid
from collections import deque
from concurrent.futures import ThreadPoolExecutor

from repro.core import accounting
from repro.core.plan.adaptive import AdaptivePlanExecutor, AdaptivePolicy
from repro.core.plan.cache import BatchedModelCache
from repro.obs import StatsStore
from repro.obs import audit as _audit
from repro.obs import trace as _trace
from repro.core.plan.execute import PartitionedExecutor
from repro.core.plan.nodes import LogicalNode
from repro.core.plan.optimize import PlanOptimizer
from repro.serve.dispatch import (DispatchedEmbedder, DispatchedModel,
                                  MicroBatchDispatcher)
from repro.serve.index_registry import IndexRegistry
from repro.serve.matview import MatViewRegistry
from repro.serve.metrics import GatewayMetrics
from repro.serve.session import (CANCELLED, DONE, EXPIRED, FAILED, RUNNING,
                                 ServeSession, SessionCancelled,
                                 SessionDeadlineExceeded)
from repro.serve.store import SharedSemanticCache
from repro.stream.continuous import Subscription, pin_stream_scans


class AdmissionError(RuntimeError):
    """The gateway's pending queue is full; retry later or shed the query."""


def _raw(model):
    """Unwrap Session's Counted* layers: dispatched handles do their own
    per-session attribution, so the backend must not double-count."""
    return getattr(model, "_m", getattr(model, "_e", model))


class Gateway:
    def __init__(self, session, *, max_inflight: int = 4,
                 max_pending: int = 64, window_s: float = 0.002,
                 max_batch: int = 64, store: SharedSemanticCache | None = None,
                 cache_capacity: int = 100_000, cache_ttl_s: float | None = None,
                 persist_path: str | None = None,
                 optimizer_kw: dict | None = None,
                 history_limit: int = 1024,
                 index_registry: IndexRegistry | None = None,
                 n_partitions: int | None = None,
                 fragment_workers: int = 4,
                 trace: "bool | _trace.Tracer" = False,
                 stats_store: StatsStore | None = None,
                 stats_decay: float = 1.0,
                 stats_load_discount: float = 1.0,
                 adaptive: "bool | AdaptivePolicy" = False,
                 matview: "bool | MatViewRegistry" = False,
                 matview_capacity: int = 64,
                 audit: "bool | _audit.AuditPolicy | None" = None):
        self.session = session
        # trace=True builds a gateway-lifetime tracer (or pass your own);
        # spans from every layer — session, plan stage, operator, fragment,
        # dispatcher batch, kernel, index build, cache lookup — parent into
        # per-session roots, exportable via export_trace()/session_trace()
        if trace is True:
            self.tracer = _trace.Tracer()
        else:
            self.tracer = trace if isinstance(trace, _trace.Tracer) else None
        # observed operator statistics keyed by (operator, fingerprint),
        # persisted alongside the semantic cache when it persists
        self._stats_path = f"{persist_path}.stats.json" if persist_path \
            else None
        self.stats_store = stats_store if stats_store is not None \
            else StatsStore(self._stats_path, decay=stats_decay,
                            load_discount=stats_load_discount)
        # adaptive=True (or a policy) runs sessions on AdaptivePlanExecutor:
        # mid-query filter re-ranking, retrieval switching, fragment resizing
        # from observed cardinalities — record-identical by the strict-mode
        # contract (core.plan.adaptive)
        if isinstance(adaptive, AdaptivePolicy):
            self._adaptive_policy: AdaptivePolicy | None = adaptive
        else:
            self._adaptive_policy = AdaptivePolicy() if adaptive else None
        # matview=True (or a registry) shares materialized subplan results
        # across concurrent sessions by plan fingerprint
        if isinstance(matview, MatViewRegistry):
            self.matviews: MatViewRegistry | None = matview
        else:
            self.matviews = MatViewRegistry(capacity=matview_capacity) \
                if matview else None
        self.store = store if store is not None else SharedSemanticCache(
            capacity=cache_capacity, ttl_s=cache_ttl_s,
            persist_path=persist_path)
        # one retrieval index per (corpus, embedder, config) across ALL
        # sessions: concurrent pipelines over the same corpus build once
        self.index_registry = index_registry if index_registry is not None \
            else IndexRegistry()
        self.dispatcher = MicroBatchDispatcher(
            oracle=_raw(session.oracle),
            proxy=_raw(session.proxy) if session.proxy is not None else None,
            embedder=_raw(session.embedder)
            if session.embedder is not None else None,
            store=self.store, window_s=window_s, max_batch=max_batch,
            tracer=self.tracer)
        self.metrics = GatewayMetrics()
        # audit=True / an AuditPolicy turns on continuous guarantee auditing
        # (default: the REPRO_AUDIT env var).  The auditor's gold oracle is a
        # dispatcher handle on a dedicated background-priority `audit` role:
        # its traffic fuses into wide batches, never consults or warms the
        # query-visible cache, and bills to the `audit` accounting kind —
        # query results and oracle bills stay bit-identical with it on/off.
        if audit is None:
            audit = bool(os.environ.get("REPRO_AUDIT"))
        self._audit_path = f"{persist_path}.audit.json" if persist_path \
            else None
        if audit:
            policy = audit if isinstance(audit, _audit.AuditPolicy) \
                else _audit.AuditPolicy()
            self.dispatcher.add_backend("audit", _raw(session.oracle),
                                        background=True)
            self.auditor: _audit.GuaranteeAuditor | None = \
                _audit.GuaranteeAuditor(
                    DispatchedModel(self.dispatcher, "audit", tag="audit"),
                    policy=policy, stats_store=self.stats_store,
                    on_violation=self._on_violation, path=self._audit_path)
        else:
            self.auditor = None
        self.max_pending = max_pending
        self.optimizer_kw = dict(optimizer_kw or {})
        if n_partitions is not None:
            self.optimizer_kw.setdefault("n_partitions", n_partitions)
        # fragment pool, shared by every session's PartitionedExecutor:
        # fragments never spawn fragments, so a fixed pool cannot deadlock.
        # Only spun up when partition planning can actually emit fragments —
        # an unpartitioned gateway should not carry idle threads.
        partitioning = (self.optimizer_kw.get("n_partitions") or 0) >= 2
        self._fragment_pool = ThreadPoolExecutor(
            max_workers=fragment_workers, thread_name_prefix="gw-frag") \
            if partitioning and fragment_workers and fragment_workers > 1 \
            else None
        self._cv = threading.Condition()
        self._queues: dict[str, deque[ServeSession]] = {}
        self._tenants: list[str] = []
        self._rr = 0
        self._closed = False
        self._counter = 0
        # session ids must be unique across gateway instances AND runs:
        # the shared/persistent store attributes entry ownership by sid, so
        # a colliding id would hide genuine cross-run cache sharing
        self._gid = uuid.uuid4().hex[:6]
        # resolved sessions age out of this ring so a long-lived gateway
        # doesn't pin every result set ever produced; callers keep their own
        # handles, and wait_all() tracks only unresolved sessions
        self.sessions: deque[ServeSession] = deque(maxlen=history_limit)
        self._unresolved: dict[str, ServeSession] = {}
        self._subscriptions: list[Subscription] = []
        self._workers = [threading.Thread(target=self._worker, daemon=True,
                                          name=f"gateway-worker-{i}")
                         for i in range(max_inflight)]
        for w in self._workers:
            w.start()

    # -- admission ---------------------------------------------------------
    def submit(self, pipeline, *, tenant: str = "default",
               optimize: bool = True, deadline_s: float | None = None,
               session_id: str | None = None) -> ServeSession:
        plan = pipeline.plan if hasattr(pipeline, "plan") else pipeline
        if not isinstance(plan, LogicalNode):
            raise TypeError("submit() takes a LazySemFrame or a plan node, "
                            f"got {type(pipeline).__name__}")
        with self._cv:
            if self._closed:
                raise RuntimeError("gateway is closed")
            pending = sum(len(q) for q in self._queues.values())
            if pending >= self.max_pending:
                self.metrics.on_reject(tenant=tenant)
                raise AdmissionError(
                    f"gateway queue full ({pending}/{self.max_pending} pending)")
            self._counter += 1
            sess = ServeSession(
                sid=session_id or f"{self._gid}-s{self._counter:04d}", plan=plan,
                tenant=tenant, optimize=optimize, deadline_s=deadline_s)
            self._queues.setdefault(tenant, deque()).append(sess)
            if tenant not in self._tenants:
                self._tenants.append(tenant)
            self.sessions.append(sess)
            self._unresolved[sess.sid] = sess
            self.metrics.on_submit(tenant=tenant)
            self._cv.notify()
        return sess

    def subscribe(self, pipeline, *, tenant: str = "default",
                  optimize: bool = True, emit_initial: bool = True
                  ) -> Subscription:
        """Register a continuous query: re-execute ``pipeline`` (whose plan
        must scan at least one ``CorpusTable``) on every table commit,
        through the normal admission path.  Returns the
        :class:`~repro.stream.continuous.Subscription` emission handle; the
        shared semantic cache keeps re-executions delta-only (monotone ops
        pay the oracle for new rows, cached judgments cover the rest)."""
        plan = pipeline.plan if hasattr(pipeline, "plan") else pipeline
        sub = Subscription(self, plan, tenant=tenant, optimize=optimize,
                           emit_initial=emit_initial)
        with self._cv:
            closed = self._closed
            if not closed:
                self._subscriptions.append(sub)
        if closed:
            sub.cancel(wait=False)  # release the table listeners
            raise RuntimeError("gateway is closed")
        self.metrics.on_subscribe()
        return sub.start()

    def _discard_subscription(self, sub) -> None:
        """Called by Subscription.cancel(): a cancelled subscription must
        not stay referenced (plan + last result set) for the gateway's
        lifetime."""
        with self._cv:
            try:
                self._subscriptions.remove(sub)
            except ValueError:
                pass

    # -- scheduling --------------------------------------------------------
    def _pop_next(self) -> ServeSession | None:
        """Round-robin across tenants, FIFO within each (lock held)."""
        n = len(self._tenants)
        for i in range(n):
            tenant = self._tenants[(self._rr + i) % n]
            q = self._queues[tenant]
            if q:
                self._rr = (self._rr + i + 1) % n
                return q.popleft()
        return None

    def _worker(self) -> None:
        while True:
            with self._cv:
                sess = self._pop_next()
                while sess is None and not self._closed:
                    self._cv.wait()
                    sess = self._pop_next()
                if sess is None:
                    return
            self._run(sess)

    # -- guarantee auditing ------------------------------------------------
    def _on_violation(self, event) -> None:
        """Runs on the auditor's worker thread when a CI lower bound crosses
        its declared target: raise the alert counter and — when the policy
        asks for recalibration — purge the predicate's cached oracle/proxy
        answers, so the next query touching it re-scores, re-labels, and
        re-learns its cascade thresholds against current model behavior.
        (The auditor itself already poisoned the StatsStore fingerprint.)"""
        self.metrics.on_violation(event.kind)
        aud = self.auditor
        if aud is not None and aud.policy.recalibrate and event.match_token:
            self.store.invalidate(namespaces=("oracle", "proxy"),
                                  contains=event.match_token)

    # -- execution ---------------------------------------------------------
    def _handles(self, sid: str):
        oracle = BatchedModelCache(
            DispatchedModel(self.dispatcher, "oracle", tag=sid),
            store=self.store, namespace="oracle", requester=sid)
        proxy = None
        if self.session.proxy is not None:
            proxy = BatchedModelCache(
                DispatchedModel(self.dispatcher, "proxy", tag=sid),
                store=self.store, namespace="proxy", requester=sid)
        embedder = None
        if self.session.embedder is not None:
            embedder = DispatchedEmbedder(self.dispatcher, tag=sid)
        return oracle, proxy, embedder

    def _resolve(self, sess: ServeSession, status: str, *,
                 records: list | None = None,
                 error: BaseException | None = None) -> None:
        sess.finish(status, records=records, error=error)
        self.metrics.on_finish(status, sess.latency_s,
                               len(records) if records is not None else None,
                               tenant=sess.tenant)
        with self._cv:
            self._unresolved.pop(sess.sid, None)

    def _run(self, sess: ServeSession) -> None:
        try:
            sess.check()                 # cancelled / expired while queued
        except SessionCancelled as exc:
            self._resolve(sess, CANCELLED, error=exc)
            return
        except SessionDeadlineExceeded as exc:
            self._resolve(sess, EXPIRED, error=exc)
            return
        sess.status = RUNNING
        sess.started_at = time.monotonic()
        oracle, proxy, embedder = self._handles(sess.sid)
        exec_kw = {k: self.optimizer_kw[k]
                   for k in ("recall_target", "index_min_corpus")
                   if k in self.optimizer_kw}
        if self._adaptive_policy is not None:
            exec_cls = AdaptivePlanExecutor
            exec_kw["policy"] = self._adaptive_policy
        else:
            exec_cls = PartitionedExecutor
        executor = exec_cls(
            self.session, stats_log=sess.stats_log, oracle=oracle,
            proxy=proxy, embedder=embedder,
            stage_hook=lambda node: sess.check(),
            index_registry=self.index_registry,
            fragment_pool=self._fragment_pool,
            stats_store=self.stats_store, matviews=self.matviews, **exec_kw)
        try:
            # the tracer (when on) wraps the whole session in one root span;
            # fragment/dispatcher threads parent into it via the captured
            # accounting context / the dispatcher's tracer handle
            # the auditor context rides the worker thread (and fragment
            # threads, via accounting.capture) so every cascade/search this
            # session runs emits its auto-decisions for sampling
            with _trace.activate(self.tracer), \
                    _audit.activate_ctx(self.auditor), \
                    _trace.span_in(self.tracer, sess.sid, "session",
                                   sid=sess.sid, tenant=sess.tenant) as sp, \
                    accounting.session_scope(sess.sid) as st:
                sess.stats = st
                # pin floating StreamScans to the versions current NOW: one
                # run never sees two versions even while writers commit
                plan = pin_stream_scans(sess.plan)
                if sess.optimize:
                    # the registry shares builds across sessions, so the
                    # optimizer may amortize IVF build cost over traffic
                    optimizer = PlanOptimizer(
                        self.session, oracle=oracle, proxy=proxy,
                        seed=self.session.seed,
                        **{"index_shared": True,
                           "stats_store": self.stats_store,
                           **self.optimizer_kw})
                    if self._adaptive_policy is not None:
                        # re-plans reuse the planner's own knobs (partition
                        # counts, quantization policy)
                        executor.optimizer = optimizer
                    with accounting.track("plan_optimize") as opt_st:
                        plan = optimizer.optimize(plan)
                    opt_st.details.update(
                        rewrites=[str(r) for r in optimizer.applied])
                    sess.stats_log.append(opt_st.as_dict())
                records = executor.run(plan)
                sp.set(rows_out=len(records), status=DONE)
            self._resolve(sess, DONE, records=records)
        except SessionCancelled as exc:
            self._resolve(sess, CANCELLED, error=exc)
        except SessionDeadlineExceeded as exc:
            self._resolve(sess, EXPIRED, error=exc)
        except BaseException as exc:
            self._resolve(sess, FAILED, error=exc)
        finally:
            # per-session partition-fragment accounting (0/0 when the plan
            # ran single-partition)
            self.metrics.on_fragments(executor.fragments_run,
                                      executor.partitioned_ops)
            for entry in sess.stats_log:
                if isinstance(entry, dict) and "candidate_pairs" in entry:
                    self.metrics.on_join_stats(entry)
            replans = getattr(executor, "replans", ())
            if replans:
                self.metrics.on_replans(len(replans))
                sess.replans = [dataclasses.asdict(e) for e in replans]

    # -- lifecycle ---------------------------------------------------------
    def wait_all(self, timeout: float | None = None) -> bool:
        """Block until every outstanding session has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            outstanding = list(self._unresolved.values())
        for sess in outstanding:
            left = None if deadline is None else \
                max(deadline - time.monotonic(), 0.0)
            if not sess.wait(left):
                return False
        return True

    def snapshot(self) -> dict:
        snap = self.metrics.snapshot(store=self.store,
                                     dispatcher=self.dispatcher,
                                     tracer=self.tracer)
        snap.update(self.index_registry.metrics())
        if self.matviews is not None:
            snap.update(self.matviews.metrics())
        if self.auditor is not None:
            snap["audit"] = self.auditor.report()
        return snap

    def metrics_registry(self):
        """Build a fresh ``MetricsRegistry`` and collect every subsystem's
        series into it: gateway throughput + per-tenant SLOs, cache,
        dispatcher, index/matview registries, and (when auditing is on) the
        guarantee CIs and violation counters."""
        from repro.obs.metrics import MetricsRegistry
        reg = MetricsRegistry()
        self.metrics.collect(reg, store=self.store,
                             dispatcher=self.dispatcher)
        for prefix, counters in (("index", self.index_registry.metrics()),
                                 ("matview", self.matviews.metrics()
                                  if self.matviews is not None else {})):
            if not counters:
                continue
            g = reg.gauge(f"repro_{prefix}_registry",
                          f"{prefix} registry counters", ("counter",))
            for k, v in counters.items():
                g.set(v, counter=k)
        if self.auditor is not None:
            self.auditor.collect(reg)
        return reg

    def metrics_text(self) -> str:
        """The Prometheus text exposition of :meth:`metrics_registry`."""
        return self.metrics_registry().render()

    # -- trace / stats export ---------------------------------------------
    def export_trace(self, path: str, *, fmt: str = "jsonl") -> int:
        """Write every span recorded so far; ``fmt`` is ``"jsonl"`` (one
        span per line) or ``"chrome"`` (Perfetto-loadable trace_event
        JSON).  Returns the span count; raises if tracing is off."""
        if self.tracer is None:
            raise RuntimeError("gateway built without trace=True")
        if fmt == "chrome":
            return self.tracer.export_chrome(path)
        if fmt == "jsonl":
            return self.tracer.export_jsonl(path)
        raise ValueError(f"unknown trace format {fmt!r}")

    def session_trace(self, sid: str) -> list:
        """All spans belonging to one serve session (its root span plus
        every descendant, across worker/fragment threads)."""
        if self.tracer is None:
            raise RuntimeError("gateway built without trace=True")
        out = []
        for root in self.tracer.session_spans(sid):
            out.extend(self.tracer.subtree(root))
        return sorted(out, key=lambda s: s.t0)

    def close(self) -> None:
        # drain subscriptions BEFORE closing workers (in-flight runs still
        # resolve), looping until none appear: a subscribe() racing close()
        # either lands in the list (cancelled next pass) or observes
        # _closed and cancels itself
        while True:
            with self._cv:
                subs = list(self._subscriptions)
                self._subscriptions.clear()
                if not subs:
                    self._closed = True
                    break
            for sub in subs:
                sub.cancel(wait=True)
        with self._cv:
            self._cv.notify_all()
        for w in self._workers:
            w.join(timeout=10.0)
        if self._fragment_pool is not None:
            self._fragment_pool.shutdown(wait=True)
        if self.auditor is not None:
            # drain pending audit judgments through the still-open
            # dispatcher (its close() flushes remaining buckets), then
            # persist the audit accumulators next to the stats store
            self.auditor.close()
        self.dispatcher.close()
        if self._stats_path:
            # observed operator statistics persist next to the semantic
            # cache, so the next process prices plans from observed reality
            self.stats_store.save(self._stats_path)
        self.store.close()

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
