"""Distribution layer: logical-axis sharding rules, context-parallel decode,
and pipeline parallelism.

``sharding`` is pure rule resolution (no device state touched at import);
``context_parallel`` / ``pipeline_parallel`` hold the multi-device execution
paths exercised by tests/test_dist.py in forced-8-device subprocesses.
"""
