"""Context-parallel decode attention (shard_map over the KV-sequence axis).

At long contexts the decode step is KV-cache-bandwidth-bound, so the cache is
sharded along its *sequence* dimension across the ``model`` axis; each device
attends over its local KV slice with flash-style partial-softmax statistics
(m, l, o) that are combined with one pmax + psum across the axis.  The new
token's K/V is written only by the shard whose slice contains ``cache_len``
(out-of-range writes are dropped), so the returned cache keeps the same
sharded layout it arrived with.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map
except ImportError:  # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map

from repro.models.attention import NEG_INF, _repeat_kv, out_proj, project_qkv


def cp_decode_self_attention(params, x, k_cache, v_cache, cache_len, *,
                             cfg, mesh, axis="model", dp_spec="data"):
    """Sequence-sharded decode attention.

    x: [B,1,D]; caches: [B,Smax,Hk,hd] sharded P(dp_spec, axis, None, None);
    ``cache_len`` scalar or [B].  Returns (out [B,1,D], new_k, new_v) with the
    caches still sequence-sharded.
    """
    b, s_max = x.shape[0], k_cache.shape[1]
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    # global key positions, sharded like the cache's sequence dim: each shard
    # sees its own slice, which sidesteps axis_index math for tuple axes.
    pos = jnp.arange(s_max, dtype=jnp.int32)
    axes = axis if isinstance(axis, tuple) else (axis,)

    kv_spec = P(dp_spec, axis, None, None)
    bat_spec = P(dp_spec)

    def body(params, x, kc, vc, lens, pos):
        b_l, s_l = kc.shape[0], kc.shape[1]
        q, k_new, v_new = project_qkv(params, x, cfg=cfg, positions=lens[:, None])
        # scatter the new K/V into whichever shard owns position ``lens``
        local = lens - pos[0]
        safe = jnp.where((local >= 0) & (local < s_l), local, s_l)  # s_l -> dropped
        bidx = jnp.arange(b_l)
        kc = kc.at[bidx, safe].set(k_new[:, 0].astype(kc.dtype), mode="drop")
        vc = vc.at[bidx, safe].set(v_new[:, 0].astype(vc.dtype), mode="drop")

        k_valid = pos[None, :] <= lens[:, None]
        if cfg.sliding_window:
            k_valid = k_valid & (lens[:, None] - pos[None, :] < cfg.sliding_window)

        h = q.shape[2]
        k_full = _repeat_kv(kc, h)
        v_full = _repeat_kv(vc, h)
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k_full,
                            preferred_element_type=jnp.float32) * scale
        scores = jnp.where(k_valid[:, None, None, :], scores, NEG_INF)

        m_loc = jnp.max(scores, axis=-1)                       # [b,h,1]
        m = jax.lax.pmax(m_loc, axes)
        p = jnp.exp(scores - m[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), axes)            # [b,h,1]
        o = jax.lax.psum(jnp.einsum("bhqs,bshd->bqhd", p.astype(v_full.dtype),
                                    v_full), axes)             # [b,1,h,hd]
        out = o / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        return out.astype(x.dtype), kc, vc

    attn, kc, vc = shard_map(
        body, mesh=mesh,
        in_specs=(P(), bat_spec, kv_spec, kv_spec, bat_spec, P(axis)),
        out_specs=(bat_spec, kv_spec, kv_spec),
        check_rep=False)(params, x, k_cache, v_cache, lens, pos)
    return out_proj(params, attn), kc, vc
