"""Logical-axis -> mesh-axis sharding rules (GSPMD side of the dist layer).

Models name every tensor dimension with a *logical* axis ("embed_in",
"kv_heads", "batch", ...; see ``repro.common.ParamSpec``).  A rule table maps
each logical axis to an ordered tuple of *candidate* mesh axes, and
``resolve_pspec`` turns (shape, logical axes, mesh, rules) into a concrete
``PartitionSpec`` under two invariants:

  * divisibility fallback — a mesh axis is only taken while the accumulated
    shard count divides the dimension size (a 6-head tensor on a 4-wide
    ``model`` axis stays replicated rather than erroring);
  * each mesh axis is used at most once per spec, first dimension wins
    (``batch`` grabbing ``data`` leaves ``kv_seq`` only ``model``).

``activation_rules`` installs a (mesh, rules) context consumed by
``shard_activation`` inside model code — the models never mention mesh axes.

Version compat: this repo runs against jax>=0.4.37; ``abstract_mesh`` /
``set_mesh`` paper over the AbstractMesh-constructor and ambient-mesh API
changes between 0.4.x and 0.5+ so tests and launch scripts are portable.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_ctx = threading.local()


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Logical axis -> ordered candidate mesh axes.  Missing / empty -> replicated.
_TRAIN_RULES = {
    # parameter axes: FSDP-style over "data", tensor-parallel over "model"
    "embed_in": ("data",),
    "embed_out": ("data",),
    "embed": ("data",),
    "vocab": ("model",),
    "mlp": ("model",),
    "mlp_out": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "experts_in": ("data",),
    "layers": ("pod",),
    # activation axes
    "batch": ("pod", "data"),
    "seq_act": ("model",),
    "embed_act": ("model",),
    "kv_seq": ("data", "model"),
    "frames": (),
    "seq": (),
    "qkv": (),
    "qkv_in": (),
}

# Serving with weights replicated over "data" (throughput replicas); only the
# head-ish axes are tensor-parallel and the KV cache is context-parallel over
# "model" (kv_seq listed before kv_heads so the sequence dim wins the axis).
_SERVE_REPLICATED_RULES = {
    "vocab": ("model",),
    "mlp": ("model",),
    "mlp_out": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "batch": ("pod", "data"),
    "kv_seq": ("model",),
    "seq_act": (),
    "embed_act": (),
}

RULE_TABLES: dict[str, dict[str, tuple[str, ...]]] = {
    "default": _TRAIN_RULES,
    "serve_replicated": _SERVE_REPLICATED_RULES,
}


def _rules_table(rules) -> dict:
    return RULE_TABLES[rules] if isinstance(rules, str) else rules


def _mesh_sizes(mesh) -> dict[str, int]:
    shape = mesh.shape  # OrderedDict name -> size on Mesh and AbstractMesh
    return dict(shape)


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


def resolve_pspec(shape, axes, mesh, rules) -> P:
    """(shape, logical axes, mesh, rule table|name) -> PartitionSpec.

    Greedy per-dimension: walk each dimension's candidate mesh axes in rule
    order, taking an axis only if it exists on the mesh, is still unused in
    this spec, and the accumulated shard count keeps dividing the dimension.
    """
    table = _rules_table(rules)
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    entries = []
    for dim, ax in zip(shape, axes):
        taken: list[str] = []
        prod = 1
        for cand in table.get(ax, ()) if ax is not None else ():
            if cand not in sizes or cand in used:
                continue
            if dim % (prod * sizes[cand]) != 0:
                continue
            taken.append(cand)
            prod *= sizes[cand]
        used.update(taken)
        entries.append(None if not taken else taken[0] if len(taken) == 1 else tuple(taken))
    return P(*entries)


def spec_shardings(specs, mesh, rules="default"):
    """SpecTree {path: ParamSpec} -> nested tree of NamedSharding."""
    from repro.common import unflatten
    table = _rules_table(rules)
    return unflatten({
        path: NamedSharding(mesh, resolve_pspec(s.shape, s.axes, mesh, table))
        for path, s in specs.items()})


# ---------------------------------------------------------------------------
# Activation-sharding context
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def activation_rules(mesh, rules="default"):
    """Install (mesh, rules) so ``shard_activation`` constrains activations."""
    prev = getattr(_ctx, "cfg", None)
    _ctx.cfg = (mesh, _rules_table(rules))
    try:
        yield
    finally:
        _ctx.cfg = prev


def shard_activation(x, axes):
    """Sharding hint on an activation; identity when no rules are installed."""
    ctx = getattr(_ctx, "cfg", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_pspec(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# jax version compat
# ---------------------------------------------------------------------------


def abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh across jax versions (0.4.x takes ((name, size), ...))."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))


@contextlib.contextmanager
def set_mesh(mesh):
    """Ambient-mesh context: jax.set_mesh on 0.5+, the Mesh context on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
