"""Pipeline parallelism (GPipe-style, dense decoder stacks).

The layer stack is partitioned into S = |first mesh axis| contiguous stages
and microbatches flow through them on the classic (n_micro + S - 1)-tick
schedule: at tick t, stage s runs microbatch t-s.  The tick loop is traced
(unrolled), so work items at the same tick have no data dependencies between
them and XLA is free to overlap them; *placement* of each stage's weights on
its pod comes from the ``layers -> pod`` rule in ``repro.dist.sharding``
(``spec_shardings`` shards the stacked layer dimension across the first mesh
axis, which is exactly stage-stationary weight placement).

Numerics are identical to ``registry.forward``: the schedule only reorders
independent per-microbatch work, and the loss combines per-microbatch CE
sums with a shared valid-token denominator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.tokenizer import TOKENIZER
from repro.models import layers as Lyr
from repro.models import transformer as T


def _n_stages(cfg: ModelConfig, mesh) -> int:
    s = dict(mesh.shape)[mesh.axis_names[0]]
    return s if cfg.num_layers % s == 0 else 1


def _stage_tree(params, n_stages: int):
    return jax.tree.map(
        lambda a: a.reshape((n_stages, a.shape[0] // n_stages) + a.shape[1:]),
        params["layers"])


def _apply_stage(stage_p, h, *, cfg: ModelConfig):
    def body(x, lp):
        x, _, _ = T._decoder_layer_seq(lp, x, cfg=cfg, use_moe=False)
        return x, None

    h, _ = jax.lax.scan(body, h, stage_p)
    return h


def pp_forward(cfg: ModelConfig, mesh, params, tokens, *, n_micro: int = 4):
    """tokens [B,S] -> logits [B,S,V] via the staged microbatch schedule."""
    if T.layer_layout(cfg)["kind"] != "dense":
        raise NotImplementedError("pipeline parallelism covers dense stacks")
    n_stages = _n_stages(cfg, mesh)
    stages = _stage_tree(params, n_stages)
    b = tokens.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    toks = tokens.reshape((n_micro, b // n_micro) + tokens.shape[1:])

    acts: list = [None] * n_micro
    for t in range(n_micro + n_stages - 1):
        for s in range(n_stages - 1, -1, -1):  # later stages first (drain order)
            m = t - s
            if not 0 <= m < n_micro:
                continue
            if s == 0:
                h = Lyr.embed(params["embed"], toks[m]).astype(cfg.activation_dtype)
            else:
                h = acts[m]
            acts[m] = _apply_stage(jax.tree.map(lambda a, s=s: a[s], stages), h, cfg=cfg)

    x = jnp.concatenate(acts, axis=0)
    x = Lyr.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return Lyr.unembed({**params.get("out", {}), **params["embed"]}, x,
                       tied=cfg.tie_embeddings)


def make_pp_loss(cfg: ModelConfig, mesh, *, n_micro: int = 4):
    """Causal-LM CE over the pipelined forward (same math as trainstep.loss_fn
    for dense models: PAD labels ignored, one global token denominator)."""

    def loss(params, tokens, labels):
        logits = pp_forward(cfg, mesh, params, tokens, n_micro=n_micro)
        valid = (labels != TOKENIZER.pad_id) & (labels >= 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.take_along_axis(logp, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
        return -jnp.sum(jnp.where(valid, tgt, 0.0)) / jnp.maximum(jnp.sum(valid), 1)

    return loss
