"""Process-wide observed-statistics store for adaptive optimization.

The optimizer prices plans from a static importance sample; this store is
the feedback path: every executed plan node reports its observed
cardinalities, model-call bill, and wall time keyed by
``(operator, predicate-fingerprint)``, so a future adaptive optimizer (and
``explain_analyze`` today) can compare the cost model's predictions with
what the same predicate actually did across sessions.

The fingerprint hashes the semantics of the node — the natural-language
template / query / target columns — not the input data, so observations
for one predicate accumulate across corpora of different sizes (selectivity
is a property of the predicate, per the paper's proxy-calibration setup).

Persistence is a small JSON document saved alongside the semantic cache
(the gateway saves it in ``close()``); ``load()`` merges additively so
multiple processes can fold their runs together.

Windowing: a feedback loop must weight the last five minutes over last
month's sessions, so the store supports exponential decay — with
``decay < 1`` every accumulator (runs, rows, calls, wall) is multiplied by
``decay`` before each new observation folds in, making the stored values
exponentially-weighted sums whose ratios (selectivity, calls/row) become
EWMAs.  ``load(path, discount=...)`` down-weights a persisted store the
same way, so history carried across processes arrives as a prior, not a
veto.  The default ``decay=1.0`` keeps the original additive semantics.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import threading

log = logging.getLogger(__name__)


def predicate_fingerprint(operator: str, *parts) -> str:
    """Stable 16-hex-char fingerprint of an operator's semantic identity."""
    h = hashlib.sha1()
    h.update(operator.encode())
    for p in parts:
        h.update(b"\x1f")
        h.update(str(p).encode())
    return h.hexdigest()[:16]


def node_fingerprint(node) -> str | None:
    """Fingerprint a plan node by its semantic payload (duck-typed so this
    module stays import-free of the plan IR).  Returns None for nodes with
    no semantic identity worth accumulating (scans, limits, exchanges)."""
    kind = type(node).__name__
    parts = []
    for attr in ("langex", "template", "query", "instruction"):
        v = getattr(node, attr, None)
        if v is None:
            continue
        # langex objects carry the natural-language template
        v = getattr(v, "template", v)
        parts.append(v)
    for attr in ("on", "columns", "by", "k", "fields"):
        v = getattr(node, attr, None)
        if v is not None and not callable(v):  # some IRs expose columns()
            parts.append(f"{attr}={v}")
    if not parts:
        return None
    return predicate_fingerprint(kind, *parts)


_SUM_FIELDS = ("rows_in", "rows_out", "oracle_calls", "proxy_calls",
               "embed_calls", "compare_calls", "generate_calls",
               "cache_hits")


@dataclasses.dataclass
class ObservedStats:
    # accumulators are ints under the default additive semantics and become
    # exponentially-weighted float sums once the store decays (decay < 1)
    operator: str
    fingerprint: str
    runs: float = 0
    rows_in: float = 0
    rows_out: float = 0
    oracle_calls: float = 0
    proxy_calls: float = 0
    embed_calls: float = 0
    compare_calls: float = 0
    generate_calls: float = 0
    cache_hits: float = 0
    wall_s: float = 0.0
    details: dict = dataclasses.field(default_factory=dict)

    @property
    def selectivity(self) -> float | None:
        if self.rows_in <= 0:
            return None
        return self.rows_out / self.rows_in

    @property
    def mean_wall_s(self) -> float:
        return self.wall_s / self.runs if self.runs else 0.0

    @property
    def oracle_calls_per_row(self) -> float:
        return self.oracle_calls / self.rows_in if self.rows_in else 0.0

    def as_dict(self) -> dict:
        rnd = lambda v: v if isinstance(v, int) else round(v, 4)
        d = {"operator": self.operator, "fingerprint": self.fingerprint,
             "runs": rnd(self.runs), "wall_s": round(self.wall_s, 6),
             "selectivity": (round(self.selectivity, 6)
                             if self.selectivity is not None else None),
             "details": {k: rnd(v) if isinstance(v, (int, float))
                         and not isinstance(v, bool) else v
                         for k, v in self.details.items()}}
        for f in _SUM_FIELDS:
            d[f] = rnd(getattr(self, f))
        return d


class StatsStore:
    """Accumulates ``ObservedStats`` keyed by (operator, fingerprint)."""

    def __init__(self, path: str | None = None, *, decay: float = 1.0,
                 load_discount: float = 1.0):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay={decay} (expected 0 < decay <= 1)")
        self._lock = threading.Lock()
        self._stats: dict[tuple[str, str], ObservedStats] = {}
        self.decay = decay
        self.path = path
        self.poisoned = 0     # entries dropped by guarantee-audit violations
        if path and os.path.exists(path):
            self.load(path, discount=load_discount)

    def _age(self, obs: ObservedStats) -> None:
        """Apply one step of exponential decay (lock held). runs becomes the
        EWMA weight mass, so ratio properties stay unbiased."""
        if self.decay >= 1.0:
            return
        d = self.decay
        obs.runs *= d
        obs.wall_s *= d
        for f in _SUM_FIELDS:
            setattr(obs, f, getattr(obs, f) * d)
        for k in obs.details:
            v = obs.details[k]
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                obs.details[k] = v * d

    def observe(self, operator: str, fingerprint: str, *, rows_in: int = 0,
                rows_out: int = 0, wall_s: float = 0.0,
                stats: dict | None = None, **details) -> ObservedStats:
        with self._lock:
            key = (operator, fingerprint)
            obs = self._stats.get(key)
            if obs is None:
                obs = self._stats[key] = ObservedStats(operator, fingerprint)
            self._age(obs)
            obs.runs += 1
            obs.rows_in += int(rows_in)
            obs.rows_out += int(rows_out)
            obs.wall_s += float(wall_s)
            if stats:
                for f in ("oracle_calls", "proxy_calls", "embed_calls",
                          "compare_calls", "generate_calls", "cache_hits"):
                    v = stats.get(f)
                    if isinstance(v, (int, float)) and not isinstance(v, bool):
                        setattr(obs, f, getattr(obs, f) + int(v))
            for k, v in details.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    obs.details[k] = obs.details.get(k, 0) + v
            return obs

    def observe_node(self, node, stats: dict | None, *, rows_in: int,
                     rows_out: int, wall_s: float = 0.0) -> ObservedStats | None:
        """Record one plan-node execution; skips nodes with no semantic
        fingerprint (scans, limits)."""
        fp = node_fingerprint(node)
        if fp is None:
            return None
        operator = (stats or {}).get("operator") or type(node).__name__.lower()
        numeric_details = {
            k: v for k, v in (stats or {}).items()
            if k not in ("operator", "wall_s") and k not in _SUM_FIELDS
            and isinstance(v, (int, float)) and not isinstance(v, bool)}
        if stats and not wall_s:
            wall_s = float(stats.get("wall_s") or 0.0)
        return self.observe(operator, fp, rows_in=rows_in, rows_out=rows_out,
                            wall_s=wall_s, stats=stats, **numeric_details)

    # -- queries ---------------------------------------------------------
    def get(self, operator: str, fingerprint: str) -> ObservedStats | None:
        with self._lock:
            return self._stats.get((operator, fingerprint))

    def selectivity(self, operator: str, fingerprint: str) -> float | None:
        obs = self.get(operator, fingerprint)
        return obs.selectivity if obs is not None else None

    def selectivity_for_node(self, node) -> float | None:
        """Observed selectivity for a plan node, any operator — the lookup
        the adaptive optimizer will use."""
        obs = self.stats_for_node(node)
        return obs.selectivity if obs is not None else None

    def stats_for_node(self, node) -> ObservedStats | None:
        """Full observed entry for a plan node's fingerprint, any operator
        — selectivity plus the run weight the shrinkage blend needs."""
        fp = node_fingerprint(node)
        if fp is None:
            return None
        with self._lock:
            for (_, f), obs in self._stats.items():
                if f == fp and obs.runs > 0:
                    return obs
        return None

    def poison(self, fingerprint: str) -> int:
        """Drop every entry with this fingerprint (all operators).

        Called by the GuaranteeAuditor when a CI violation shows the
        predicate's history was earned under a drifted proxy/oracle — the
        adaptive executor and feedback costing must stop trusting its
        selectivities; fresh observations rebuild the entry from zero."""
        with self._lock:
            victims = [k for k in self._stats if k[1] == fingerprint]
            for k in victims:
                del self._stats[k]
            self.poisoned += len(victims)
        if victims:
            log.warning("stats-store poisoned %d entr%s for fingerprint %s",
                        len(victims), "y" if len(victims) == 1 else "ies",
                        fingerprint)
        return len(victims)

    def snapshot(self) -> list[dict]:
        with self._lock:
            entries = list(self._stats.values())
        return [e.as_dict() for e in sorted(
            entries, key=lambda e: (e.operator, e.fingerprint))]

    def __len__(self) -> int:
        with self._lock:
            return len(self._stats)

    # -- persistence -----------------------------------------------------
    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("StatsStore.save() needs a path")
        doc = {"version": 1, "entries": self.snapshot()}
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)
        return path

    def load(self, path: str, *, discount: float = 1.0,
             strict: bool = False) -> int:
        """Merge a saved store into this one.  ``discount`` scales every
        incoming accumulator (1.0 = the original additive merge): a
        down-weighted load makes cross-process history a shrinkage prior
        that fresh observations quickly outvote, instead of a month of
        stale sessions outvoting the last five minutes.

        A missing, truncated, or corrupt file (crashed writer, torn disk,
        wrong schema) is log-and-continue with whatever state already loaded
        — persisted stats are advisory history, and a bad file must never
        block gateway startup.  ``strict=True`` restores the raising
        behavior for callers that want the error."""
        if not 0.0 <= discount <= 1.0:
            raise ValueError(f"discount={discount} (expected 0 <= d <= 1)")
        try:
            with open(path) as f:
                doc = json.load(f)
            entries = doc.get("entries", ())
            if not isinstance(entries, (list, tuple)):
                raise ValueError(f"entries is {type(entries).__name__}, "
                                 "expected a list")
        except (OSError, json.JSONDecodeError, UnicodeDecodeError,
                ValueError, AttributeError) as exc:
            if strict:
                raise
            log.warning("stats store load failed (%s: %s) — continuing "
                        "with fresh state", path, exc)
            return 0
        scale = (lambda v: v) if discount == 1.0 else (lambda v: v * discount)
        n = skipped = 0
        for e in entries:
            try:
                key = (e["operator"], e["fingerprint"])
                counts = {f: float(e.get(f, 0) or 0) for f in _SUM_FIELDS
                          if f not in ("rows_in", "rows_out")}
                runs = float(e.get("runs", 0) or 0)
                rows_in = float(e.get("rows_in", 0) or 0)
                rows_out = float(e.get("rows_out", 0) or 0)
                wall_s = float(e.get("wall_s", 0.0) or 0.0)
                details = e.get("details") or {}
            except (TypeError, KeyError, ValueError, AttributeError):
                skipped += 1   # malformed entry: drop it, keep the rest
                continue
            with self._lock:
                obs = self._stats.get(key)
                if obs is None:
                    obs = self._stats[key] = ObservedStats(key[0], key[1])
                obs.runs += scale(runs)
                obs.rows_in += scale(rows_in)
                obs.rows_out += scale(rows_out)
                obs.wall_s += scale(wall_s)
                for f, v in counts.items():
                    setattr(obs, f, getattr(obs, f) + scale(v))
                for k, v in details.items():
                    if isinstance(v, (int, float)) and not isinstance(v, bool):
                        obs.details[k] = obs.details.get(k, 0) + scale(v)
            n += 1
        if skipped:
            log.warning("stats store load: skipped %d malformed entr%s in %s",
                        skipped, "y" if skipped == 1 else "ies", path)
        return n
