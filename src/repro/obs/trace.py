"""Span-based tracing for the semantic-operator stack.

One ``Tracer`` per traced run (or per gateway); spans nest through a
thread-local context so every layer — session, plan stage, operator,
partition fragment, dispatcher batch, kernel dispatch, index build, cache
lookup — attributes its work to the right parent without passing handles
through call signatures.  Tracing is off by default: the module-level
``span()`` returns a shared no-op context manager when no tracer is
installed on the calling thread, so the off path costs one thread-local
read per call site.

Cross-thread propagation mirrors ``core.accounting``: the coordinating
thread snapshots its context with ``capture()`` and fragment / worker /
dispatcher threads re-install it with ``activate_ctx()``, so spans opened
on other threads still parent into the owning session or operator span.

Exports: ``Tracer.export_jsonl()`` (one span per line) and
``Tracer.export_chrome()`` (Chrome ``trace_event`` JSON, loadable in
Perfetto / ``chrome://tracing``).
"""
from __future__ import annotations

import contextlib
import itertools
import json
import threading
import time

_ctx = threading.local()


def current_tracer() -> "Tracer | None":
    return getattr(_ctx, "tracer", None)


def current_span() -> "Span | None":
    return getattr(_ctx, "span", None)


class Span:
    """One timed unit of work.  ``attrs`` are typed-by-convention: counts
    are ints, seconds/thresholds are floats, identifiers are strings."""

    __slots__ = ("span_id", "parent_id", "name", "kind", "t0", "t1",
                 "attrs", "thread")

    def __init__(self, span_id: int, parent_id: int | None, name: str,
                 kind: str, attrs: dict):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.kind = kind
        self.t0 = time.monotonic()
        self.t1: float | None = None
        self.attrs = attrs
        self.thread = threading.get_ident()

    @property
    def dur_s(self) -> float:
        return ((self.t1 if self.t1 is not None else time.monotonic())
                - self.t0)

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def add(self, key: str, n: float = 1) -> None:
        self.attrs[key] = self.attrs.get(key, 0) + n

    def as_dict(self, origin: float = 0.0) -> dict:
        return {
            "span_id": self.span_id, "parent_id": self.parent_id,
            "name": self.name, "kind": self.kind,
            "ts_us": round((self.t0 - origin) * 1e6, 1),
            "dur_us": round(self.dur_s * 1e6, 1),
            "thread": self.thread, "attrs": _jsonable(self.attrs),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, kind={self.kind!r}, "
                f"dur={self.dur_s * 1e3:.2f}ms, attrs={self.attrs})")


class _NoopSpan:
    """Shared sink for all span mutation on the tracing-off path."""

    __slots__ = ()

    def set(self, **attrs) -> None:
        pass

    def add(self, key: str, n: float = 1) -> None:
        pass


class _NoopCM:
    __slots__ = ()

    def __enter__(self) -> _NoopSpan:
        return NOOP_SPAN

    def __exit__(self, *exc) -> bool:
        return False


NOOP_SPAN = _NoopSpan()
_NOOP_CM = _NoopCM()

# attribute keys whose values are summed when aggregating spans
_COUNTER_KEYS = ("oracle_calls", "proxy_calls", "embed_calls",
                 "compare_calls", "generate_calls", "cache_hits",
                 "scanned_bytes", "candidate_pairs",
                 "pairs_pruned_by_inference", "block_prompts",
                 "block_fallbacks")


class Tracer:
    """Collects finished spans; thread-safe; bounded (oldest runs should
    export and ``reset()`` — a serving gateway traces forever otherwise)."""

    def __init__(self, *, max_spans: int = 1_000_000):
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._ids = itertools.count(1)
        self._max_spans = max_spans
        self.dropped = 0
        self.origin = time.monotonic()

    # -- span lifecycle ---------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, kind: str = "span", **attrs):
        """Open a span parented to this thread's current span (if this
        tracer is the one installed here), install it as current, and
        record it on exit."""
        parent = current_span() if current_tracer() is self else None
        sp = Span(next(self._ids),
                  parent.span_id if parent is not None else None,
                  name, kind, attrs)
        prev = (current_tracer(), current_span())
        _ctx.tracer, _ctx.span = self, sp
        try:
            yield sp
        finally:
            sp.t1 = time.monotonic()
            _ctx.tracer, _ctx.span = prev
            with self._lock:
                if len(self._spans) < self._max_spans:
                    self._spans.append(sp)
                else:
                    self.dropped += 1

    # -- queries ----------------------------------------------------------
    def spans(self, kind: str | None = None) -> list[Span]:
        with self._lock:
            out = list(self._spans)
        if kind is not None:
            out = [s for s in out if s.kind == kind]
        out.sort(key=lambda s: s.t0)
        return out

    def roots(self) -> list[Span]:
        return [s for s in self.spans() if s.parent_id is None]

    def children_index(self) -> dict:
        """span_id -> list of child spans (each list sorted by start)."""
        idx: dict = {}
        for s in self.spans():
            if s.parent_id is not None:
                idx.setdefault(s.parent_id, []).append(s)
        return idx

    def subtree(self, root: Span) -> list[Span]:
        idx = self.children_index()
        out, todo = [], [root]
        while todo:
            s = todo.pop()
            out.append(s)
            todo.extend(idx.get(s.span_id, ()))
        return out

    def session_spans(self, sid: str | None = None) -> list[Span]:
        return [s for s in self.spans(kind="session")
                if sid is None or s.attrs.get("sid") == sid]

    def reset(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    # -- aggregation ------------------------------------------------------
    def stage_summary(self) -> dict:
        """Per-(kind, name) wall/count/call roll-up — the gateway snapshot's
        span-derived stage breakdown.  Wall is *inclusive* per span; only
        compare totals within one kind."""
        out: dict = {}
        for s in self.spans():
            row = out.setdefault(f"{s.kind}/{s.name}",
                                 {"count": 0, "wall_s": 0.0})
            row["count"] += 1
            row["wall_s"] = round(row["wall_s"] + s.dur_s, 6)
            for k in _COUNTER_KEYS:
                v = s.attrs.get(k)
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    row[k] = row.get(k, 0) + v
        return out

    # -- export -----------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.as_dict(self.origin)) + "\n")
        return len(spans)

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` document (complete 'X' events, µs)."""
        events = []
        for s in self.spans():
            events.append({
                "name": s.name, "cat": s.kind, "ph": "X",
                "ts": round((s.t0 - self.origin) * 1e6, 1),
                "dur": round(s.dur_s * 1e6, 1),
                "pid": 1, "tid": s.thread,
                "args": _jsonable({**s.attrs, "span_id": s.span_id,
                                   "parent_id": s.parent_id}),
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> int:
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])


# -- module-level context helpers ----------------------------------------

def span(name: str, kind: str = "span", **attrs):
    """Open a span on this thread's installed tracer; no-op (and no attrs
    evaluation cost beyond the call) when tracing is off."""
    t = current_tracer()
    if t is None:
        return _NOOP_CM
    return t.span(name, kind, **attrs)


def span_in(tracer: "Tracer | None", name: str, kind: str = "span", **attrs):
    """Open a span on an explicit tracer (dispatcher/subscription threads
    that hold a tracer handle rather than inheriting thread context)."""
    if tracer is None:
        return _NOOP_CM
    return tracer.span(name, kind, **attrs)


def capture() -> tuple:
    """Snapshot (tracer, span) for re-installation on another thread."""
    return (current_tracer(), current_span())


@contextlib.contextmanager
def activate_ctx(ctx: tuple):
    """Install a captured (tracer, span) pair on this thread; fragment
    workers use this so their spans parent into the coordinator's span."""
    prev = (current_tracer(), current_span())
    _ctx.tracer, _ctx.span = ctx
    try:
        yield
    finally:
        _ctx.tracer, _ctx.span = prev


@contextlib.contextmanager
def activate(tracer: "Tracer | None"):
    """Install a tracer (with no current span) on this thread — the entry
    point for a traced run on a worker thread."""
    prev = (current_tracer(), current_span())
    _ctx.tracer, _ctx.span = tracer, None
    try:
        yield tracer
    finally:
        _ctx.tracer, _ctx.span = prev


def _jsonable(d: dict) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x if isinstance(x, (str, int, float, bool)) else repr(x)
                      for x in v]
        else:
            out[k] = repr(v)
    return out
