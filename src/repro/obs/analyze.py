"""EXPLAIN ANALYZE for lazy semantic-operator plans.

``explain_analyze(frame)`` runs the plan under a tracer and renders the
optimized plan tree with the cost model's *predictions* next to what the
run actually *observed* — per-node cardinality, selectivity, oracle calls,
wall time, and scanned bytes — flagging nodes where the model drifted
beyond a tolerance.  Predictions come from
``core.plan.optimize.predicted_node_metrics`` (the same numbers
``explain_plan`` prints); observations come from the span tree
(``kind="plan_stage"`` spans keyed by plan-node identity) and land in the
given ``StatsStore`` so later sessions can price the same predicates from
observed reality.
"""
from __future__ import annotations

import dataclasses

from repro.core import accounting
from repro.core.plan import nodes as N
from repro.core.plan.execute import PlanExecutor
from repro.core.plan.optimize import predicted_node_metrics
from repro.obs import audit as _audit
from repro.obs import trace as _trace
from repro.obs.stats_store import StatsStore, node_fingerprint
from repro.obs.trace import Span, Tracer

_OBS_COUNTERS = ("oracle_calls", "proxy_calls", "embed_calls", "cache_hits",
                 "scanned_bytes", "candidate_pairs",
                 "pairs_pruned_by_inference", "block_prompts",
                 "block_fallbacks")


@dataclasses.dataclass
class NodeReport:
    node: N.LogicalNode
    depth: int
    predicted: dict
    observed: dict | None          # None when the node never ran directly
    drift: list[str] = dataclasses.field(default_factory=list)
    replanned: str | None = None   # adaptive executor's mid-query decision
    audit: dict | None = None      # GuaranteeAuditor CI estimate for this
                                   # node's predicate fingerprint

    def render(self) -> str:
        pad = "  " * self.depth
        pred = self.predicted
        line = f"{pad}{self.node.label()}"
        if self.observed is None:
            return (f"{line}  (pred rows~{pred['rows']:.0f}, "
                    f"oracle~{pred['oracle_calls']:.0f}; not executed "
                    f"directly)")
        obs = self.observed
        cols = [f"rows {pred['rows']:.0f}~/{obs['rows_out']} obs"]
        if pred["selectivity"] is not None and obs.get("selectivity") is not None:
            cols.append(f"sel {pred['selectivity']:.3f}~/"
                        f"{obs['selectivity']:.3f} obs")
        cols.append(f"oracle {pred['oracle_calls']:.0f}~/"
                    f"{obs['oracle_calls']} obs")
        cols.append(f"wall {obs['wall_s'] * 1e3:.1f}ms")
        if obs.get("scanned_bytes"):
            cols.append(f"bytes {obs['scanned_bytes']}")
        if obs.get("tau_plus") is not None:
            cols.append(f"tau {obs['tau_plus']:.2f}/{obs['tau_minus']:.2f}")
        if obs.get("candidate_pairs"):
            cols.append(f"cand {obs['candidate_pairs']}")
        if obs.get("block_prompts"):
            blk = f"blocks {obs['block_prompts']}"
            if obs.get("block_fallbacks"):
                blk += f"(-{obs['block_fallbacks']} fb)"
            cols.append(blk)
        if obs.get("pairs_pruned_by_inference"):
            cols.append(f"pruned {obs['pairs_pruned_by_inference']}")
        if self.audit is not None:
            # the audited guarantee next to the calibrated thresholds: CI
            # bounds on live precision/recall from gold re-judgments
            for kind, tag in (("precision", "P"), ("recall", "R")):
                ci = self.audit.get(kind)
                if ci is not None:
                    cols.append(f"audit {tag}~{ci['point']:.2f}"
                                f"[{ci['lo']:.2f},{ci['hi']:.2f}] "
                                f"n={ci['n']}")
            if self.audit.get("violations"):
                cols.append(f"violations={self.audit['violations']}")
        line += "  (" + ", ".join(cols) + ")"
        if self.drift:
            line += "  !! drift: " + ", ".join(self.drift)
        if self.replanned:
            line += f"  >> replanned: {self.replanned}"
        return line


@dataclasses.dataclass
class ExplainAnalyzeReport:
    records: list
    plan: N.LogicalNode
    nodes: list[NodeReport]
    tracer: Tracer
    stats_store: StatsStore
    tolerance: float

    @property
    def drifted(self) -> list[NodeReport]:
        return [r for r in self.nodes if r.drift]

    def render(self) -> str:
        head = (f"EXPLAIN ANALYZE  (predicted~/observed, "
                f"drift tolerance {self.tolerance:.0%})")
        return "\n".join([head] + [r.render() for r in self.nodes])

    def __str__(self) -> str:
        return self.render()


def _drift_ratio(pred: float, obs: float) -> float:
    lo, hi = sorted((max(pred, 0.0), float(obs)))
    return hi / max(lo, 1.0)


def _observed_for(sp: Span, children: dict) -> dict:
    """Exclusive observed metrics for one plan-stage span: call counters
    from the *top-level* operator/fragment spans directly below it (their
    attrs already include nested roll-ups via ``accounting.track``), wall
    minus the time spent in child plan stages."""
    agg = dict.fromkeys(_OBS_COUNTERS, 0)
    taus: dict = {}
    child_stage_wall = 0.0
    stack = list(children.get(sp.span_id, ()))
    while stack:
        c = stack.pop()
        if c.kind == "plan_stage":
            child_stage_wall += c.dur_s
            continue
        if c.kind in ("operator", "fragment"):
            for k in _OBS_COUNTERS:
                v = c.attrs.get(k, 0)
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    agg[k] += int(v)
            # calibrated cascade thresholds land on the operator span via
            # accounting.track's detail flattening
            for k in ("tau_plus", "tau_minus"):
                if k not in taus and isinstance(c.attrs.get(k), float):
                    taus[k] = c.attrs[k]
            continue  # roll-ups make descending double-count
        stack.extend(children.get(c.span_id, ()))
    rows_in = sp.attrs.get("rows_in")
    rows_out = sp.attrs.get("rows_out", 0)
    return {
        **agg,
        **taus,
        "rows_in": rows_in,
        "rows_out": rows_out,
        "selectivity": (rows_out / rows_in if rows_in else None),
        "wall_s": max(sp.dur_s - child_stage_wall, 0.0),
        "wall_total_s": sp.dur_s,
    }


def _walk(node: N.LogicalNode, depth: int, by_node: dict, children: dict,
          tolerance: float, out: list, auditor=None) -> None:
    pred = predicted_node_metrics(node)
    sp = by_node.get(id(node))
    observed = _observed_for(sp, children) if sp is not None else None
    drift = []
    if observed is not None:
        if _drift_ratio(pred["rows"], observed["rows_out"]) > 1 + tolerance:
            drift.append(
                f"rows {_drift_ratio(pred['rows'], observed['rows_out']):.1f}x")
        # oracle drift only matters where the model priced actual calls
        if pred["oracle_calls"] >= 1 or observed["oracle_calls"] >= 1:
            r = _drift_ratio(pred["oracle_calls"], observed["oracle_calls"])
            if r > 1 + tolerance:
                drift.append(f"oracle {r:.1f}x")
    replanned = sp.attrs.get("replanned") if sp is not None else None
    audit = auditor.report_for(node_fingerprint(node)) \
        if auditor is not None else None
    out.append(NodeReport(node, depth, pred, observed, drift, replanned,
                          audit))
    for c in node.children():
        _walk(c, depth + 1, by_node, children, tolerance, out, auditor)


def explain_analyze(frame, *, optimize: bool = True, tolerance: float = 0.5,
                    tracer: Tracer | None = None,
                    stats_store: StatsStore | None = None,
                    auditor=None,
                    **opt_kw) -> ExplainAnalyzeReport:
    """Run a ``LazySemFrame`` plan traced, and return a report comparing the
    cost model's per-node predictions with the observed execution.

    The frame's cached (optimizer, executor) pair is reused, so an
    ``explain()`` or earlier ``collect()`` shares probe labels and the
    batched cache with this run — same contract as ``collect``.

    With ``auditor=`` (a ``GuaranteeAuditor``) the run executes under that
    auditor's sampling hooks, the queue is drained before reporting, and
    each node shows the audited precision/recall CI for its predicate
    fingerprint next to the calibrated thresholds.
    """
    tracer = tracer if tracer is not None else Tracer()
    stats_store = stats_store if stats_store is not None else StatsStore()
    if optimize:
        optimizer, executor = frame._optimizer_and_executor(**opt_kw)
    else:
        optimizer = None
        executor = PlanExecutor(frame.session, stats_log=frame.stats_log)
    prev_store, executor.stats_store = executor.stats_store, stats_store
    try:
        with _trace.activate(tracer), _audit.activate_ctx(auditor):
            if optimizer is not None:
                with _trace.span("explain_analyze", kind="session"):
                    with accounting.track("plan_optimize") as st:
                        plan = optimizer.optimize(frame.plan)
                    st.details.update(
                        rewrites=[str(r) for r in optimizer.applied])
                    frame.stats_log.append(st.as_dict())
                    frame.last_rewrites = optimizer.applied
                    records = executor.run(plan)
            else:
                with _trace.span("explain_analyze", kind="session"):
                    plan = frame.plan
                    records = executor.run(plan)
    finally:
        executor.stats_store = prev_store
    if auditor is not None:
        auditor.drain()   # settle queued gold re-judgments before reporting
    by_node = {}
    for sp in tracer.spans(kind="plan_stage"):
        by_node.setdefault(sp.attrs.get("node_id"), sp)
    nodes: list[NodeReport] = []
    _walk(plan, 0, by_node, tracer.children_index(), tolerance, nodes,
          auditor)
    return ExplainAnalyzeReport(records=records, plan=plan, nodes=nodes,
                                tracer=tracer, stats_store=stats_store,
                                tolerance=tolerance)
