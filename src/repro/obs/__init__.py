"""Observability for the semantic-operator stack: span tracing, EXPLAIN
ANALYZE, and the cross-session observed-statistics store (ROADMAP open
item #1's substrate — the optimizer can't adapt to what it can't see)."""
from repro.obs.stats_store import (ObservedStats, StatsStore,  # noqa: F401
                                   node_fingerprint, predicate_fingerprint)
from repro.obs.trace import (NOOP_SPAN, Span, Tracer, activate,  # noqa: F401
                             activate_ctx, capture, current_span,
                             current_tracer, span, span_in)

__all__ = [
    "Tracer", "Span", "NOOP_SPAN", "span", "span_in", "activate",
    "activate_ctx", "capture", "current_span", "current_tracer",
    "StatsStore", "ObservedStats", "predicate_fingerprint",
    "node_fingerprint", "explain_analyze", "ExplainAnalyzeReport",
    "GuaranteeAuditor", "AuditPolicy", "AuditBudgeter", "ViolationEvent",
    "wilson_interval", "clopper_pearson", "binomial_interval",
    "MetricsRegistry", "parse_exposition",
]

_AUDIT_NAMES = frozenset({
    "GuaranteeAuditor", "AuditPolicy", "AuditBudgeter", "ViolationEvent",
    "wilson_interval", "clopper_pearson", "binomial_interval",
})
_METRICS_NAMES = frozenset({"MetricsRegistry", "parse_exposition"})


def __getattr__(name):
    # explain_analyze pulls in the plan executor; import lazily so
    # core modules can import repro.obs without a cycle
    if name in ("explain_analyze", "ExplainAnalyzeReport"):
        from repro.obs import analyze
        return getattr(analyze, name)
    # audit pulls in accounting/backends lazily, metrics is standalone;
    # both stay lazy here so `import repro.obs` keeps no heavy edges
    if name in _AUDIT_NAMES:
        from repro.obs import audit
        return getattr(audit, name)
    if name in _METRICS_NAMES:
        from repro.obs import metrics
        return getattr(metrics, name)
    raise AttributeError(name)
