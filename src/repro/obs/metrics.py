"""MetricsRegistry: the production metrics plane (Prometheus text format).

One registry unifies every serving-layer series — gateway throughput and
per-tenant SLOs, dispatcher fusion, semantic-cache sharing, index/matview
registries, and the guarantee auditor's precision/recall CIs and violation
counters — behind three primitive types:

  * :class:`Counter`   — monotonically increasing totals;
  * :class:`Gauge`     — point-in-time values;
  * :class:`Histogram` — fixed-bucket distributions with ``_sum``/``_count``.

All three carry label sets (``reg.counter("x", "help", ("tenant",))`` then
``c.inc(1, tenant="a")``) and serialize to the Prometheus text exposition
format via :meth:`MetricsRegistry.render`.  Producers are *collected on
demand*: the gateway's ``metrics_text()`` builds a registry and asks each
subsystem to ``collect(reg)`` from its own authoritative counters, so the
hot paths never pay a second bookkeeping write.

Thread-safe (one lock per registry, shared by its metrics);
:func:`parse_exposition` is the validating parser the tests and benchmarks
use to assert the output is well-formed exposition text.
"""
from __future__ import annotations

import math
import re
import threading

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default latency bucket bounds (seconds) for exported histograms
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0)


def _escape(value) -> str:
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(value: float) -> str:
    if value != value:                       # NaN
        return "NaN"
    if value in (math.inf, -math.inf):
        return "+Inf" if value > 0 else "-Inf"
    if float(value).is_integer() and abs(value) < 1e15:
        return str(int(value))
    return repr(float(value))


class _Metric:
    """Shared machinery: label validation + per-labelset child storage."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple,
                 lock: threading.Lock):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._children: dict[tuple, object] = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def _label_str(self, key: tuple) -> str:
        if not self.labelnames:
            return ""
        inner = ",".join(f'{ln}="{_escape(v)}"'
                         for ln, v in zip(self.labelnames, key))
        return "{" + inner + "}"

    def samples(self) -> list[tuple[str, str, float]]:
        """-> [(sample_name, label_str, value)] (lock held by caller)."""
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (inc {n})")
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0.0) + n

    def set_total(self, v: float, **labels) -> None:
        """Install an externally-accumulated monotone total (the collect-on-
        demand pattern: the source of truth lives in the producer)."""
        key = self._key(labels)
        with self._lock:
            self._children[key] = float(v)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._children.get(self._key(labels), 0.0))

    def samples(self):
        return [(self.name, self._label_str(k), v)
                for k, v in sorted(self._children.items())]


class Gauge(_Metric):
    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._children[key] = float(v)

    def inc(self, n: float = 1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0.0) + n

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._children.get(self._key(labels), 0.0))

    def samples(self):
        return [(self.name, self._label_str(k), v)
                for k, v in sorted(self._children.items())]


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError(f"{self.name}: needs at least one bucket bound")
        self.buckets = b

    def observe(self, x: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = {
                    "counts": [0] * len(self.buckets), "sum": 0.0, "n": 0}
            for i, bound in enumerate(self.buckets):
                if x <= bound:
                    child["counts"][i] += 1
                    break
            child["sum"] += float(x)
            child["n"] += 1

    def observe_buckets(self, cumulative: list[int], total: int,
                        sum_: float, **labels) -> None:
        """Install pre-aggregated cumulative bucket counts (exporting an
        existing histogram, e.g. the gateway's ``LatencyHistogram``)."""
        if len(cumulative) != len(self.buckets):
            raise ValueError(
                f"{self.name}: {len(cumulative)} cumulative counts for "
                f"{len(self.buckets)} buckets")
        key = self._key(labels)
        counts = [cumulative[0]] + [cumulative[i] - cumulative[i - 1]
                                    for i in range(1, len(cumulative))]
        with self._lock:
            self._children[key] = {"counts": counts, "sum": float(sum_),
                                   "n": int(total)}

    def samples(self):
        out = []
        for key, child in sorted(self._children.items()):
            acc = 0
            base = self._label_str(key)
            for bound, c in zip(self.buckets, child["counts"]):
                acc += c
                ls = self._bucket_label(key, _fmt(bound))
                out.append((f"{self.name}_bucket", ls, acc))
            out.append((f"{self.name}_bucket",
                        self._bucket_label(key, "+Inf"), child["n"]))
            out.append((f"{self.name}_sum", base, child["sum"]))
            out.append((f"{self.name}_count", base, child["n"]))
        return out

    def _bucket_label(self, key: tuple, le: str) -> str:
        pairs = [f'{ln}="{_escape(v)}"'
                 for ln, v in zip(self.labelnames, key)]
        pairs.append(f'le="{le}"')
        return "{" + ",".join(pairs) + "}"


class MetricsRegistry:
    """Holds the metric families and renders the exposition document."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _register(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if type(existing) is not cls \
                        or existing.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-registered with a different "
                        "type or label set")
                return existing
            m = cls(name, help, tuple(labelnames), self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames: tuple = (),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    def render(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
            lines: list[str] = []
            for m in metrics:
                if m.help:
                    lines.append(f"# HELP {m.name} {_escape(m.help)}")
                lines.append(f"# TYPE {m.name} {m.kind}")
                for sample_name, label_str, value in m.samples():
                    lines.append(f"{sample_name}{label_str} {_fmt(value)}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Validating parser (tests / benchmarks: "is this real exposition text?")
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^{}]*)\})?"
    r"\s+(?P<value>[^ \n]+)(?:\s+\d+)?$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_exposition(text: str) -> dict[str, float]:
    """Parse Prometheus text exposition; raises ``ValueError`` on any
    malformed line.  Returns ``{"name{labels}": value}`` plus a ``# TYPE``
    consistency check (every sample must belong to a declared family)."""
    samples: dict[str, float] = {}
    types: dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: bad TYPE line {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        name = m.group("name")
        labels = m.group("labels") or ""
        if labels:
            consumed = ",".join(f'{k}="{v}"' for k, v
                                in _LABEL_PAIR_RE.findall(labels))
            if consumed != labels.rstrip(","):
                raise ValueError(f"line {lineno}: malformed labels {labels!r}")
        raw = m.group("value")
        try:
            value = float(raw)
        except ValueError:
            if raw == "+Inf":
                value = math.inf
            elif raw == "-Inf":
                value = -math.inf
            elif raw == "NaN":
                value = math.nan
            else:
                raise ValueError(
                    f"line {lineno}: bad sample value {raw!r}") from None
        family = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in types:
                family = name[: -len(suffix)]
                break
        if family not in types:
            raise ValueError(f"line {lineno}: sample {name!r} has no TYPE")
        key = name + ("{" + labels + "}" if labels else "")
        samples[key] = value
    return samples
