"""GuaranteeAuditor: online quality observability for the guarantee machinery.

Cascade thresholds (``tau_plus``/``tau_minus``) are calibrated once, from an
importance sample at (gamma_R, gamma_P, delta) — but under streaming
appends, shared-cache reuse, adaptive replans, and proxy drift nothing
re-checks that the deployed decision rule still delivers the promised
precision/recall.  This module is that check:

  * every cascade operator (``sem_filter`` / cascade joins, including the
    partitioned variants) emits its *auto-decisions* — rows accepted or
    rejected by threshold alone, without an oracle label — through
    :func:`emit_cascade`;
  * the auditor samples a budgeted fraction of them
    (:class:`AuditBudgeter`: a hard per-window sample cap) and re-judges the
    sampled rows with the gold oracle **asynchronously**, on its own worker
    thread, through the micro-batch dispatcher's background-priority
    ``audit`` role — so audit traffic shares fused batches but never blocks
    a query, never warms a query-visible cache namespace, and bills to a
    dedicated ``audit`` accounting kind (query oracle bills stay
    bit-identical with auditing on or off);
  * per (operator, predicate-fingerprint) it accumulates Wilson /
    Clopper-Pearson confidence intervals on the observed precision and
    recall of the deployed rule, and — for ANN retrieval — sampled exact
    re-scans estimating live recall@k against each index's
    ``recall_target`` (:func:`emit_search`, fed by ``IVFIndex.search``
    including the delta-buffer and int8 paths);
  * when a CI lower bound crosses below the declared target it emits a
    structured :class:`ViolationEvent`: an alert counter is raised, the
    matching ``StatsStore`` fingerprint entry is poisoned (adaptive
    replanning and feedback costing stop trusting stale selectivities), and
    an ``on_violation`` callback lets the gateway purge the predicate's
    cached oracle/proxy answers so the next query recalibrates fresh.

Estimators (w.r.t. the *current* gold oracle):

  judged rows carry oracle labels, so errors only hide in auto-decisions.
  With J = judged-accepted, A = auto-accepted, R = auto-rejected population
  counts and audited gold-true rates p_acc (among sampled auto-accepts) and
  p_rej (among sampled auto-rejects):

      precision_lo = (J + A * lo(p_acc)) / (J + A)
      recall_lo    = (J + A * lo(p_acc))
                     / (J + A * lo(p_acc) + R * hi(p_rej))

  where lo/hi are the chosen binomial interval's bounds at 1 - delta.
  Both intervals are numpy/stdlib-only: Wilson uses the normal quantile
  from ``statistics.NormalDist``; Clopper-Pearson inverts the regularized
  incomplete beta (continued fraction + bisection).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import math
import os
import re
import statistics
import threading
import time
from collections import deque

import numpy as np

from repro.obs.stats_store import predicate_fingerprint

log = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# Binomial confidence intervals (numpy/stdlib only — no scipy)
# ---------------------------------------------------------------------------


def wilson_interval(successes: int, n: int, *,
                    delta: float = 0.05) -> tuple[float, float]:
    """Wilson score interval: P(p in [lo, hi]) >= 1 - delta (approx)."""
    if n <= 0:
        return 0.0, 1.0
    s = min(max(int(successes), 0), int(n))
    z = statistics.NormalDist().inv_cdf(1.0 - delta / 2.0)
    p = s / n
    z2 = z * z
    denom = 1.0 + z2 / n
    center = (p + z2 / (2.0 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1.0 - p) / n + z2 / (4.0 * n * n))
    # at the boundaries center-half is exactly 0 (resp. 1) in real
    # arithmetic; pin them so float error cannot leak past the edge
    lo = 0.0 if s == 0 else max(0.0, center - half)
    hi = 1.0 if s == n else min(1.0, center + half)
    return lo, hi


def _betacf(a: float, b: float, x: float) -> float:
    """Continued fraction for the incomplete beta (Numerical Recipes)."""
    MAXIT, EPS, FPMIN = 300, 3e-14, 1e-300
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < FPMIN:
        d = FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, MAXIT + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        delt = d * c
        h *= delt
        if abs(delt - 1.0) < EPS:
            break
    return h


def _betainc(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_bt = (math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
             + a * math.log(x) + b * math.log1p(-x))
    bt = math.exp(ln_bt)
    if x < (a + 1.0) / (a + b + 2.0):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1.0 - x) / b


def _beta_inv(p: float, a: float, b: float) -> float:
    """Inverse of I_x(a, b) by bisection (monotone in x; ~1e-12 accurate)."""
    lo, hi = 0.0, 1.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if _betainc(a, b, mid) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def clopper_pearson(successes: int, n: int, *,
                    delta: float = 0.05) -> tuple[float, float]:
    """Exact (conservative) binomial interval: P(p in [lo, hi]) >= 1-delta."""
    if n <= 0:
        return 0.0, 1.0
    s = min(max(int(successes), 0), int(n))
    lo = 0.0 if s == 0 else _beta_inv(delta / 2.0, s, n - s + 1)
    hi = 1.0 if s == n else _beta_inv(1.0 - delta / 2.0, s + 1, n - s)
    return lo, hi


def binomial_interval(successes: int, n: int, *, delta: float = 0.05,
                      method: str = "wilson") -> tuple[float, float]:
    if method in ("cp", "clopper-pearson", "clopper_pearson", "exact"):
        return clopper_pearson(successes, n, delta=delta)
    if method == "wilson":
        return wilson_interval(successes, n, delta=delta)
    raise ValueError(f"unknown interval method {method!r}")


def template_match_token(template) -> str:
    """Longest literal segment of a langex template — present verbatim in
    every rendered prompt, so it keys cache invalidation for the predicate."""
    segs = re.split(r"\{[^{}]*\}", str(template))
    return max(segs, key=len).strip() if segs else ""


# ---------------------------------------------------------------------------
# Budgeter
# ---------------------------------------------------------------------------


class AuditBudgeter:
    """Hard per-window sample cap: ``take(n)`` grants at most what is left
    of ``budget`` in the current ``window_s`` window (clock injectable for
    the property tests).  Thread-safe; never grants more than asked."""

    def __init__(self, budget: int, window_s: float, *,
                 now_fn=time.monotonic):
        if budget < 0:
            raise ValueError(f"budget={budget} (expected >= 0)")
        if window_s <= 0:
            raise ValueError(f"window_s={window_s} (expected > 0)")
        self.budget = int(budget)
        self.window_s = float(window_s)
        self._now = now_fn
        self._lock = threading.Lock()
        self._window_start: float | None = None
        self._spent_window = 0
        self.granted_total = 0
        self.denied_total = 0

    def _roll(self, now: float) -> None:
        if self._window_start is None or \
                now - self._window_start >= self.window_s:
            self._window_start = now
            self._spent_window = 0

    def take(self, n: int) -> int:
        """Grant ``min(n, remaining-in-window)`` samples; 0 when spent."""
        if n <= 0:
            return 0
        with self._lock:
            self._roll(self._now())
            granted = min(int(n), self.budget - self._spent_window)
            granted = max(granted, 0)
            self._spent_window += granted
            self.granted_total += granted
            self.denied_total += int(n) - granted
            return granted

    def remaining(self) -> int:
        with self._lock:
            self._roll(self._now())
            return self.budget - self._spent_window


# ---------------------------------------------------------------------------
# Policy / events
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AuditPolicy:
    sample_fraction: float = 0.5       # of auto-decisions per cascade
    budget_per_window: int = 512       # gold re-judgments per window
    window_s: float = 30.0
    min_samples: int = 16              # CI checks wait for this many audits
    delta: float = 0.1                 # CI coverage 1 - delta
    method: str = "wilson"             # or "clopper-pearson"
    recalibrate: bool = True           # violation => purge + poison
    search_sample_fraction: float = 0.25   # of queries per ANN search
    search_budget_per_window: int = 256    # exact re-scored queries / window
    min_search_samples: int = 32       # returned slots before recall CI check
    seed: int = 0

    def interval(self, successes: int, n: int) -> tuple[float, float]:
        return binomial_interval(successes, n, delta=self.delta,
                                 method=self.method)


@dataclasses.dataclass
class ViolationEvent:
    """A CI lower bound fell below its declared target."""

    kind: str                  # "precision" | "recall" | "recall_at_k"
                               # | "block_agreement"
    operator: str
    fingerprint: str
    template: str | None
    match_token: str | None
    observed: float            # point estimate
    lower: float               # CI lower bound that tripped
    target: float
    n: int                     # audited samples behind the bound
    details: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["observed"] = round(self.observed, 4)
        d["lower"] = round(self.lower, 4)
        return d


# ---------------------------------------------------------------------------
# Accumulators
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _CascadeAccount:
    operator: str
    fingerprint: str
    template: str
    match_token: str
    recall_target: float
    precision_target: float
    # audited samples (gold re-judgments of auto-decisions)
    acc_n: int = 0             # sampled auto-accepts
    acc_true: int = 0          # ... that the gold oracle confirms
    rej_n: int = 0             # sampled auto-rejects
    rej_true: int = 0          # ... that the gold oracle says were matches
    # population totals since the last reset
    judged_accepted: int = 0
    auto_accepted: int = 0
    auto_rejected: int = 0
    audited: int = 0
    violations: int = 0

    def reset_window(self) -> None:
        """Start a fresh estimation window (after a violation fires the old
        evidence describes the *pre-recalibration* rule)."""
        self.acc_n = self.acc_true = 0
        self.rej_n = self.rej_true = 0
        self.judged_accepted = self.auto_accepted = self.auto_rejected = 0

    def estimates(self, policy: AuditPolicy) -> dict:
        j, a, r = self.judged_accepted, self.auto_accepted, self.auto_rejected
        out: dict = {"operator": self.operator,
                     "fingerprint": self.fingerprint,
                     "template": self.template,
                     "audited_accepts": self.acc_n,
                     "audited_rejects": self.rej_n,
                     "audited": self.audited,
                     "violations": self.violations,
                     "precision_target": self.precision_target,
                     "recall_target": self.recall_target,
                     "precision": None, "recall": None}
        if self.acc_n > 0 and (j + a) > 0:
            p_hat = self.acc_true / self.acc_n
            p_lo, p_hi = policy.interval(self.acc_true, self.acc_n)
            out["precision"] = {
                "point": (j + a * p_hat) / (j + a),
                "lo": (j + a * p_lo) / (j + a),
                "hi": (j + a * p_hi) / (j + a),
                "n": self.acc_n}
            if self.rej_n > 0:
                m_hat = self.rej_true / self.rej_n
                m_lo, m_hi = policy.interval(self.rej_true, self.rej_n)
                tp = j + a * p_hat
                tp_lo = j + a * p_lo
                denom = tp + r * m_hat
                out["recall"] = {
                    "point": tp / denom if denom > 0 else 1.0,
                    "lo": tp_lo / (tp_lo + r * m_hi)
                    if (tp_lo + r * m_hi) > 0 else 1.0,
                    "hi": min((j + a * p_hi)
                              / max(j + a * p_hi + r * m_lo, 1e-12), 1.0),
                    "n": self.rej_n}
        return out


@dataclasses.dataclass
class _BlockAccount:
    """Agreement of block-prompt verdicts with the pairwise gold oracle.

    The block-join path decides most pairs through multi-pair structured
    prompts; its guarantee rests on block verdicts tracking what the same
    oracle would answer pairwise.  Sampled block verdicts are re-judged
    pairwise and the agreement rate gets a CI against the operator's
    declared agreement target."""

    operator: str
    fingerprint: str
    template: str
    match_token: str
    agreement_target: float
    n: int = 0                 # block verdicts re-judged pairwise
    agree: int = 0             # ... matching the pairwise gold verdict
    pairs_seen: int = 0        # block-judged pairs observed (population)
    audited: int = 0
    violations: int = 0

    def reset_window(self) -> None:
        self.n = self.agree = 0

    def estimates(self, policy: AuditPolicy) -> dict:
        out: dict = {"operator": self.operator,
                     "fingerprint": self.fingerprint,
                     "template": self.template,
                     "agreement_target": self.agreement_target,
                     "pairs_seen": self.pairs_seen, "audited": self.audited,
                     "violations": self.violations, "agreement": None}
        if self.n > 0:
            lo, hi = policy.interval(self.agree, self.n)
            out["agreement"] = {"point": self.agree / self.n,
                                "lo": lo, "hi": hi, "n": self.n}
        return out


@dataclasses.dataclass
class _SearchAccount:
    key: str                   # index kind (+ quantize) label
    recall_target: float
    n: int = 0                 # audited result slots (k per audited query)
    hits: int = 0              # slots whose exact score clears the exact kth
    queries_audited: int = 0
    violations: int = 0

    def estimates(self, policy: AuditPolicy) -> dict:
        out = {"key": self.key, "recall_target": self.recall_target,
               "queries_audited": self.queries_audited, "n": self.n,
               "violations": self.violations, "recall_at_k": None}
        if self.n > 0:
            lo, hi = policy.interval(self.hits, self.n)
            out["recall_at_k"] = {"point": self.hits / self.n,
                                  "lo": lo, "hi": hi, "n": self.n}
        return out


# ---------------------------------------------------------------------------
# Thread-local auditor context (mirrors accounting/trace propagation)
# ---------------------------------------------------------------------------

_tls = threading.local()


def current_auditor() -> "GuaranteeAuditor | None":
    return getattr(_tls, "auditor", None)


def capture() -> "GuaranteeAuditor | None":
    """Snapshot for re-installation on fragment/worker threads (rides in
    ``accounting.capture()``'s context tuple)."""
    return current_auditor()


@contextlib.contextmanager
def activate_ctx(auditor: "GuaranteeAuditor | None"):
    prev = current_auditor()
    _tls.auditor = auditor
    try:
        yield
    finally:
        _tls.auditor = prev


# -- operator-side emission hooks (cheap no-ops without an active auditor) --


def emit_cascade(operator: str, template, res, prompt_fn, *,
                 recall_target: float, precision_target: float) -> int:
    """Called by cascade operators right after the decision rule ran.
    ``res`` is a ``CascadeResult`` (its ``judged`` mask marks oracle-labeled
    rows); ``prompt_fn(indices) -> prompts`` materializes prompts for the
    sampled rows only.  Returns the number of decisions enqueued for audit."""
    aud = current_auditor()
    if aud is None or getattr(res, "judged", None) is None:
        return 0
    try:
        return aud.observe_cascade(operator, template, res, prompt_fn,
                                   recall_target=recall_target,
                                   precision_target=precision_target)
    except Exception:  # auditing is observability: never break the query
        log.warning("audit emit_cascade failed", exc_info=True)
        return 0


def emit_search(index, queries, scores, ids, k, *, vectors, n_cut,
                recall_target: float) -> int:
    """Called by ANN indexes at the end of ``search()``; the auditor
    exact-rescans a sampled subset of the query rows asynchronously."""
    aud = current_auditor()
    if aud is None:
        return 0
    try:
        return aud.observe_search(index, queries, scores, ids, k,
                                  vectors=vectors, n_cut=n_cut,
                                  recall_target=recall_target)
    except Exception:
        log.warning("audit emit_search failed", exc_info=True)
        return 0


def emit_block_join(operator: str, template, pairs, verdicts, prompt_fn, *,
                    agreement_target: float) -> int:
    """Called by the block-join path with the pairs it decided through block
    prompts (``pairs``/``verdicts`` aligned); the auditor re-judges a
    budgeted sample of them *pairwise* asynchronously and tracks the
    block-vs-pairwise agreement CI against ``agreement_target``.
    ``prompt_fn(indices) -> prompts`` renders the pairwise prompts for the
    sampled positions only."""
    aud = current_auditor()
    if aud is None or not len(pairs):
        return 0
    try:
        return aud.observe_block_join(operator, template, pairs, verdicts,
                                      prompt_fn,
                                      agreement_target=agreement_target)
    except Exception:
        log.warning("audit emit_block_join failed", exc_info=True)
        return 0


# ---------------------------------------------------------------------------
# The auditor
# ---------------------------------------------------------------------------


class GuaranteeAuditor:
    """Budgeted asynchronous gold audits of live cascade/ANN decisions.

    ``oracle`` is any predicate-capable model; a raw backend is wrapped in
    a ``CountedModel(..., "audit")`` so its calls land on the dedicated
    ``audit`` accounting kind (dispatcher handles already carry a role).
    The worker thread runs under the auditor's own ``OpStats`` — audit
    traffic never leaks into any session's bill.
    """

    def __init__(self, oracle, *, policy: AuditPolicy | None = None,
                 stats_store=None, on_violation=None, path: str | None = None,
                 now_fn=time.monotonic):
        from repro.core.accounting import OpStats  # lazy: avoids a cycle
        if getattr(oracle, "role", None) != "audit":
            from repro.core.backends.base import CountedModel
            oracle = CountedModel(oracle, "audit")
        self._oracle = oracle
        self.policy = policy or AuditPolicy()
        self.stats_store = stats_store
        self.on_violation = on_violation
        self.path = path
        self.stats = OpStats(operator="audit")
        self.budgeter = AuditBudgeter(self.policy.budget_per_window,
                                      self.policy.window_s, now_fn=now_fn)
        self.search_budgeter = AuditBudgeter(
            self.policy.search_budget_per_window, self.policy.window_s,
            now_fn=now_fn)
        self._lock = threading.Lock()
        self._rng = np.random.default_rng(self.policy.seed)
        self._cascades: dict[str, _CascadeAccount] = {}
        self._searches: dict[str, _SearchAccount] = {}
        self._blocks: dict[str, _BlockAccount] = {}
        self._emissions: dict[str, dict] = {}   # per-tenant continuous-query
        self.violations: deque[ViolationEvent] = deque(maxlen=256)
        self.violation_counts: dict[str, int] = {}
        self.errors = 0
        self.last_error: str | None = None
        self._pending = 0
        self._done_cv = threading.Condition(self._lock)
        self._queue: deque = deque()
        self._queue_cv = threading.Condition()
        self._closed = False
        if path:
            self.load(path)
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="guarantee-auditor")
        self._thread.start()

    # -- caller side (query threads; cheap) --------------------------------
    def observe_cascade(self, operator: str, template, res, prompt_fn, *,
                        recall_target: float, precision_target: float) -> int:
        template = str(getattr(template, "template", template))
        passed = np.asarray(res.passed, bool).ravel()
        judged = np.asarray(res.judged, bool).ravel()
        auto_acc = np.flatnonzero(passed & ~judged)
        auto_rej = np.flatnonzero(~passed & ~judged)
        fp = predicate_fingerprint(operator, template)
        frac = self.policy.sample_fraction
        want_acc = math.ceil(frac * len(auto_acc)) if len(auto_acc) else 0
        want_rej = math.ceil(frac * len(auto_rej)) if len(auto_rej) else 0
        with self._lock:
            acct = self._cascades.get(fp)
            if acct is None:
                acct = self._cascades[fp] = _CascadeAccount(
                    operator=operator, fingerprint=fp, template=template,
                    match_token=template_match_token(template),
                    recall_target=recall_target,
                    precision_target=precision_target)
            acct.recall_target = recall_target
            acct.precision_target = precision_target
            acct.judged_accepted += int((passed & judged).sum())
            acct.auto_accepted += len(auto_acc)
            acct.auto_rejected += len(auto_rej)
            granted = self.budgeter.take(want_acc + want_rej)
            if granted <= 0:
                return 0
            g_acc = min(want_acc, granted)
            g_rej = min(want_rej, granted - g_acc)
            sel_acc = self._rng.choice(auto_acc, size=g_acc, replace=False) \
                if g_acc else np.zeros(0, int)
            sel_rej = self._rng.choice(auto_rej, size=g_rej, replace=False) \
                if g_rej else np.zeros(0, int)
        prompts_acc = list(prompt_fn(sel_acc)) if len(sel_acc) else []
        prompts_rej = list(prompt_fn(sel_rej)) if len(sel_rej) else []
        if not prompts_acc and not prompts_rej:
            return 0
        self._enqueue(("cascade", fp, prompts_acc, prompts_rej))
        return len(prompts_acc) + len(prompts_rej)

    def observe_search(self, index, queries, scores, ids, k, *, vectors,
                       n_cut: int, recall_target: float) -> int:
        q = np.atleast_2d(np.asarray(queries, np.float32))
        nq = len(q)
        if nq == 0 or n_cut <= 0 or k <= 0:
            return 0
        want = math.ceil(self.policy.search_sample_fraction * nq)
        granted = self.search_budgeter.take(want)
        if granted <= 0:
            return 0
        with self._lock:
            rows = self._rng.choice(nq, size=min(granted, nq), replace=False)
        key = getattr(index, "kind", str(index))
        quant = getattr(index, "quantize", None)
        if quant and quant != "none":
            key = f"{key}/{quant}"
        # copies decouple the job from the caller's buffers; `vectors` is
        # the search-time snapshot (replaced, never resized, on mutation)
        job = ("search", key, float(recall_target), vectors,
               q[rows].copy(), np.asarray(scores)[rows].copy(),
               np.asarray(ids)[rows].copy(), int(k), int(n_cut))
        self._enqueue(job)
        return len(rows)

    def observe_block_join(self, operator: str, template, pairs, verdicts,
                           prompt_fn, *, agreement_target: float) -> int:
        template = str(getattr(template, "template", template))
        verdicts = np.asarray(verdicts, bool).ravel()
        n_pairs = len(verdicts)
        if n_pairs == 0:
            return 0
        fp = predicate_fingerprint(operator, template)
        want = math.ceil(self.policy.sample_fraction * n_pairs)
        with self._lock:
            acct = self._blocks.get(fp)
            if acct is None:
                acct = self._blocks[fp] = _BlockAccount(
                    operator=operator, fingerprint=fp, template=template,
                    match_token=template_match_token(template),
                    agreement_target=agreement_target)
            acct.agreement_target = agreement_target
            acct.pairs_seen += n_pairs
            granted = self.budgeter.take(want)
            if granted <= 0:
                return 0
            sel = self._rng.choice(n_pairs, size=min(granted, n_pairs),
                                   replace=False)
        prompts = list(prompt_fn(sel))
        if not prompts:
            return 0
        self._enqueue(("block_join", fp, prompts, verdicts[sel].tolist()))
        return len(prompts)

    def observe_emission(self, *, tenant: str, rows: int, added: int,
                         error: bool = False) -> None:
        """Continuous-query emission accounting (per-tenant audit series);
        the emission's cascade decisions are sampled by the normal
        ``emit_cascade`` path since subscriptions execute through the
        gateway workers."""
        with self._lock:
            e = self._emissions.setdefault(
                tenant, {"emissions": 0, "rows": 0, "added": 0, "errors": 0})
            e["emissions"] += 1
            e["rows"] += max(int(rows), 0)
            e["added"] += max(int(added), 0)
            if error:
                e["errors"] += 1

    # -- worker side -------------------------------------------------------
    def _enqueue(self, job: tuple) -> None:
        with self._queue_cv:
            if self._closed:
                return
            self._queue.append(job)
            self._queue_cv.notify()
        with self._lock:
            self._pending += 1

    def _loop(self) -> None:
        from repro.core import accounting
        # the worker owns its accounting context: audit model calls land on
        # self.stats (kind "audit"), never on a session
        with accounting.activate((self.stats, None, (None, None), None)):
            while True:
                with self._queue_cv:
                    while not self._queue and not self._closed:
                        self._queue_cv.wait()
                    if not self._queue:
                        return           # closed and drained
                    job = self._queue.popleft()
                try:
                    self._run_job(job)
                except Exception as exc:
                    with self._lock:
                        self.errors += 1
                        self.last_error = repr(exc)
                finally:
                    with self._done_cv:
                        self._pending -= 1
                        self._done_cv.notify_all()

    def _run_job(self, job: tuple) -> None:
        if job[0] == "cascade":
            _, fp, prompts_acc, prompts_rej = job
            labels, _ = self._oracle.predicate(prompts_acc + prompts_rej)
            labels = np.asarray(labels, bool)
            acc_true = int(labels[: len(prompts_acc)].sum())
            rej_true = int(labels[len(prompts_acc):].sum())
            events = []
            with self._lock:
                acct = self._cascades.get(fp)
                if acct is None:
                    return
                acct.acc_n += len(prompts_acc)
                acct.acc_true += acc_true
                acct.rej_n += len(prompts_rej)
                acct.rej_true += rej_true
                acct.audited += len(prompts_acc) + len(prompts_rej)
                events = self._check_cascade(acct)
            for ev in events:
                self._fire(ev)
        elif job[0] == "block_join":
            _, fp, prompts, block_v = job
            labels, _ = self._oracle.predicate(prompts)
            labels = np.asarray(labels, bool)
            agree = int((labels == np.asarray(block_v, bool)).sum())
            event = None
            with self._lock:
                acct = self._blocks.get(fp)
                if acct is None:
                    return
                acct.n += len(prompts)
                acct.agree += agree
                acct.audited += len(prompts)
                event = self._check_block(acct)
            if event is not None:
                self._fire(event)
        elif job[0] == "search":
            (_, key, recall_target, vectors, q, scores, ids, k, n_cut) = job
            n, hits = self._exact_rescan(vectors, q, scores, ids, k, n_cut)
            event = None
            with self._lock:
                acct = self._searches.get(key)
                if acct is None:
                    acct = self._searches[key] = _SearchAccount(
                        key=key, recall_target=recall_target)
                acct.recall_target = recall_target
                acct.n += n
                acct.hits += hits
                acct.queries_audited += len(q)
                event = self._check_search(acct)
            if event is not None:
                self._fire(event)

    def _exact_rescan(self, vectors, q, scores, ids, k: int,
                      n_cut: int) -> tuple[int, int]:
        """Exact recall@k of the returned ids vs a brute-force re-scan of
        the snapshot corpus.  A returned id counts as a hit when its exact
        score clears the exact kth-best score (score-threshold overlap:
        robust to ties); unfilled/invalid slots count as misses."""
        from repro.index.backend import MASKED_SCORE, exact_topk
        k_eff = min(int(k), int(n_cut))
        if k_eff <= 0:
            return 0, 0
        exact_s, _ = exact_topk(vectors[:n_cut], q, k_eff)
        kth = exact_s[:, k_eff - 1]
        corpus = np.asarray(vectors[:n_cut], np.float32)
        unit = corpus / np.maximum(
            np.linalg.norm(corpus, axis=1, keepdims=True), 1e-9)
        qn = np.asarray(q, np.float32)
        qn = qn / np.maximum(np.linalg.norm(qn, axis=1, keepdims=True), 1e-9)
        n = hits = 0
        for r in range(len(q)):
            valid = (np.asarray(scores[r]) > MASKED_SCORE / 2)
            row_ids = np.asarray(ids[r])[valid].astype(np.int64)
            row_ids = row_ids[(row_ids >= 0) & (row_ids < n_cut)][:k_eff]
            got = unit[row_ids] @ qn[r] if len(row_ids) else np.zeros(0)
            hits += int((got >= kth[r] - 1e-6).sum())
            n += k_eff
        return n, hits

    # -- violation machinery ----------------------------------------------
    def _check_cascade(self, acct: _CascadeAccount) -> list[ViolationEvent]:
        """Lock held.  Returns the violations to fire (accumulators reset)."""
        if acct.acc_n < self.policy.min_samples:
            return []
        est = acct.estimates(self.policy)
        events = []
        prec = est["precision"]
        if prec is not None and prec["lo"] < acct.precision_target:
            events.append(ViolationEvent(
                kind="precision", operator=acct.operator,
                fingerprint=acct.fingerprint, template=acct.template,
                match_token=acct.match_token, observed=prec["point"],
                lower=prec["lo"], target=acct.precision_target, n=prec["n"],
                details={"audited_accepts": acct.acc_n,
                         "gold_true": acct.acc_true,
                         "auto_accepted": acct.auto_accepted,
                         "judged_accepted": acct.judged_accepted}))
        rec = est["recall"]
        if rec is not None and acct.rej_n >= self.policy.min_samples \
                and rec["lo"] < acct.recall_target:
            events.append(ViolationEvent(
                kind="recall", operator=acct.operator,
                fingerprint=acct.fingerprint, template=acct.template,
                match_token=acct.match_token, observed=rec["point"],
                lower=rec["lo"], target=acct.recall_target, n=rec["n"],
                details={"audited_rejects": acct.rej_n,
                         "gold_true_rejects": acct.rej_true,
                         "auto_rejected": acct.auto_rejected}))
        if events:
            acct.violations += len(events)
            # fresh estimation window: post-recalibration evidence must not
            # be averaged with the drifted rule's (and the reset debounces —
            # the next check waits for min_samples new audits)
            acct.reset_window()
        return events

    def _check_block(self, acct: _BlockAccount) -> ViolationEvent | None:
        """Lock held.  Fires when the CI lower bound of block-vs-pairwise
        agreement drops below the operator's agreement target."""
        if acct.n < self.policy.min_samples:
            return None
        lo, _ = self.policy.interval(acct.agree, acct.n)
        if lo >= acct.agreement_target:
            return None
        ev = ViolationEvent(
            kind="block_agreement", operator=acct.operator,
            fingerprint=acct.fingerprint, template=acct.template,
            match_token=acct.match_token, observed=acct.agree / acct.n,
            lower=lo, target=acct.agreement_target, n=acct.n,
            details={"pairs_seen": acct.pairs_seen, "audited": acct.audited})
        acct.violations += 1
        acct.reset_window()
        return ev

    def _check_search(self, acct: _SearchAccount) -> ViolationEvent | None:
        if acct.n < self.policy.min_search_samples:
            return None
        lo, _ = self.policy.interval(acct.hits, acct.n)
        if lo >= acct.recall_target:
            return None
        ev = ViolationEvent(
            kind="recall_at_k", operator="Search", fingerprint=acct.key,
            template=None, match_token=None, observed=acct.hits / acct.n,
            lower=lo, target=acct.recall_target, n=acct.n,
            details={"queries_audited": acct.queries_audited})
        acct.violations += 1
        acct.n = acct.hits = 0
        return ev

    def _fire(self, event: ViolationEvent) -> None:
        with self._lock:
            self.violations.append(event)
            self.violation_counts[event.kind] = \
                self.violation_counts.get(event.kind, 0) + 1
        log.warning("guarantee violation: %s %s lower=%.3f target=%.3f "
                    "(n=%d, %s)", event.kind, event.operator, event.lower,
                    event.target, event.n, event.fingerprint)
        if self.stats_store is not None and event.template is not None:
            # stale selectivities must stop feeding adaptive replans and
            # feedback costing for this predicate
            try:
                self.stats_store.poison(event.fingerprint)
            except Exception:
                log.warning("stats-store poison failed", exc_info=True)
        if self.on_violation is not None:
            try:
                self.on_violation(event)
            except Exception:
                log.warning("on_violation callback failed", exc_info=True)

    # -- reports / metrics -------------------------------------------------
    def report(self, fingerprint: str | None = None) -> dict:
        with self._lock:
            cascades = [a.estimates(self.policy)
                        for a in self._cascades.values()
                        if fingerprint is None or a.fingerprint == fingerprint]
            searches = [a.estimates(self.policy)
                        for a in self._searches.values()]
            block_joins = [a.estimates(self.policy)
                           for a in self._blocks.values()
                           if fingerprint is None
                           or a.fingerprint == fingerprint]
            return {
                "cascades": cascades, "searches": searches,
                "block_joins": block_joins,
                "emissions": {t: dict(e) for t, e in self._emissions.items()},
                "violations": dict(self.violation_counts),
                "audit_calls": self.stats.audit_calls,
                "budget": {"granted": self.budgeter.granted_total,
                           "denied": self.budgeter.denied_total},
                "errors": self.errors, "pending": self._pending,
            }

    def report_for(self, fingerprint: str | None) -> dict | None:
        """The single cascade estimate for one predicate fingerprint (the
        ``explain_analyze`` lookup); None when never audited."""
        if fingerprint is None:
            return None
        with self._lock:
            acct = self._cascades.get(fingerprint)
            return acct.estimates(self.policy) if acct is not None else None

    def collect(self, registry) -> None:
        """Write the audit series into a ``MetricsRegistry``."""
        rep = self.report()
        calls = registry.counter("repro_audit_oracle_calls_total",
                                 "gold oracle calls made by the auditor")
        calls.set_total(rep["audit_calls"])
        granted = registry.counter("repro_audit_samples_total",
                                   "audit samples granted by the budgeter",
                                   ("outcome",))
        granted.set_total(rep["budget"]["granted"], outcome="granted")
        granted.set_total(rep["budget"]["denied"], outcome="denied")
        viol = registry.counter("repro_guarantee_violations_total",
                                "guarantee CI violations", ("kind",))
        for kind in ("precision", "recall", "recall_at_k", "block_agreement"):
            viol.set_total(rep["violations"].get(kind, 0), kind=kind)
        bound = registry.gauge("repro_audit_ci_lower_bound",
                               "CI lower bound of the audited guarantee",
                               ("kind", "operator", "fingerprint"))
        point = registry.gauge("repro_audit_observed",
                               "point estimate of the audited guarantee",
                               ("kind", "operator", "fingerprint"))
        nsamp = registry.gauge("repro_audit_samples",
                               "audited samples behind the current CI",
                               ("kind", "operator", "fingerprint"))
        for est in rep["cascades"]:
            for kind in ("precision", "recall"):
                ci = est[kind]
                if ci is None:
                    continue
                labels = {"kind": kind, "operator": est["operator"],
                          "fingerprint": est["fingerprint"]}
                bound.set(ci["lo"], **labels)
                point.set(ci["point"], **labels)
                nsamp.set(ci["n"], **labels)
        for est in rep["searches"]:
            ci = est["recall_at_k"]
            if ci is None:
                continue
            labels = {"kind": "recall_at_k", "operator": "Search",
                      "fingerprint": est["key"]}
            bound.set(ci["lo"], **labels)
            point.set(ci["point"], **labels)
            nsamp.set(ci["n"], **labels)
        for est in rep["block_joins"]:
            ci = est["agreement"]
            if ci is None:
                continue
            labels = {"kind": "block_agreement", "operator": est["operator"],
                      "fingerprint": est["fingerprint"]}
            bound.set(ci["lo"], **labels)
            point.set(ci["point"], **labels)
            nsamp.set(ci["n"], **labels)
        if rep["emissions"]:
            em = registry.counter("repro_audit_emissions_total",
                                  "continuous-query emissions observed",
                                  ("tenant",))
            for tenant, e in rep["emissions"].items():
                em.set_total(e["emissions"], tenant=tenant)

    # -- persistence -------------------------------------------------------
    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("GuaranteeAuditor.save() needs a path")
        with self._lock:
            doc = {"version": 1,
                   "cascades": [dataclasses.asdict(a)
                                for a in self._cascades.values()],
                   "searches": [dataclasses.asdict(a)
                                for a in self._searches.values()],
                   "block_joins": [dataclasses.asdict(a)
                                   for a in self._blocks.values()],
                   "violation_counts": dict(self.violation_counts)}
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)
        return path

    def load(self, path: str, *, strict: bool = False) -> int:
        """Merge persisted audit state; a missing/truncated/corrupt file is
        log-and-continue (fresh state) unless ``strict=True`` — auditing
        must never block gateway startup."""
        if not os.path.exists(path):
            return 0
        try:
            with open(path) as f:
                doc = json.load(f)
            n = 0
            with self._lock:
                for e in doc.get("cascades", ()):
                    acct = _CascadeAccount(**{
                        k: e[k] for k in (
                            "operator", "fingerprint", "template",
                            "match_token", "recall_target",
                            "precision_target", "acc_n", "acc_true", "rej_n",
                            "rej_true", "judged_accepted", "auto_accepted",
                            "auto_rejected", "audited", "violations")})
                    self._cascades[acct.fingerprint] = acct
                    n += 1
                for e in doc.get("block_joins", ()):
                    acct = _BlockAccount(**{
                        k: e[k] for k in (
                            "operator", "fingerprint", "template",
                            "match_token", "agreement_target", "n", "agree",
                            "pairs_seen", "audited", "violations")})
                    self._blocks[acct.fingerprint] = acct
                    n += 1
                for e in doc.get("searches", ()):
                    acct = _SearchAccount(**{
                        k: e[k] for k in ("key", "recall_target", "n", "hits",
                                          "queries_audited", "violations")})
                    self._searches[acct.key] = acct
                    n += 1
                for k, v in (doc.get("violation_counts") or {}).items():
                    self.violation_counts[k] = \
                        self.violation_counts.get(k, 0) + int(v)
            return n
        except (OSError, json.JSONDecodeError, UnicodeDecodeError,
                ValueError, KeyError, TypeError, AttributeError) as exc:
            if strict:
                raise
            log.warning("audit state load failed (%s: %s) — starting fresh",
                        path, exc)
            return 0

    # -- lifecycle ---------------------------------------------------------
    def drain(self, timeout: float | None = 30.0) -> bool:
        """Block until every enqueued audit job has been judged."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._done_cv:
            while self._pending > 0:
                left = None if deadline is None else \
                    max(deadline - time.monotonic(), 0.0)
                if left == 0.0:
                    return False
                self._done_cv.wait(timeout=left)
        return True

    def close(self, *, drain: bool = True,
              timeout: float | None = 30.0) -> None:
        if drain:
            self.drain(timeout)
        with self._queue_cv:
            self._closed = True
            self._queue_cv.notify_all()
        self._thread.join(timeout=10.0)
        if self.path:
            try:
                self.save(self.path)
            except OSError:
                log.warning("audit state save failed", exc_info=True)

    def __enter__(self) -> "GuaranteeAuditor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
