"""E5-style text embedding encoder: bidirectional transformer + mean pooling
+ L2 normalization.  Used by the semantic-operator layer as the embedding
proxy (sem_join sim-filter, sem_group_by, sem_search, sem_sim_join).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import SpecTree, init_params
from repro.configs.base import ModelConfig
from repro.data.tokenizer import TOKENIZER
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.transformer import _stack

E5_SMALL = ModelConfig(
    name="e5-small-sim", family="dense",
    num_layers=12, d_model=384, num_heads=12, num_kv_heads=12,
    d_ff=1536, vocab_size=TOKENIZER.vocab_size, rope_theta=10_000.0,
    dtype="float32",
)


def _enc_layer_specs(cfg: ModelConfig) -> dict:
    specs = {("attn",) + p: s for p, s in attn.attention_spec(cfg).items()}
    specs.update({("attn_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    specs.update({("ffn_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    specs.update({("ffn",) + p: s for p, s in L.swiglu_spec(cfg.d_model, cfg.d_ff).items()})
    return specs


def param_specs(cfg: ModelConfig = E5_SMALL) -> SpecTree:
    specs: SpecTree = {}
    specs.update({("embed",) + p: s for p, s in L.embed_spec(cfg.vocab_size, cfg.d_model).items()})
    specs.update(_stack(_enc_layer_specs(cfg), cfg.num_layers, "layers"))
    specs.update({("final_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    return specs


def encode_tokens(params, tokens, valid_mask, *, cfg: ModelConfig):
    """tokens [B,T], valid_mask [B,T] -> unit vectors [B, d]."""
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)

    def layer(x, lp):
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        a, _ = attn.self_attention(lp["attn"], h, cfg=cfg, causal=False)
        x = x + a
        h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        return x + L.swiglu(lp["ffn"], h), None

    x, _ = jax.lax.scan(layer, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    m = valid_mask[..., None].astype(jnp.float32)
    pooled = jnp.sum(x.astype(jnp.float32) * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-6)


class Embedder:
    """Batched text -> unit-vector embeddings via the JAX encoder."""

    def __init__(self, cfg: ModelConfig = E5_SMALL, params=None, *, seed: int = 0,
                 max_len: int = 256):
        self.cfg = cfg
        self.max_len = max_len
        self.params = params if params is not None else init_params(
            param_specs(cfg), jax.random.PRNGKey(seed))
        self._encode = jax.jit(functools.partial(encode_tokens, cfg=cfg))

    @property
    def dim(self) -> int:
        return self.cfg.d_model

    def embed(self, texts: list[str]) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.cfg.d_model), np.float32)
        seqs = [TOKENIZER.encode(t)[: self.max_len] for t in texts]
        out = []
        bs = 64
        for i in range(0, len(seqs), bs):
            batch = seqs[i:i + bs]
            width = max(16, max(len(s) for s in batch))
            toks = TOKENIZER.pad_batch(batch, width)
            mask = (toks != TOKENIZER.pad_id).astype(np.float32)
            out.append(np.asarray(self._encode(self.params, jnp.asarray(toks), jnp.asarray(mask))))
        return np.concatenate(out, axis=0)
