"""shard_map MoE: shard-local dispatch + explicit TP/EP reduction.

GSPMD partitions the dispatch scatter-add by computing per-device partial
scatters into the FULL [B, E, C, d] buffer and all-reducing it — ~TB/step of
wire traffic at mixtral scale (§Perf iteration log).  This path makes the
locality explicit instead:

  * batch rows over the dp axes (dispatch/combine are per-row — fully local),
  * experts over ``model`` when divisible (expert parallelism: every shard
    dispatches only its local experts; the final psum over ``model`` merges
    expert contributions),
  * otherwise d_ff over ``model`` (tensor parallelism inside experts; the
    same psum merges the w_down row-parallel partials),
  * FSDP-resident weight dims are all-gathered at entry by jit (ZeRO-3
    semantics come from the in_specs mismatch with the stored sharding).

Semantics match moe_ffn up to capacity accounting (per local expert id).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.moe import capacity


def _dp(mesh: Mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def moe_ffn_sharded(params, x, *, cfg: ModelConfig, mesh: Mesh):
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, s)
    ep = e % mesh.shape["model"] == 0          # expert parallelism viable?
    dp_spec = _dp(mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    w_specs = {
        "router": P(None, None),
        "w_gate": P("model" if ep else None, None, None if ep else "model"),
        "w_up": P("model" if ep else None, None, None if ep else "model"),
        "w_down": P("model" if ep else None, None if ep else "model", None),
    }

    def local(router, w_gate, w_up, w_down, x):
        bl = x.shape[0]
        e_loc = w_gate.shape[0]
        n_shard = e // e_loc
        shard = jax.lax.axis_index("model") if ep else 0

        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        if k > 1:
            gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot.reshape(bl, s * k, e), axis=1) - 1
        pos = jnp.sum(pos * onehot.reshape(bl, s * k, e), axis=-1).reshape(bl, s, k)
        local_e = expert_idx - shard * e_loc    # this shard's expert range
        in_range = (local_e >= 0) & (local_e < e_loc)
        dropped = (pos >= cap) | ~in_range
        slot = jnp.where(dropped, cap, pos)
        eidx = jnp.clip(local_e, 0, e_loc - 1)

        buf = jnp.zeros((bl, e_loc, cap + 1, d), x.dtype)
        bidx = jnp.arange(bl)[:, None, None]
        buf = buf.at[bidx, eidx, slot].add(
            jnp.broadcast_to(x[:, :, None, :], (bl, s, k, d)), mode="drop")
        buf = buf[:, :, :cap]

        g = jnp.einsum("becd,edf->becf", buf, w_gate)
        u = jnp.einsum("becd,edf->becf", buf, w_up)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        out_buf = jnp.einsum("becf,efd->becd", h, w_down)
        out_buf = jnp.concatenate([out_buf, jnp.zeros((bl, e_loc, 1, d), out_buf.dtype)], 2)

        gathered = out_buf[bidx, eidx, slot]
        gates = jnp.where(dropped, 0.0, gate_vals).astype(x.dtype)
        y = jnp.einsum("bskd,bsk->bsd", gathered, gates)
        # merge expert shards (EP) / row-parallel partials (TP)
        y = jax.lax.psum(y, "model")

        frac = jnp.mean(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=(1, 2))
        lb = e * jnp.mean(jnp.sum(frac * jnp.mean(probs, axis=1), axis=-1))
        z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        if dp_axes:  # aux losses averaged over data shards -> replicated
            lb = jax.lax.pmean(lb, dp_axes)
            z = jax.lax.pmean(z, dp_axes)
        return y, lb, z

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(w_specs["router"], w_specs["w_gate"], w_specs["w_up"],
                  w_specs["w_down"], P(dp_spec, None, None)),
        out_specs=(P(dp_spec, None, None), P(), P()),
    )
    y, lb, z = fn(params["router"], params["w_gate"], params["w_up"],
                  params["w_down"], x)
    aux = {"moe_lb": lb * cfg.router_aux_coef, "moe_z": z * 1e-3}
    return y, aux
