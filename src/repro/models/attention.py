"""GQA attention: full / chunked (memory-safe at 32k+) / sliding-window / cross /
decode-against-cache.  Pure jnp; the Pallas flash kernels in ``repro.kernels``
are the TPU hot path and are selected via ``cfg.attn_impl == 'pallas'``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import ParamSpec
from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attention_spec(cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    specs = {
        ("wq",): ParamSpec((d, h, hd), ("embed_in", "heads", "qkv"), init="scaled"),
        ("wk",): ParamSpec((d, hk, hd), ("embed_in", "kv_heads", "qkv"), init="scaled"),
        ("wv",): ParamSpec((d, hk, hd), ("embed_in", "kv_heads", "qkv"), init="scaled"),
        ("wo",): ParamSpec((h, hd, d), ("heads", "qkv_in", "embed_out"), init="scaled"),
    }
    if cfg.qkv_bias and not cross:
        specs[("bq",)] = ParamSpec((h, hd), ("heads", "qkv"), init="zeros", dtype=jnp.float32)
        specs[("bk",)] = ParamSpec((hk, hd), ("kv_heads", "qkv"), init="zeros", dtype=jnp.float32)
        specs[("bv",)] = ParamSpec((hk, hd), ("kv_heads", "qkv"), init="zeros", dtype=jnp.float32)
    return specs


def project_qkv(params, x, mem=None, *, cfg: ModelConfig, positions=None):
    """Project hidden states to (q, k, v). ``mem`` (cross-attn) supplies k/v."""
    src = x if mem is None else mem
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if positions is not None and mem is None:  # RoPE only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(params, attn_out):
    return jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"])


# ---------------------------------------------------------------------------
# Core softmax attention (GQA-aware)
# ---------------------------------------------------------------------------


def _repeat_kv(k, num_heads):
    """[B,S,Hk,hd] -> [B,S,H,hd]. The repeat-KV formulation (instead of a
    [Hk, G] grouped reshape) keeps the q-heads dimension intact so GSPMD can
    shard it over the ``model`` axis even when Hk < mesh width — a grouped
    reshape of a sharded 64-head axis into [8, 8] is unpartitionable and
    silently replicates attention compute across the whole model axis."""
    hk = k.shape[2]
    if hk == num_heads:
        return k
    return jnp.repeat(k, num_heads // hk, axis=2)


def gqa_attend(q, k, v, mask):
    """q:[B,Sq,H,hd] k,v:[B,Sk,Hk,hd] mask: broadcastable to [B,1,Sq,Sk] (bool).

    Returns [B,Sq,H,hd]. Softmax in f32.

    Sq > 1 (train/prefill) uses the repeat-KV formulation so the q-heads dim
    shards over the model axis (a grouped reshape of a sharded heads axis is
    unpartitionable).  Sq == 1 (decode) uses the grouped einsum instead: the
    decode step is KV-bandwidth-bound, repeat-KV would materialize (and
    stream) group-times more cache bytes, and the tiny single-token q is
    replicated anyway (§Perf iteration log, qwen2-72b x decode_32k).
    """
    with jax.named_scope("attn_core"):
        b, sq, h, hd = q.shape
        hk = k.shape[2]
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        if sq == 1 and hk != h:
            g = h // hk
            qg = q.reshape(b, 1, hk, g, hd)
            scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                                preferred_element_type=jnp.float32) * scale
            scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                               scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
            return out.reshape(b, 1, h, hd)
        k = _repeat_kv(k, h)
        v = _repeat_kv(v, h)
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32)
        scores = scores * scale
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
        return out


def make_mask(q_pos, k_pos, *, causal: bool, window: int = 0, k_valid=None):
    """Boolean mask [.., Sq, Sk] from absolute positions."""
    m = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], bool)
    if causal:
        m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window:
        m = m & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    if k_valid is not None:
        m = m & k_valid[..., None, :]
    return m


# ---------------------------------------------------------------------------
# Full / chunked self-attention over a sequence
# ---------------------------------------------------------------------------


def self_attention(params, x, *, cfg: ModelConfig, causal: bool = True):
    """Training/prefill self-attention with automatic q-chunking for long seq.

    Returns (out [B,S,D], (k, v)) — k/v are handed back so prefill can fill a
    decode cache without recomputing projections.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = project_qkv(params, x, cfg=cfg, positions=positions)
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if s > 8 * cfg.attn_q_chunk else "full"
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=cfg.sliding_window)
    elif impl == "chunked":
        out = _kv_chunked_attention(q, k, v, cfg=cfg, causal=causal)
    else:
        mask = make_mask(jnp.arange(s), jnp.arange(s), causal=causal, window=cfg.sliding_window)
        out = gqa_attend(q, k, v, mask[None, None])
    return out_proj(params, out), (k, v)


def _kv_chunked_attention(q, k, v, *, cfg: ModelConfig, causal: bool):
    """Flash-style online-softmax scan over KV blocks.

    q is never sliced (it may be sequence-sharded across the ``model`` axis —
    slicing a sharded dim would force GSPMD to reshard); k/v are sliced on
    their (replicated/gathered) sequence dim, which is free.  Peak score
    buffer is [B, H, Sq_local, C] for one KV block.
    """
    with jax.named_scope("attn_core"):
        b, s, h, hd = q.shape
        c = min(cfg.attn_q_chunk, s)
        if s % c:  # pad KV with masked tail positions (q is never padded)
            pad = c - s % c
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_kv = k.shape[1]
        n = s_kv // c
        k = _repeat_kv(k, h)
        v = _repeat_kv(v, h)
        kc = k.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)  # [n,B,C,H,hd]
        vc = v.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)
        q_pos = jnp.arange(s)
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        f32 = jnp.float32

        m0 = jnp.full((b, h, s), NEG_INF, f32)
        l0 = jnp.zeros((b, h, s), f32)
        o0 = jnp.zeros((b, s, h, hd), f32)

        def body(carry, kv_i):
            m, l, o = carry
            k_blk, v_blk, i = kv_i
            k_pos = i * c + jnp.arange(c)
            mask = make_mask(q_pos, k_pos, causal=causal, window=cfg.sliding_window,
                             k_valid=k_pos < s)  # excludes padded tail keys
            sc = jnp.einsum("bqhd,bshd->bhqs", q, k_blk, preferred_element_type=f32) * scale
            sc = jnp.where(mask[None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l = l * alpha + jnp.sum(p, axis=-1)
            o = o * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqs,bshd->bqhd", p.astype(v_blk.dtype), v_blk).astype(f32)
            return (m_new, l, o), None

        (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kc, vc, jnp.arange(n)))
        o = o / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        return o.astype(q.dtype)


def cross_attention(params, x, mem, *, cfg: ModelConfig, mem_valid=None):
    """Cross-attention to a memory (image patches / audio frames / encoder out)."""
    q, k, v = project_qkv(params, x, mem, cfg=cfg)
    sq, sk = x.shape[1], mem.shape[1]
    mask = make_mask(jnp.arange(sq), jnp.arange(sk), causal=False, k_valid=mem_valid)
    out = gqa_attend(q, k, v, mask[None, None])
    return out_proj(params, out)


# ---------------------------------------------------------------------------
# Decode-step attention against a KV cache
# ---------------------------------------------------------------------------


def decode_self_attention(params, x, k_cache, v_cache, cache_len, *, cfg: ModelConfig):
    """x: [B,1,D]; caches: [B,Smax,Hk,hd]. Writes new kv at ``cache_len``.

    ``cache_len`` may be a scalar (uniform batch; dry-run serve_step) or a
    [B] vector (continuous batching: per-slot lengths).
    Returns (out [B,1,D], new_k_cache, new_v_cache).
    """
    b = x.shape[0]
    if cfg.decode_cp:
        from repro.dist import sharding as shd
        ctx = getattr(shd._ctx, "cfg", None)
        if ctx is not None and "model" in ctx[0].axis_names:
            from repro.dist.context_parallel import cp_decode_self_attention
            mesh, rules = ctx
            spec = shd.resolve_pspec(k_cache.shape, ("batch", "kv_seq", "kv_heads", "qkv"),
                                     mesh, rules)
            seq_axes = spec[1] if spec[1] is not None else "model"
            return cp_decode_self_attention(params, x, k_cache, v_cache, cache_len,
                                            cfg=cfg, mesh=mesh, axis=seq_axes,
                                            dp_spec=spec[0])
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    positions = lens[:, None]
    q, k_new, v_new = project_qkv(params, x, cfg=cfg, positions=positions)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, lens].set(k_new[:, 0].astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[bidx, lens].set(v_new[:, 0].astype(v_cache.dtype), mode="drop")
    s_max = k_cache.shape[1]
    k_pos = jnp.arange(s_max)
    k_valid = k_pos[None, :] <= lens[:, None]
    if cfg.sliding_window:
        k_valid = k_valid & (lens[:, None] - k_pos[None, :] < cfg.sliding_window)
    mask = k_valid[:, None, None, :]
    out = gqa_attend(q, k_cache, v_cache, mask)
    return out_proj(params, out), k_cache, v_cache


def decode_cross_attention(params, x, k_mem, v_mem, *, cfg: ModelConfig):
    """Cross-attn during decode with precomputed memory K/V: [B,Sm,Hk,hd]."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
    mask = jnp.ones((1, 1, 1, k_mem.shape[1]), bool)
    out = gqa_attend(q, k_mem, v_mem, mask)
    return out_proj(params, out)
