"""xLSTM language model: grouped stacks of mLSTM blocks with an sLSTM block
every ``cfg.slstm_every`` layers (xLSTM[m:s] notation of arXiv:2405.04517).
"""
from __future__ import annotations

import functools

import jax

from repro.common import ParamSpec, SpecTree
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import xlstm as X
from repro.models.transformer import _group_tree, _maybe_remat, _stack


def _layout(cfg: ModelConfig):
    if cfg.slstm_every:
        g = cfg.num_layers // cfg.slstm_every
        return {"groups": g, "m_per_group": cfg.slstm_every - 1,
                "n_m": g * (cfg.slstm_every - 1), "n_s": g}
    return {"groups": 0, "m_per_group": 0, "n_m": cfg.num_layers, "n_s": 0}


def _m_block_specs(cfg):
    specs = {("norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()}
    specs.update({("mixer",) + p: s for p, s in X.mlstm_spec(cfg).items()})
    return specs


def _s_block_specs(cfg):
    specs = {("norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()}
    specs.update({("mixer",) + p: s for p, s in X.slstm_spec(cfg).items()})
    return specs


def param_specs(cfg: ModelConfig) -> SpecTree:
    lay = _layout(cfg)
    specs: SpecTree = {}
    specs.update({("embed",) + p: s for p, s in L.embed_spec(cfg.vocab_size, cfg.d_model).items()})
    specs.update(_stack(_m_block_specs(cfg), lay["n_m"], "m_layers"))
    if lay["n_s"]:
        specs.update(_stack(_s_block_specs(cfg), lay["n_s"], "s_layers"))
    specs.update({("final_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    specs.update({("out",) + p: s
                  for p, s in L.unembed_spec(cfg.vocab_size, cfg.d_model, tied=cfg.tie_embeddings).items()})
    return specs


def _m_block(lp, x, *, cfg, state=None, return_state=False):
    from repro.dist.sharding import shard_activation
    x = shard_activation(x, ("batch", None, None))
    h = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
    if return_state:
        y, st = X.mlstm_forward(lp["mixer"], h, cfg=cfg, state=state, return_state=True)
        return x + y, st
    return x + X.mlstm_forward(lp["mixer"], h, cfg=cfg), None


def _s_block(lp, x, *, cfg, state=None, return_state=False):
    h = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
    if return_state:
        y, st = X.slstm_forward(lp["mixer"], h, cfg=cfg, state=state, return_state=True)
        return x + y, st
    return x + X.slstm_forward(lp["mixer"], h, cfg=cfg), None


def _run_seq(params, x, *, cfg: ModelConfig, remat: bool, collect_state: bool):
    lay = _layout(cfg)
    mb = _maybe_remat(functools.partial(_m_block, cfg=cfg, return_state=collect_state), cfg, remat)
    sb = _maybe_remat(functools.partial(_s_block, cfg=cfg, return_state=collect_state), cfg, remat)
    states = {}
    if lay["n_s"] == 0:
        def body(x, lp):
            x, st = mb(lp, x)
            return x, st
        x, sts = jax.lax.scan(body, x, params["m_layers"])
        if collect_state:
            states["m"] = sts
    else:
        m_groups = _group_tree(params["m_layers"], lay["groups"])

        def group(x, gp):
            mp, sp = gp

            def inner(x, lp):
                x, st = mb(lp, x)
                return x, st

            x, msts = jax.lax.scan(inner, x, mp)
            x, sst = sb(sp, x)
            return x, (msts, sst)

        x, (msts, ssts) = jax.lax.scan(group, x, (m_groups, params["s_layers"]))
        if collect_state:
            states["m"] = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), msts)
            states["s"] = ssts
    return x, states


def _logits(params, x, cfg):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)


def forward(params, tokens, *, cfg: ModelConfig, extra=None, remat=False):
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x, _ = _run_seq(params, x, cfg=cfg, remat=remat, collect_state=False)
    return _logits(params, x, cfg), {}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> SpecTree:
    lay = _layout(cfg)
    specs: SpecTree = {}
    for p, s in X.mlstm_state_specs(cfg, batch).items():
        specs[("m",) + p] = ParamSpec((lay["n_m"],) + s.shape, ("layers",) + s.axes, dtype=s.dtype, init="zeros")
    for p, s in X.slstm_state_specs(cfg, batch).items():
        if lay["n_s"]:
            specs[("s",) + p] = ParamSpec((lay["n_s"],) + s.shape, ("layers",) + s.axes, dtype=s.dtype, init="zeros")
    return specs


def prefill(params, tokens, cache, *, cfg: ModelConfig, extra=None, last_only=False):
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x, states = _run_seq(params, x, cfg=cfg, remat=False, collect_state=True)
    if last_only:
        x = x[:, -1:]
    return _logits(params, x, cfg), states


def decode_step(params, tokens, cache, cache_len, *, cfg: ModelConfig, extra=None):
    lay = _layout(cfg)
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)

    def m_step(x, inp):
        lp, st = inp
        h = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
        st, y = X.mlstm_decode(lp["mixer"], st, h, cfg=cfg)
        return x + y, st

    new_cache: dict = {}
    if lay["n_s"] == 0:
        x, msts = jax.lax.scan(m_step, x, (params["m_layers"], cache["m"]))
        new_cache["m"] = msts
    else:
        m_groups = _group_tree(params["m_layers"], lay["groups"])
        m_states = _group_tree(cache["m"], lay["groups"])

        def group(x, inp):
            mp, mst, sp, sst = inp
            x, msts = jax.lax.scan(m_step, x, (mp, mst))
            h = L.rmsnorm(sp["norm"], x, cfg.norm_eps)
            sst, y = X.slstm_decode(sp["mixer"], sst, h, cfg=cfg)
            return x + y, (msts, sst)

        x, (msts, ssts) = jax.lax.scan(group, x, (m_groups, m_states, params["s_layers"], cache["s"]))
        new_cache["m"] = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), msts)
        new_cache["s"] = ssts
    return _logits(params, x, cfg), new_cache
