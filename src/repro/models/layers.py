"""Shared neural-net building blocks (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> dict:
    return {("scale",): ParamSpec((d,), ("embed",), init="ones", dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


def layernorm_spec(d: int) -> dict:
    return {
        ("scale",): ParamSpec((d,), ("embed",), init="ones", dtype=jnp.float32),
        ("bias",): ParamSpec((d,), ("embed",), init="zeros", dtype=jnp.float32),
    }


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (SwiGLU for llama-family, GELU for whisper)
# ---------------------------------------------------------------------------


def swiglu_spec(d: int, d_ff: int) -> dict:
    return {
        ("w_gate",): ParamSpec((d, d_ff), ("embed_in", "mlp_out"), init="scaled"),
        ("w_up",): ParamSpec((d, d_ff), ("embed_in", "mlp_out"), init="scaled"),
        ("w_down",): ParamSpec((d_ff, d), ("mlp", "embed_out"), init="scaled"),
    }


def swiglu(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


def gelu_ffn_spec(d: int, d_ff: int) -> dict:
    return {
        ("w_in",): ParamSpec((d, d_ff), ("embed_in", "mlp_out"), init="scaled"),
        ("b_in",): ParamSpec((d_ff,), ("mlp",), init="zeros", dtype=jnp.float32),
        ("w_out",): ParamSpec((d_ff, d), ("mlp", "embed_out"), init="scaled"),
        ("b_out",): ParamSpec((d,), ("embed",), init="zeros", dtype=jnp.float32),
    }


def gelu_ffn(params, x):
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"]) + params["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"]) + params["b_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d: int) -> dict:
    return {("embedding",): ParamSpec((vocab, d), ("vocab", "embed"), init="normal")}


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x, *, tied: bool):
    w = params["embedding"] if tied else params["head"]
    if tied:
        return jnp.einsum("bsd,vd->bsv", x, w, preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)


def unembed_spec(vocab: int, d: int, *, tied: bool) -> dict:
    if tied:
        return {}
    return {("head",): ParamSpec((d, vocab), ("embed_in", "vocab"), init="scaled")}
