"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block applied
every ``cfg.attn_every`` layers (arXiv:2411.15242).

Simplifications vs the released checkpoints (noted in DESIGN.md):
  * the shared block's "concatenated original embedding" skip is realized as a
    learned projection of the token embedding added to the block input
    (keeps width d instead of 2d),
  * per-application LoRA deltas on the shared block are omitted (pure sharing).

Depth layout for L layers, every=k:  G = L // k groups of (k mamba layers +
1 shared-attn application), then L - G*k trailing mamba layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import ParamSpec, SpecTree
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import _group_tree, _maybe_remat


def _layout(cfg: ModelConfig):
    g = cfg.num_layers // cfg.attn_every
    return {"groups": g, "per_group": cfg.attn_every,
            "tail": cfg.num_layers - g * cfg.attn_every}


def _mamba_block_specs(cfg: ModelConfig) -> dict:
    specs = {("norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()}
    specs.update({("mixer",) + p: s for p, s in ssm.mamba2_spec(cfg).items()})
    return specs


def _shared_attn_specs(cfg: ModelConfig) -> dict:
    specs: dict = {}
    specs.update({("attn",) + p: s for p, s in attn.attention_spec(cfg).items()})
    specs.update({("attn_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    specs.update({("ffn_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    specs.update({("ffn",) + p: s for p, s in L.swiglu_spec(cfg.d_model, cfg.d_ff).items()})
    specs[("skip_proj",)] = ParamSpec((cfg.d_model, cfg.d_model), ("embed_in", "embed_out"), init="scaled")
    return specs


def param_specs(cfg: ModelConfig) -> SpecTree:
    lay = _layout(cfg)
    specs: SpecTree = {}
    specs.update({("embed",) + p: s for p, s in L.embed_spec(cfg.vocab_size, cfg.d_model).items()})
    from repro.models.transformer import _stack
    specs.update(_stack(_mamba_block_specs(cfg), lay["groups"] * lay["per_group"], "mamba_layers"))
    if lay["tail"]:
        specs.update(_stack(_mamba_block_specs(cfg), lay["tail"], "tail_layers"))
    specs.update({("shared",) + p: s for p, s in _shared_attn_specs(cfg).items()})
    specs.update({("final_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    specs.update({("out",) + p: s
                  for p, s in L.unembed_spec(cfg.vocab_size, cfg.d_model, tied=cfg.tie_embeddings).items()})
    return specs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _mamba_block_seq(lp, x, *, cfg, state=None, return_state=False):
    from repro.dist.sharding import shard_activation
    x = shard_activation(x, ("batch", None, None))  # keep batch on dp axes
    h = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
    if return_state:
        y, st = ssm.mamba2_forward(lp["mixer"], h, cfg=cfg, state=state, return_state=True)
        return x + y, st
    return x + ssm.mamba2_forward(lp["mixer"], h, cfg=cfg), None


def _shared_attn_seq(sp, x, x0, *, cfg):
    """Shared transformer block; x0 is the original token embedding (skip)."""
    from repro.dist.sharding import shard_activation
    x = shard_activation(x, ("batch", None, None))
    h_in = x + jnp.einsum("bsd,de->bse", x0, sp["skip_proj"])
    h = L.rmsnorm(sp["attn_norm"], h_in, cfg.norm_eps)
    a, kv = attn.self_attention(sp["attn"], h, cfg=cfg)
    x = x + a
    h = L.rmsnorm(sp["ffn_norm"], x, cfg.norm_eps)
    return x + L.swiglu(sp["ffn"], h), kv


def _shared_attn_decode(sp, x, x0, k_cache, v_cache, cache_len, *, cfg):
    h_in = x + jnp.einsum("bsd,de->bse", x0, sp["skip_proj"])
    h = L.rmsnorm(sp["attn_norm"], h_in, cfg.norm_eps)
    a, k_cache, v_cache = attn.decode_self_attention(sp["attn"], h, k_cache, v_cache, cache_len, cfg=cfg)
    x = x + a
    h = L.rmsnorm(sp["ffn_norm"], x, cfg.norm_eps)
    return x + L.swiglu(sp["ffn"], h), k_cache, v_cache


# ---------------------------------------------------------------------------
# Forward / prefill / decode
# ---------------------------------------------------------------------------


def _run_seq(params, x, *, cfg: ModelConfig, remat: bool, collect_state: bool):
    lay = _layout(cfg)
    x0 = x
    mb = _maybe_remat(functools.partial(_mamba_block_seq, cfg=cfg, return_state=collect_state), cfg, remat)
    groups = _group_tree(params["mamba_layers"], lay["groups"])
    kv_caches = []
    states: dict = {}

    def inner(x, lp):
        x, st = mb(lp, x)
        return x, st

    def group(x, gp):
        x, sts = jax.lax.scan(inner, x, gp)
        x, kv = _shared_attn_seq(params["shared"], x, x0, cfg=cfg)
        # only stack ys that are consumed — unused scan outputs still
        # materialize [G, ...] buffers in the compiled loop
        return x, ((sts, kv) if collect_state else None)

    x, ys = jax.lax.scan(group, x, groups)
    if collect_state:
        sts, kvs = ys
        states["mamba"] = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), sts)
        kv_caches = kvs  # stacked over groups: [G,B,S,hk,hd]
    if lay["tail"]:
        x, tail_sts = jax.lax.scan(inner, x, params["tail_layers"])
        if collect_state:
            states["tail"] = tail_sts
    return x, states, kv_caches


def forward(params, tokens, *, cfg: ModelConfig, extra=None, remat=False):
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x, _, _ = _run_seq(params, x, cfg=cfg, remat=remat, collect_state=False)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)
    return logits, {}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> SpecTree:
    lay = _layout(cfg)
    specs: SpecTree = {}
    for path, s in ssm.mamba2_state_specs(cfg, batch).items():
        n = lay["groups"] * lay["per_group"]
        specs[("mamba",) + path] = ParamSpec((n,) + s.shape, ("layers",) + s.axes, dtype=s.dtype, init="zeros")
        if lay["tail"]:
            specs[("tail",) + path] = ParamSpec((lay["tail"],) + s.shape, ("layers",) + s.axes,
                                                dtype=s.dtype, init="zeros")
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads", "qkv")
    shp = (lay["groups"], batch, max_seq, cfg.num_kv_heads, cfg.hd)
    specs[("attn", "k")] = ParamSpec(shp, kv_axes, dtype=jnp.dtype(cfg.dtype), init="zeros")
    specs[("attn", "v")] = ParamSpec(shp, kv_axes, dtype=jnp.dtype(cfg.dtype), init="zeros")
    return specs


def prefill(params, tokens, cache, *, cfg: ModelConfig, extra=None, last_only=False):
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x, states, kvs = _run_seq(params, x, cfg=cfg, remat=False, collect_state=True)
    from repro.models.transformer import _write_prefill
    new_cache = {
        "mamba": states["mamba"],
        "attn": {"k": _write_prefill(cache["attn"]["k"], kvs[0]),
                 "v": _write_prefill(cache["attn"]["v"], kvs[1])},
    }
    if "tail" in states:
        new_cache["tail"] = states["tail"]
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)
    return logits, new_cache


def decode_step(params, tokens, cache, cache_len, *, cfg: ModelConfig, extra=None):
    lay = _layout(cfg)
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x0 = x
    groups = _group_tree(params["mamba_layers"], lay["groups"])
    mstate = _group_tree(cache["mamba"], lay["groups"])

    def inner(x, inp):
        lp, st = inp
        h = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
        st, y = ssm.mamba2_decode(lp["mixer"], st, h, cfg=cfg)
        return x + y, st

    def group(x, inp):
        gp, gst, kc, vc = inp
        x, sts = jax.lax.scan(inner, x, (gp, gst))
        x, kc, vc = _shared_attn_decode(params["shared"], x, x0, kc, vc, cache_len, cfg=cfg)
        return x, (sts, kc, vc)

    x, (msts, ks, vs) = jax.lax.scan(group, x, (groups, mstate, cache["attn"]["k"], cache["attn"]["v"]))
    new_cache = {
        "mamba": jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), msts),
        "attn": {"k": ks, "v": vs},
    }
    if lay["tail"]:
        x, tsts = jax.lax.scan(inner, x, (params["tail_layers"], cache["tail"]))
        new_cache["tail"] = tsts
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)
    return logits, new_cache
